"""Object store with a mounted-filesystem view + streaming cache (FfDL C8).

FfDL §3.7: "FfDL can mount remote data in the learner container, so DL
frameworks can access training data as though it were on the local
filesystem. A driver streams files on demand and caches them so they can be
reused across training epochs and jobs."

``ObjectStore`` models the remote service (buckets of immutable blobs with
GET/PUT/LIST and per-operation latency+bandwidth accounting so the scale
benchmark can reproduce §5.5's shared-bandwidth degradation).
``MountedBucket`` is the driver: a file-like read path backed by an LRU block
cache shared across epochs *and jobs* — the optimization the paper's
"lessons learned" section calls out.
"""

from __future__ import annotations

import hashlib
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Optional


class ObjectStoreError(Exception):
    pass


@dataclass
class StoreStats:
    gets: int = 0
    puts: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    cache_hits: int = 0
    cache_misses: int = 0


class ObjectStore:
    """In-process object storage service: buckets → key → immutable bytes.

    ``bandwidth_bps`` (optional) models the shared network/storage pipe: each
    transfer asks the clock for ``size / bandwidth`` seconds, which the scale
    benchmark aggregates to reproduce heavy-load degradation.
    """

    def __init__(self, clock=None, bandwidth_bps: Optional[float] = None):
        self._buckets: dict[str, dict[str, bytes]] = {}
        self._lock = threading.RLock()
        self.stats = StoreStats()
        self.clock = clock
        self.bandwidth_bps = bandwidth_bps
        self.fail_next: int = 0  # chaos hook: fail the next N operations
        # gray-failure interposition (objstore.get / objstore.put): wired
        # by the owning platform to the shared FaultPlane
        self.faults = None
        self.fault_key = None

    def _maybe_fail(self, op: str):
        if self.fail_next > 0:
            self.fail_next -= 1
            raise ObjectStoreError(f"injected object-store fault during {op}")
        if self.faults is not None:
            self.faults.on(f"objstore.{op}", key=self.fault_key,
                           exc=ObjectStoreError)

    def _charge(self, nbytes: int):
        if self.clock is not None and self.bandwidth_bps:
            self.clock.advance(nbytes / self.bandwidth_bps)

    def create_bucket(self, name: str):
        with self._lock:
            self._buckets.setdefault(name, {})

    def put(self, bucket: str, key: str, data):
        self._maybe_fail("put")
        if isinstance(data, str):
            data = data.encode()
        with self._lock:
            self._buckets.setdefault(bucket, {})[key] = bytes(data)
            self.stats.puts += 1
            self.stats.bytes_written += len(data)
        self._charge(len(data))

    def get(self, bucket: str, key: str) -> bytes:
        self._maybe_fail("get")
        with self._lock:
            try:
                data = self._buckets[bucket][key]
            except KeyError:
                raise ObjectStoreError(f"no such object {bucket}/{key}")
            self.stats.gets += 1
            self.stats.bytes_read += len(data)
        self._charge(len(data))
        return data

    def delete(self, bucket: str, key: str):
        with self._lock:
            self._buckets.get(bucket, {}).pop(key, None)

    def list(self, bucket: str, prefix: str = "") -> list[str]:
        with self._lock:
            return sorted(k for k in self._buckets.get(bucket, {})
                          if k.startswith(prefix))

    def exists(self, bucket: str, key: str) -> bool:
        with self._lock:
            return key in self._buckets.get(bucket, {})


class BlockCache:
    """LRU byte-block cache shared across MountedBucket instances.

    Keyed by (bucket, key) — "the same datasets are often used across jobs,
    and an intelligent caching layer tuned to DL access patterns could have
    significant cost and performance improvements" (FfDL §4).
    """

    def __init__(self, capacity_bytes: int = 1 << 30):
        self.capacity = capacity_bytes
        self._lru: OrderedDict[tuple, bytes] = OrderedDict()
        self._size = 0
        self._lock = threading.Lock()

    def get(self, k):
        with self._lock:
            if k in self._lru:
                self._lru.move_to_end(k)
                return self._lru[k]
        return None

    def put(self, k, data: bytes):
        with self._lock:
            if k in self._lru:
                return
            self._lru[k] = data
            self._size += len(data)
            while self._size > self.capacity and self._lru:
                _, evicted = self._lru.popitem(last=False)
                self._size -= len(evicted)


class MountedBucket:
    """Filesystem-like read view of a bucket with read-through caching."""

    def __init__(self, store: ObjectStore, bucket: str,
                 cache: Optional[BlockCache] = None):
        self.store = store
        self.bucket = bucket
        self.cache = cache

    def read(self, key: str) -> bytes:
        ck = (self.bucket, key)
        if self.cache is not None:
            hit = self.cache.get(ck)
            if hit is not None:
                self.store.stats.cache_hits += 1
                return hit
            self.store.stats.cache_misses += 1
        data = self.store.get(self.bucket, key)
        if self.cache is not None:
            self.cache.put(ck, data)
        return data

    def write(self, key: str, data: bytes):
        self.store.put(self.bucket, key, data)

    def listdir(self, prefix: str = "") -> list[str]:
        return self.store.list(self.bucket, prefix)

    def exists(self, key: str) -> bool:
        return self.store.exists(self.bucket, key)


class DirBucket:
    """MountedBucket-compatible view over a local directory (the launcher's
    checkpoint target when no object-store service is wired in)."""

    def __init__(self, root: str):
        import os
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        import os
        return os.path.join(self.root, key)

    def read(self, key: str) -> bytes:
        with open(self._path(key), "rb") as f:
            return f.read()

    def write(self, key: str, data):
        import os
        if isinstance(data, str):
            data = data.encode()
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)  # atomic publish

    def listdir(self, prefix: str = "") -> list:
        import os
        out = []
        for dirpath, _, files in os.walk(self.root):
            for fn in files:
                rel = os.path.relpath(os.path.join(dirpath, fn), self.root)
                rel = rel.replace(os.sep, "/")
                if rel.startswith(prefix) and not rel.endswith(".tmp"):
                    out.append(rel)
        return sorted(out)

    def exists(self, key: str) -> bool:
        import os
        return os.path.exists(self._path(key))
