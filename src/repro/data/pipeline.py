"""Deterministic sharded token pipeline.

Properties the platform relies on:
  * **step-keyed determinism** — batch(step) is a pure function of
    (dataset_seed, step), so a learner recovering from a checkpoint at step k
    regenerates exactly the batches the crashed learner would have seen.
    This is what makes the crash-recovery integration test able to assert
    bitwise-identical loss trajectories.
  * **host sharding** — each data-parallel host reads only its slice.
  * **prefetch** — a background thread keeps ``prefetch`` batches ready,
    modeling the load-data helper; worker count drives the Table 4/6
    resource-sizing benchmark.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_index: int = 0


class SyntheticLM:
    """Synthetic next-token-prediction stream with a learnable structure.

    Tokens follow a noisy arithmetic progression per sequence, so models can
    actually reduce loss on it (used by the e2e training example); labels are
    the next token.
    """

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_index]))
        b, s = self.local_batch, cfg.seq_len
        start = rng.integers(0, cfg.vocab_size, (b, 1))
        stride = rng.integers(1, 7, (b, 1))
        seq = (start + stride * np.arange(s + 1)) % cfg.vocab_size
        noise = rng.random((b, s + 1)) < 0.05
        seq = np.where(noise, rng.integers(0, cfg.vocab_size, (b, s + 1)), seq)
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchIterator:
    """Background-thread prefetch of a batch iterator (the load-data helper).

    ``workers`` scales the synthetic per-batch preparation cost the way CPU
    feeder threads scale input throughput in the paper's Tables 4/6.
    """

    def __init__(self, source: Iterator[dict], prefetch: int = 2,
                 workers: int = 1, prep_cost_s: float = 0.0):
        self.source = source
        self.prep_cost_s = prep_cost_s
        self.workers = max(1, workers)
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        import time
        for item in self.source:
            if self._stop.is_set():
                return
            if self.prep_cost_s:
                time.sleep(self.prep_cost_s / self.workers)
            while not self._stop.is_set():
                try:
                    self.q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        return self.q.get()

    def close(self):
        self._stop.set()


def shard_batch(batch: dict, mesh=None, batch_spec=None):
    """Device-put a host batch with the batch PartitionSpec (or as-is)."""
    if mesh is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, batch_spec)), batch)
