"""whisper-tiny [audio] — 4L d_model=384 6H d_ff=1536 vocab=51865; enc-dec
with conv frontend STUB (input_specs feeds precomputed (B, 1500, 384) frame
embeddings). [arXiv:2212.04356]

Whisper uses LayerNorm + GELU + absolute (sinusoidal) positions, no RoPE.
decode_32k exceeds Whisper's 448 trained positions — mechanically valid via
sinusoidal positions, noted in DESIGN.md §7.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,  # decoder layers
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        is_encoder_decoder=True,
        n_enc_layers=4,
        enc_seq=1500,
        act="gelu",
        rms_norm=False,
        use_rope=False,
        tie_embeddings=True,
        scan_layers=False,
    )


def tiny() -> ModelConfig:
    return config().replace(
        name="whisper-tiny-tiny",
        n_layers=2,
        n_enc_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        enc_seq=32,
        attn_chunk=64,
    )
