from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    all_cells,
    cells,
    get_config,
    get_tiny_config,
)

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "all_cells",
    "cells",
    "get_config",
    "get_tiny_config",
]
