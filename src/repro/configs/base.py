"""Model & shape configuration.

One ``ModelConfig`` per assigned architecture lives in
``src/repro/configs/<arch_id>.py`` (exact numbers from the assignment) plus a
``tiny()`` reduced variant of the same family for CPU smoke tests. The
registry resolves ``--arch <id>`` lookups for the launcher, dry-run and
benchmarks.
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | audio | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    local_window: int = 0  # 0 → global attention
    attn_chunk: int = 512  # flash block size
    # layer pattern, cycled: entries in {attn, mlstm, slstm, rglru}
    block_pattern: tuple = ("attn",)
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 0  # precomputed frame embeddings fed by the stub frontend
    # recurrent dims
    lru_width: int = 0
    conv_width: int = 4
    # misc
    act: str = "silu"
    rms_norm: bool = True
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # training-time policy knobs (overridable per run / hillclimb)
    remat: str = "full"  # none | dots | full
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if attention cost doesn't grow quadratically without bound
        (pure-recurrent or bounded local window) → runs long_500k."""
        kinds = set(self.block_pattern)
        if "attn" not in kinds:
            return True
        return self.local_window > 0

    def pattern_for_layers(self) -> tuple:
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate total parameter count (embedding + blocks)."""
        d, hd = self.d_model, self.hd
        n = self.vocab_size * d  # embed (+ tied unembed)
        if not self.tie_embeddings:
            n += self.vocab_size * d
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        dense_mlp = 3 * d * self.d_ff if self.act == "silu" else 2 * d * self.d_ff
        moe_mlp = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
        for kind in self.pattern_for_layers():
            if kind == "attn":
                n += attn
                n += moe_mlp if self.is_moe else dense_mlp
            elif kind == "rglru":
                w = self.lru_width or d
                n += 2 * d * w + w * d + 3 * w * (w // max(self.n_heads, 1)) + self.conv_width * w
                n += dense_mlp
            elif kind == "mlstm":
                di = 2 * d
                n += d * 2 * di + 3 * di * di + 2 * di + di * d + self.conv_width * di
            elif kind == "slstm":
                dh = d
                n += 4 * d * dh + 4 * dh * (dh // max(self.n_heads, 1))
                n += 2 * d * int(d * 4 / 3)
        if self.is_encoder_decoder:
            # encoder blocks: self-attn + mlp; decoder adds cross-attn
            enc = (attn + dense_mlp) * self.n_enc_layers
            cross = (4 * d * self.n_heads * hd) * self.n_layers
            n += enc + cross
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts instead of all)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        moe_total = self.n_layers * self.n_experts * 3 * d * self.moe_d_ff
        moe_active = self.n_layers * self.top_k * 3 * d * self.moe_d_ff
        return full - moe_total + moe_active


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


# The four assigned LM shape cells.
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "qwen3-moe-235b-a22b",
    "granite-moe-3b-a800m",
    "xlstm-125m",
    "whisper-tiny",
    "smollm-360m",
    "deepseek-coder-33b",
    "llama3-8b",
    "qwen2.5-3b",
    "chameleon-34b",
    "recurrentgemma-2b",
]


def _module_for(arch_id: str):
    mod = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch_id: str) -> ModelConfig:
    return _module_for(arch_id).config()


def get_tiny_config(arch_id: str) -> ModelConfig:
    return _module_for(arch_id).tiny()


def cells(arch_id: str) -> list[tuple[str, str]]:
    """All runnable (arch, shape) cells for an arch; decode/long rules from
    DESIGN.md §7 (long_500k only for sub-quadratic archs)."""
    cfg = get_config(arch_id)
    out = []
    for shape in SHAPES.values():
        if shape.name == "long_500k" and not cfg.sub_quadratic:
            continue  # noted skip: quadratic full attention at 500k
        out.append((arch_id, shape.name))
    return out


def all_cells() -> list[tuple[str, str]]:
    out = []
    for a in ARCH_IDS:
        out.extend(cells(a))
    return out
