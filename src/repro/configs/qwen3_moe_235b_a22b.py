"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]

Qwen3 uses head_dim=128 (decoupled from d_model/n_heads) and QK-norm.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_ff=1536,
        vocab_size=151936,
        head_dim=128,
        n_experts=128,
        top_k=8,
        moe_d_ff=1536,
        qk_norm=True,
        rope_theta=1000000.0,
    )


def tiny() -> ModelConfig:
    return config().replace(
        name="qwen3-moe-tiny",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        moe_d_ff=96,
        n_experts=8,
        top_k=2,
        vocab_size=256,
        scan_layers=False,
        attn_chunk=64,
    )
