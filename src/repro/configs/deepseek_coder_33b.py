"""deepseek-coder-33b [dense] — 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256; llama-arch. [arXiv:2401.14196]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        family="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19200,
        vocab_size=32256,
        rope_theta=100000.0,
    )


def tiny() -> ModelConfig:
    return config().replace(
        name="deepseek-coder-tiny",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        scan_layers=False,
        attn_chunk=64,
    )
