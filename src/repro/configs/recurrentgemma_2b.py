"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000; RG-LRU + local attention in a (rglru, rglru, attn) pattern.
[arXiv:2402.19427]

Local window 2048 + linear recurrence → sub-quadratic → runs long_500k.
Gemma-style head_dim=256 (10 heads x 256 = 2560).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        d_ff=7680,
        vocab_size=256000,
        head_dim=256,
        block_pattern=("rglru", "rglru", "attn"),
        local_window=2048,
        lru_width=2560,
        act="silu",
        tie_embeddings=True,
        scan_layers=False,  # heterogeneous pattern → unrolled with remat
    )


def tiny() -> ModelConfig:
    return config().replace(
        name="recurrentgemma-tiny",
        n_layers=3,
        d_model=64,
        n_heads=2,
        n_kv_heads=1,
        head_dim=32,
        d_ff=128,
        vocab_size=256,
        lru_width=64,
        local_window=32,
        attn_chunk=16,
    )
