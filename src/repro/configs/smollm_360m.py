"""smollm-360m [dense] — 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152; llama-arch small. [hf:HuggingFaceTB/SmolLM-135M]

This family backs the end-to-end training example (examples/train_e2e.py).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        family="dense",
        n_layers=32,
        d_model=960,
        n_heads=15,
        n_kv_heads=5,
        d_ff=2560,
        vocab_size=49152,
        tie_embeddings=True,
    )


def tiny() -> ModelConfig:
    return config().replace(
        name="smollm-tiny",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        scan_layers=False,
        attn_chunk=64,
    )
