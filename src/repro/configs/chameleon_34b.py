"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536; early-fusion, VQ image tokens. [arXiv:2405.09818]

The modality frontend is a STUB: image patches are VQ-quantized into the
shared 65536-token vocab upstream, so input_specs() feeds token ids directly.
Chameleon uses QK-norm for training stability.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab_size=65536,
        qk_norm=True,
    )


def tiny() -> ModelConfig:
    return config().replace(
        name="chameleon-tiny",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        scan_layers=False,
        attn_chunk=64,
    )
