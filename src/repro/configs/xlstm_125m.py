"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304; sLSTM + mLSTM
blocks (alternating), no separate MLP (d_ff=0: xLSTM blocks carry their own
up/down projections). [arXiv:2405.04517]

Pure-recurrent → sub-quadratic → runs the long_500k cell.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        block_pattern=("mlstm", "slstm"),
        use_rope=False,
        tie_embeddings=True,
        scan_layers=False,  # heterogeneous pattern → unrolled with remat
    )


def tiny() -> ModelConfig:
    return config().replace(
        name="xlstm-tiny",
        n_layers=2,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        vocab_size=256,
    )
