"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]

The paper's own organization (IBM) — the natural "paper's technique" MoE.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        n_experts=40,
        top_k=8,
        moe_d_ff=512,
        tie_embeddings=True,
    )


def tiny() -> ModelConfig:
    return config().replace(
        name="granite-moe-tiny",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        moe_d_ff=64,
        n_experts=5,
        top_k=2,
        vocab_size=256,
        scan_layers=False,
        attn_chunk=64,
    )
