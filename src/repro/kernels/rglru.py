"""Pallas TPU kernel for the RG-LRU linear recurrence (RecurrentGemma).

The GPU reference implementation is a fused CUDA scan. On TPU we restructure
(DESIGN.md §2): the recurrence h_t = a_t * h_{t-1} + b_t is elementwise over
the width dim, so the natural TPU decomposition is

  grid = (batch_blocks, width_blocks, time_blocks)

with the time dimension walked sequentially by the LAST grid axis (Pallas
TPU executes the grid in row-major order, so for a fixed (i, j) the t blocks
run in order) carrying h in a VMEM scratch accumulator. Each program
processes a (block_b, block_t, block_w) tile with an in-register scan over
the tile's time steps — pure VPU work, no MXU — and writes the tile's
outputs. HBM traffic is exactly one read of (a, b) and one write of h:
bandwidth-optimal for a memory-bound op.

Width/batch tiles are (8, 128)-lane aligned. Validated against ``ref.py``
in interpret mode (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, hlast_ref, carry_ref, *,
                  block_t):
    """Refs: a/b/o: (block_b, block_t, block_w); h0/hlast: (block_b, block_w);
    carry_ref: VMEM scratch (block_b, block_w) fp32 persisting across the
    sequential time-block walk."""
    t_idx = pl.program_id(2)

    @pl.when(t_idx == 0)
    def _init():
        carry_ref[...] = h0_ref[...].astype(jnp.float32)

    h = carry_ref[...]
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)

    def step(t, h):
        h = a[:, t, :] * h + b[:, t, :]
        o_ref[:, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h)
    carry_ref[...] = h

    num_t = pl.num_programs(2)

    @pl.when(t_idx == num_t - 1)
    def _finish():
        hlast_ref[...] = h.astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_t", "block_w",
                                             "interpret"))
def rglru_scan_tpu(a, b, h0=None, *, block_b=8, block_t=256, block_w=128,
                   interpret=False):
    """Linear recurrence h_t = a_t*h_{t-1} + b_t over axis 1.

    a, b: (B, S, W); h0: (B, W) fp32 or None. Returns (h (B,S,W) in b.dtype,
    h_last (B, W) fp32).
    """
    bsz, s, w = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, w), jnp.float32)
    block_b = min(block_b, bsz)
    block_t = min(block_t, s)
    block_w = min(block_w, w)
    if bsz % block_b or s % block_t or w % block_w:
        raise ValueError(f"dims must divide blocks: {(bsz, s, w)} vs "
                         f"{(block_b, block_t, block_w)}")
    grid = (bsz // block_b, w // block_w, s // block_t)
    kernel = functools.partial(_rglru_kernel, block_t=block_t)
    h, hlast = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_t, block_w),
                         lambda i, j, t: (i, t, j)),
            pl.BlockSpec((block_b, block_t, block_w),
                         lambda i, j, t: (i, t, j)),
            pl.BlockSpec((block_b, block_w), lambda i, j, t: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, block_t, block_w),
                         lambda i, j, t: (i, t, j)),
            pl.BlockSpec((block_b, block_w), lambda i, j, t: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, w), b.dtype),
            jax.ShapeDtypeStruct((bsz, w), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_b, block_w), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return h, hlast
