"""Pallas TPU flash-attention (GQA, causal / local-window / bidirectional).

TPU-native design (DESIGN.md §5):
  * grid = (batch*kv_heads, q_blocks); each program owns one (B*KV, q_block)
    tile and walks kv blocks with ``jax.lax.fori_loop`` carrying the online-
    softmax state in registers/VMEM — HBM traffic is O(S*block) not O(S^2).
  * Block shapes are MXU-aligned: q/kv block sizes are multiples of 128 in
    the sequence dims and head_dim is padded by the caller to a multiple of
    128 (the q @ k^T and p @ v contractions then map onto 128x128 systolic
    passes).
  * Causality is exploited structurally: the kv walk stops at the q block's
    diagonal (lower-triangle blocks only, ~2x savings); a local window also
    bounds the walk from below (RecurrentGemma's 2048-window attention).
  * fp32 accumulation for scores/normalizer (exp in fp32), bf16 tensors.

Grouped-query attention is handled by folding the q-head group into the
q-block rows: a (kv_head, group, q_block) tile attends against that kv
head's single k/v block — no k/v duplication in VMEM.

Validated against ``ref.py`` (pure-jnp oracle) in interpret mode on CPU
(tests/test_kernels.py sweeps shapes/dtypes/window/causality).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, seq_kv,
                  causal, window, scale, q_offset):
    """One (bkv, q_block) program: walk kv blocks, online softmax.

    Refs (VMEM blocks):
      q_ref: (block_q, head_dim)   — this program's query rows
      k_ref: (seq_kv, head_dim)    — full K for this (batch, kv_head)
      v_ref: (seq_kv, head_dim)    — full V
      o_ref: (block_q, head_dim)
    """
    qi = pl.program_id(1)
    head_dim = q_ref.shape[-1]
    q = q_ref[...].astype(jnp.float32) * scale

    q_start = qi * block_q + q_offset  # absolute position of q row 0

    n_kv_blocks = seq_kv // block_k
    if causal:
        # last kv block that any q row in this tile can see
        hi = jax.lax.div(q_start + block_q - 1, block_k) + 1
        hi = jnp.minimum(hi, n_kv_blocks)
    else:
        hi = n_kv_blocks
    if window > 0:
        lo = jnp.maximum((q_start - window) // block_k, 0)
    else:
        lo = 0

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok &= q_pos >= k_pos
        if window > 0:
            ok &= (q_pos - k_pos) < window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, head_dim), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "q_offset",
                     "interpret"))
def flash_attention_tpu(q, k, v, *, causal=True, window=0, block_q=128,
                        block_k=128, q_offset=0, interpret=False):
    """q: (B, H, Sq, D); k/v: (B, KV, Skv, D); H % KV == 0.

    Returns (B, H, Sq, D) in q.dtype. On CPU call with interpret=True.
    """
    b, h, sq, d = q.shape
    n_kv, skv = k.shape[1], k.shape[2]
    assert h % n_kv == 0
    group = h // n_kv
    scale = d ** -0.5

    block_q = min(block_q, sq * group)
    block_k = min(block_k, skv)
    # fold (group, seq) into q rows so one kv head serves its whole q group
    qg = q.reshape(b, n_kv, group, sq, d)

    if (sq * group) % block_q or skv % block_k:
        raise ValueError(f"seq dims must divide blocks: {(sq, group, block_q, skv, block_k)}")

    if group == 1:
        grid = (b * n_kv, sq // block_q)
        kernel = functools.partial(
            _flash_kernel, block_q=block_q, block_k=block_k, seq_kv=skv,
            causal=causal, window=window, scale=scale, q_offset=q_offset)
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
                pl.BlockSpec((None, skv, d), lambda i, j: (i, 0, 0)),
                pl.BlockSpec((None, skv, d), lambda i, j: (i, 0, 0)),
            ],
            out_specs=pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
            out_shape=jax.ShapeDtypeStruct((b * n_kv, sq, d), q.dtype),
            interpret=interpret,
        )(qg.reshape(b * n_kv, sq, d),
          k.reshape(b * n_kv, skv, d), v.reshape(b * n_kv, skv, d))
        return out.reshape(b, h, sq, d)

    # Grouped-query: vmap the single-group kernel over the group dim — each
    # group member attends the same kv head, so k/v blocks are shared (no
    # duplication in VMEM; pallas adds the vmap dim to the grid).
    fn = functools.partial(flash_attention_tpu, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           q_offset=q_offset, interpret=interpret)
    out = jax.vmap(lambda qg_: fn(qg_, k, v), in_axes=2, out_axes=2)(qg)
    return out.reshape(b, h, sq, d)
