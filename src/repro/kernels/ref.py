"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """O(S^2)-memory reference GQA attention.

    q: (B, H, Sq, D); k/v: (B, KV, Skv, D). fp32 softmax, output in q.dtype.
    """
    b, h, sq, d = q.shape
    n_kv, skv = k.shape[1], k.shape[2]
    group = h // n_kv
    qg = q.reshape(b, n_kv, group, sq, d).astype(jnp.float32) * (d ** -0.5)
    s = jnp.einsum("bkgsd,bkcd->bkgsc", qg, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(skv)
    if causal:
        s = jnp.where((q_pos[:, None] >= k_pos[None, :])[None, None, None],
                      s, -1e30)
    if window > 0:
        s = jnp.where((q_pos[:, None] - k_pos[None, :] < window)
                      [None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgsc,bkcd->bkgsd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)


def rglru_scan_ref(a, b, h0=None):
    """Step-by-step linear recurrence h_t = a_t * h_{t-1} + b_t.

    a, b: (B, S, W). Returns (h (B,S,W) in b.dtype, h_last (B,W) fp32).
    """
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    h = jnp.zeros_like(bf[:, 0]) if h0 is None else h0.astype(jnp.float32)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    h_last, hs = jax.lax.scan(step, h, (af.swapaxes(0, 1), bf.swapaxes(0, 1)))
    return hs.swapaxes(0, 1).astype(b.dtype), h_last
