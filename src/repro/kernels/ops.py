"""Dispatching wrappers: Pallas on TPU, chunked-jnp equivalent elsewhere.

The model code calls these; on a TPU runtime the Pallas kernels execute, on
CPU (tests, dry-run) the structurally-equivalent jnp paths run (same math,
same memory behavior class), with ``force`` overrides for kernel tests in
interpret mode.
"""

from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.rglru import rglru_scan_tpu


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    force: str | None = None):
    """GQA flash attention. force in {None, 'pallas', 'interpret', 'ref'}."""
    mode = force or ("pallas" if _on_tpu() else "jnp")
    if mode == "pallas":
        return flash_attention_tpu(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)
    if mode == "interpret":
        return flash_attention_tpu(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, interpret=True)
    if mode == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       q_offset=q_offset)
    # memory-efficient jnp path (the dry-run lowers this)
    from repro.nn.attention import flash_attention as chunked
    return chunked(q, k, v, causal=causal, window=window, q_offset=q_offset)


def rglru_scan(a, b, h0=None, *, force: str | None = None):
    """Linear recurrence. force in {None, 'pallas', 'interpret', 'ref'}."""
    mode = force or ("pallas" if _on_tpu() else "jnp")
    if mode == "pallas":
        return rglru_scan_tpu(a, b, h0)
    if mode == "interpret":
        return rglru_scan_tpu(a, b, h0, interpret=True)
    if mode == "ref":
        return ref.rglru_scan_ref(a, b, h0)
    # associative-scan jnp path (log-depth, what the dry-run lowers)
    import jax.numpy as jnp

    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    if h0 is not None:
        bf = bf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (af, bf), axis=1)
    return h.astype(b.dtype), h[:, -1]
