from repro.parallel.sharding import (
    MeshEnv,
    current_env,
    logical_to_spec,
    null_env,
    param_shardings,
    resolve_spec,
    shard,
    use_env,
)

__all__ = [
    "MeshEnv",
    "current_env",
    "logical_to_spec",
    "null_env",
    "param_shardings",
    "resolve_spec",
    "shard",
    "use_env",
]
