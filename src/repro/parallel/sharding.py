"""Logical-axis sharding: the single place where parallelism policy lives.

Every parameter and activation in the model code is annotated with *logical*
axis names ("batch", "embed", "heads", "mlp", "experts", ...). A
:class:`MeshEnv` maps logical names onto physical mesh axes via a rules table.
Model code never mentions physical axes, so the same model runs:

  * unsharded on one CPU device (tests / smoke),
  * on a single-pod (data, model) mesh,
  * on the multi-pod (pod, data, model) production mesh,

purely by swapping rules. This mirrors t5x/maxtext logical-axis design and is
what lets the dry-run sweep meshes without touching model code.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# A logical rule maps a logical axis name to one mesh axis, a tuple of mesh
# axes (sharded over their product), or None (replicated).
MeshAxes = Union[None, str, tuple]

# Baseline rules for a (data, model) single-pod mesh.
SINGLE_POD_RULES: dict[str, MeshAxes] = {
    "batch": ("data",),
    "batch_attn": ("data",),  # attention-block batch (batch-TP override
                              # reshards attention over data x model when
                              # heads %% TP != 0 would replicate compute)
    "seq": None,            # residual-stream sequence axis (SP shards this)
    "attn_seq": None,       # attention-internal q seq (never SP-sharded:
                            # the blocked kv walk needs whole sequences)
    "kv_seq": None,         # kv-cache sequence axis
    "embed": None,
    "residual": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qkv": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "lru": "model",
    "conv": None,
    "layers": None,
    "enc_seq": None,
    "zero": None,           # extra axis ZeRO-1 adds to optimizer state
}

# Production multi-pod rules: the pod axis joins the data axis for DP.
MULTI_POD_RULES: dict[str, MeshAxes] = dict(
    SINGLE_POD_RULES,
    batch=("pod", "data"),
    batch_attn=("pod", "data"),
)


def zero1_rules(rules: dict[str, MeshAxes]) -> dict[str, MeshAxes]:
    """Rules with the ZeRO-1 axis bound to the DP axes (optimizer sharding)."""
    return dict(rules, zero=rules["batch"])


@dataclass(frozen=True)
class MeshEnv:
    """A mesh plus the logical→physical rules to use inside it."""

    mesh: Optional[Mesh]
    rules: dict[str, MeshAxes] = field(default_factory=dict)

    @property
    def active(self) -> bool:
        return self.mesh is not None

    def axis_size(self, name: str) -> int:
        assert self.mesh is not None
        return self.mesh.shape[name]


def null_env() -> MeshEnv:
    """Environment with no mesh: all sharding helpers become no-ops."""
    return MeshEnv(mesh=None, rules={})


class _EnvStack(threading.local):
    def __init__(self):
        self.stack: list[MeshEnv] = []


_ENVS = _EnvStack()


def current_env() -> MeshEnv:
    if _ENVS.stack:
        return _ENVS.stack[-1]
    return null_env()


@contextlib.contextmanager
def use_env(env: MeshEnv):
    """Install a MeshEnv (and enter its mesh) for the dynamic extent."""
    _ENVS.stack.append(env)
    try:
        if env.mesh is not None:
            # newer jax: jax.set_mesh(mesh); older jax: the Mesh object is
            # itself the context manager
            cm = (jax.set_mesh(env.mesh) if hasattr(jax, "set_mesh")
                  else env.mesh)
            with cm:
                yield env
        else:
            yield env
    finally:
        _ENVS.stack.pop()


def _mesh_axes_tuple(mesh_axes: MeshAxes) -> tuple:
    if mesh_axes is None:
        return ()
    if isinstance(mesh_axes, str):
        return (mesh_axes,)
    return tuple(mesh_axes)


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    env: Optional[MeshEnv] = None,
    shape: Optional[Sequence[int]] = None,
) -> P:
    """Resolve logical axis names to a PartitionSpec under env's rules.

    A mesh axis may appear at most once in a PartitionSpec; later (lower
    priority) occurrences are dropped. If ``shape`` is given, mesh axes whose
    size does not divide the corresponding dim are dropped too (e.g. kv_heads=4
    cannot shard over model=16 — it stays replicated rather than erroring).
    """
    env = env or current_env()
    if not env.active:
        return P()
    used: set[str] = set()
    out = []
    for i, name in enumerate(logical_axes):
        mesh_axes = _mesh_axes_tuple(env.rules.get(name)) if name else ()
        picked = []
        size = 1
        for ax in mesh_axes:
            if ax in used or ax not in env.mesh.shape:
                continue
            picked.append(ax)
            size *= env.axis_size(ax)
        if shape is not None and picked and shape[i] % size != 0:
            # Try progressively shorter prefixes of the axis tuple.
            while picked:
                picked.pop()
                size = 1
                for ax in picked:
                    size *= env.axis_size(ax)
                if size == 1 or shape[i] % size == 0:
                    break
        used.update(picked)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(tuple(picked))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x, *logical_axes: Optional[str]):
    """with_sharding_constraint under the current env (no-op when unset)."""
    env = current_env()
    if not env.active:
        return x
    spec = logical_to_spec(logical_axes, env, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(env.mesh, spec))


def resolve_spec(axes_leaf, shape, env: Optional[MeshEnv] = None) -> P:
    """PartitionSpec for one parameter given its logical axes and shape."""
    return logical_to_spec(axes_leaf, env=env, shape=shape)


def param_shardings(axes_tree, shapes_tree, env: Optional[MeshEnv] = None):
    """NamedShardings for a parameter tree.

    ``axes_tree`` has the same structure as the params with tuples of logical
    names at the leaves; ``shapes_tree`` carries arrays/ShapeDtypeStructs.
    """
    env = env or current_env()
    if not env.active:
        return jax.tree.map(
            lambda _: None, shapes_tree, is_leaf=lambda l: hasattr(l, "shape")
        )

    def one(axes, arr):
        spec = resolve_spec(tuple(axes), arr.shape, env)
        return NamedSharding(env.mesh, spec)

    return jax.tree.map(
        one, axes_tree, shapes_tree, is_leaf=lambda l: isinstance(l, tuple)
    )
