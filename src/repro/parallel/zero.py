"""ZeRO-1: shard optimizer state (Adam m/v + fp32 master) over the DP axes.

With pure DP, optimizer state is replicated — 12 fp32 bytes/param/device. At
llama3-8b on a 512-chip mesh that replication wastes ~96 GB/device-group;
ZeRO-1 cuts it by the DP degree. We insert the DP mesh axes into the first
dimension of each leaf that (a) is not already sharded there and (b) is
divisible — falling back to later dims, else leaving the leaf alone (tiny
scales/biases don't matter).

The parameter update then runs on DP-sharded optimizer state; XLA inserts
reduce-scatter for the gradient → sharded-update → all-gather of new params,
i.e. the canonical ZeRO-1 schedule emerges from sharding propagation.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.optim.adamw import OptState
from repro.parallel.sharding import MeshEnv, resolve_spec


def _dp_axes(env: MeshEnv) -> tuple:
    axes = env.rules.get("batch") or ()
    if isinstance(axes, str):
        axes = (axes,)
    return tuple(a for a in axes if a in env.mesh.shape)


def zero1_spec(param_spec: P, shape, env: MeshEnv) -> P:
    """Insert the DP axes into the first divisible, DP-free dimension."""
    dp = _dp_axes(env)
    if not dp:
        return param_spec
    dp_size = 1
    for a in dp:
        dp_size *= env.axis_size(a)
    entries = list(param_spec) + [None] * (len(shape) - len(param_spec))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a:
                used.add(a)
    if any(a in used for a in dp):
        return param_spec  # already DP-sharded somehow
    for i, e in enumerate(entries):
        cur = tuple(a for a in (e if isinstance(e, tuple) else (e,)) if a)
        cur_size = 1
        for a in cur:
            cur_size *= env.axis_size(a)
        if shape[i] % (cur_size * dp_size) == 0:
            entries[i] = cur + dp if cur else (dp if len(dp) > 1 else dp[0])
            while entries and entries[-1] is None:
                entries.pop()
            return P(*entries)
    return param_spec


def opt_state_shardings(axes_tree, abstract_params, env: MeshEnv) -> OptState:
    """NamedShardings for OptState(m, v, master) with the ZeRO-1 axis."""
    def one(axes, arr):
        base = resolve_spec(tuple(axes), arr.shape, env)
        spec = zero1_spec(base, arr.shape, env)
        return NamedSharding(env.mesh, spec)

    tree = jax.tree.map(one, axes_tree, abstract_params,
                        is_leaf=lambda l: isinstance(l, tuple))
    return OptState(m=tree, v=tree, master=tree)
