"""AdamW with mixed-precision master weights, global-norm clipping and
schedules. Built from scratch (no optax): the optimizer state layout
(fp32 master + m + v, all shardable with an extra ZeRO axis) is part of the
distribution design, so we own it.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    # decay only matrices (dims >= 2), standard practice
    decay_vectors: bool = False


class OptState(NamedTuple):
    m: dict
    v: dict
    master: dict  # fp32 master copy of params


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_frac, as fp32 scalar."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # copy=True: fp32 params would otherwise alias their master buffer,
    # breaking donation (same buffer donated twice).
    master = jax.tree.map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return OptState(m=zeros, v=jax.tree.map(jnp.copy, zeros), master=master)


def abstract_state(params) -> OptState:
    f32 = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    return OptState(m=f32, v=f32, master=f32)


def global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(l.astype(jnp.float32)))
        for l in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: OptState, step, param_dtype):
    """One AdamW step. grads in any dtype; math in fp32 on master weights.

    Returns (new_params (cast to param_dtype), new_state, metrics).
    """
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)
    lr = schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - jnp.power(cfg.beta1, t)
    bc2 = 1.0 - jnp.power(cfg.beta2, t)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay > 0:
            decay = cfg.weight_decay if (w.ndim >= 2 or cfg.decay_vectors) else 0.0
            step_ = step_ + decay * w
        w = w - lr * step_
        return m, v, w

    zipped = jax.tree.map(upd, grads, state.m, state.v, state.master)
    is_triplet = lambda x: isinstance(x, tuple) and len(x) == 3 and not isinstance(x[0], tuple)
    m = jax.tree.map(lambda x: x[0], zipped, is_leaf=is_triplet)
    v = jax.tree.map(lambda x: x[1], zipped, is_leaf=is_triplet)
    master = jax.tree.map(lambda x: x[2], zipped, is_leaf=is_triplet)
    # Cast back to each param's storage dtype (norm scales stay fp32).
    new_params = jax.tree.map(lambda w, g: w.astype(g.dtype), master, grads)
    del param_dtype
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(m, v, master), metrics
