"""Matmul precision policy (hillclimb lever, EXPERIMENTS.md §Perf it.2).

Default (baseline): interior einsums request fp32 outputs
(``preferred_element_type=f32``) — numerically safest, but it materializes
fp32 intermediates and makes every backward dot f32-wide.

``bf16_interior``: interior matmuls emit bf16 (the TPU MXU accumulates in
fp32 internally either way); fp32 is kept where it matters — logits/unembed,
softmax/normalizer internals, RMS norms, router, recurrence coefficients.
"""

from __future__ import annotations

import contextlib
import threading

import jax.numpy as jnp


class _Policy(threading.local):
    def __init__(self):
        self.bf16_interior = False


_P = _Policy()


def interior_pref():
    """preferred_element_type for interior matmuls (None = input dtype)."""
    return None if _P.bf16_interior else jnp.float32


def cast_interior(x, like_dtype):
    """Cast an einsum output to the residual dtype (no-op under bf16)."""
    return x.astype(like_dtype)


@contextlib.contextmanager
def bf16_interior(enabled: bool = True):
    old = _P.bf16_interior
    _P.bf16_interior = enabled
    try:
        yield
    finally:
        _P.bf16_interior = old
