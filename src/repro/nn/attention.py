"""GQA attention: train/prefill (blocked-causal flash), decode (KV cache),
local-window (RecurrentGemma), bidirectional (encoder) and cross attention.

The blocked-causal implementation mirrors the structure of the Pallas flash
kernel in ``repro.kernels.flash_attention`` (same block decomposition, online
softmax) so that the CPU dry-run lowers an HLO whose FLOP/byte profile is
representative of the TPU kernel: only lower-triangle (q_block, kv_block)
pairs are computed, giving ~2x FLOP savings over naive causal attention and
O(S·C) live memory instead of O(S^2).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.nn import params as prm
from repro.nn.layers import apply_rope, def_headnorm, headnorm
from repro.nn.policy import interior_pref
from repro.parallel import shard

NEG_INF = -1e30


def def_gqa(d_model, n_heads, n_kv_heads, head_dim, qkv_bias=False, qk_norm=False):
    d = {
        "wq": prm.ParamDef((d_model, n_heads, head_dim), ("embed", "heads", "head_dim"),
                           init="scaled_fan_in"),
        "wk": prm.ParamDef((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim"),
                           init="scaled_fan_in"),
        "wv": prm.ParamDef((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim"),
                           init="scaled_fan_in"),
        "wo": prm.ParamDef((n_heads, head_dim, d_model), ("heads", "head_dim", "embed"),
                           init="scaled_fan_in"),
    }
    if qkv_bias:
        d["bq"] = prm.ParamDef((n_heads, head_dim), ("heads", "head_dim"), init="zeros")
        d["bk"] = prm.ParamDef((n_kv_heads, head_dim), ("kv_heads", "head_dim"), init="zeros")
        d["bv"] = prm.ParamDef((n_kv_heads, head_dim), ("kv_heads", "head_dim"), init="zeros")
    if qk_norm:
        d["q_norm"] = def_headnorm(head_dim)
        d["k_norm"] = def_headnorm(head_dim)
    return d


class KVCache(NamedTuple):
    k: jax.Array  # (B, n_kv, S_max, head_dim)
    v: jax.Array  # (B, n_kv, S_max, head_dim)


def _project_qkv(p, x, positions, rope_theta, use_rope=True):
    """x: (B, S, d) → q (B, H, S, hd), k/v (B, KV, S, hd)."""
    pref = interior_pref()
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"], preferred_element_type=pref)
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"], preferred_element_type=pref)
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"], preferred_element_type=pref)
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)[None, :, None, :]
        k = k + p["bk"].astype(k.dtype)[None, :, None, :]
        v = v + p["bv"].astype(v.dtype)[None, :, None, :]
    q, k, v = q.astype(x.dtype), k.astype(x.dtype), v.astype(x.dtype)
    if "q_norm" in p:
        q = headnorm(p["q_norm"], q)
        k = headnorm(p["k_norm"], k)
    if use_rope:
        q = apply_rope(q, positions[:, None, :], rope_theta)
        k = apply_rope(k, positions[:, None, :], rope_theta)
    q = shard(q, "batch_attn", "heads", "attn_seq", "head_dim")
    k = shard(k, "batch_attn", "kv_heads", "attn_seq", "head_dim")
    v = shard(v, "batch_attn", "kv_heads", "attn_seq", "head_dim")
    return q, k, v


def _group_q(q, n_kv):
    """(B, H, S, D) → (B, KV, G, S, D) grouping query heads per kv head."""
    b, h, s, d = q.shape
    return q.reshape(b, n_kv, h // n_kv, s, d)


def _flash_block(q, k, v, m, l, o, mask):
    """One online-softmax accumulation step.

    q: (B, KV, G, Sq, D); k/v: (B, KV, C, D); mask: broadcastable (Sq, C) or None.
    m/l: (B, KV, G, Sq); o: (B, KV, G, Sq, D); all fp32 accumulators.
    """
    s = jnp.einsum("bkgsd,bkcd->bkgsc", q, k, preferred_element_type=jnp.float32)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    alpha = jnp.exp(m - m_new)
    p_ = jnp.exp(s - m_new[..., None])
    l_new = l * alpha + jnp.sum(p_, axis=-1)
    pv = jnp.einsum("bkgsc,bkcd->bkgsd", p_.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    o_new = o * alpha[..., None] + pv
    return m_new, l_new, o_new


def _finish(m, l, o, dtype):
    return (o / jnp.maximum(l, 1e-30)[..., None]).astype(dtype)


def _pick_chunk(s: int, want: int) -> int:
    """Largest divisor of s that is <= want (handles seqs like 1500)."""
    c = min(want, s)
    while s % c:
        c -= 1
    return c


def flash_attention(q, k, v, *, causal=True, window=0, chunk=512,
                    q_offset=0):
    """Blocked flash attention over (B, H, S, D) q and (B, KV, Skv, D) k/v.

    ``window > 0`` restricts each query to the last ``window`` keys (local
    attention). ``q_offset`` is the absolute position of q[0] relative to
    k[0] (used when q is a suffix of the kv sequence).
    Returns (B, H, S, D) in q.dtype.
    """
    b, h, sq, d = q.shape
    n_kv = k.shape[1]
    skv = k.shape[2]
    scale = d ** -0.5
    qg = _group_q((q * scale).astype(q.dtype), n_kv)

    cq = _pick_chunk(sq, chunk)
    ck = _pick_chunk(skv, chunk)
    n_qc, n_kc = sq // cq, skv // ck

    outs = []
    for i in range(n_qc):
        qi = jax.lax.dynamic_slice_in_dim(qg, i * cq, cq, axis=3)
        q_pos = q_offset + i * cq + jnp.arange(cq)
        # Static kv-block range for this q block: causal upper bound and
        # local-window lower bound (both resolved at trace time).
        hi = n_kc if not causal else min(n_kc, (q_offset + (i + 1) * cq + ck - 1) // ck)
        lo = 0
        if window > 0:
            lo = max(0, (q_offset + i * cq - window) // ck)
        m = jnp.full((b, n_kv, h // n_kv, cq), NEG_INF, jnp.float32)
        l = jnp.zeros((b, n_kv, h // n_kv, cq), jnp.float32)
        o = jnp.zeros((b, n_kv, h // n_kv, cq, d), jnp.float32)

        def body(carry, j):
            m, l, o = carry
            kj = jax.lax.dynamic_slice_in_dim(k, j * ck, ck, axis=2)
            vj = jax.lax.dynamic_slice_in_dim(v, j * ck, ck, axis=2)
            k_pos = j * ck + jnp.arange(ck)
            mask = None
            if causal or window > 0:
                ok = jnp.ones((cq, ck), bool)
                if causal:
                    ok &= q_pos[:, None] >= k_pos[None, :]
                if window > 0:
                    ok &= q_pos[:, None] - k_pos[None, :] < window
                mask = ok[None, None, None]
            m, l, o = _flash_block(qi, kj, vj, m, l, o, mask)
            return (m, l, o), None

        (m, l, o), _ = jax.lax.scan(body, (m, l, o), jnp.arange(lo, hi))
        outs.append(_finish(m, l, o, q.dtype).reshape(b, h, cq, d))
    return jnp.concatenate(outs, axis=2) if len(outs) > 1 else outs[0]


def naive_attention(q, k, v, *, causal=True, window=0, q_offset=0):
    """Reference O(S^2)-memory attention (oracle for tests)."""
    b, h, sq, d = q.shape
    n_kv = k.shape[1]
    skv = k.shape[2]
    qg = _group_q(q, n_kv) * (d ** -0.5)
    s = jnp.einsum("bkgsd,bkcd->bkgsc", qg, k, preferred_element_type=jnp.float32)
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(skv)
    if causal:
        s = jnp.where((q_pos[:, None] >= k_pos[None, :])[None, None, None], s, NEG_INF)
    if window > 0:
        s = jnp.where((q_pos[:, None] - k_pos[None, :] < window)[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgsc,bkcd->bkgsd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, h, sq, d).astype(q.dtype)


def decode_attention(q, cache: KVCache, cache_len, *, window=0):
    """Single-step attention against a KV cache.

    q: (B, H, 1, D); cache.k/v: (B, KV, S_max, D); cache_len: () int32 —
    number of valid cache entries (the new token's k/v must already be
    written at cache_len - 1).
    """
    b, h, _, d = q.shape
    n_kv = cache.k.shape[1]
    s_max = cache.k.shape[2]
    qg = _group_q(q * (d ** -0.5), n_kv)
    # Scores einsum reads the cache in ITS dtype (bf16): requesting an f32
    # output here makes XLA upcast the entire multi-GB cache (§Perf llama3
    # decode it.8). Softmax runs in f32 on the small scores tensor; the MXU
    # accumulates dots in f32 internally regardless.
    s = jnp.einsum("bkgsd,bkcd->bkgsc", qg, cache.k)  # (B,KV,G,1,S_max)
    s = s.astype(jnp.float32)
    pos = jnp.arange(s_max)
    valid = pos < cache_len
    if window > 0:
        valid &= pos >= cache_len - window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgsc,bkcd->bkgsd", p.astype(cache.v.dtype), cache.v)
    return o.reshape(b, h, 1, d).astype(q.dtype)


def gqa_attention(
    p,
    x,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    positions,
    rope_theta: float = 10000.0,
    use_rope: bool = True,
    causal: bool = True,
    window: int = 0,
    chunk: int = 512,
    impl: str = "flash",
    cache: Optional[KVCache] = None,
    cache_len=None,
    mode: str = "train",  # train | prefill | decode
):
    """Full GQA attention block. Returns (y, new_cache_or_None)."""
    del n_heads  # implied by param shapes
    q, k, v = _project_qkv(p, x, positions, rope_theta, use_rope)
    new_cache = None
    if mode == "decode":
        assert cache is not None and cache_len is not None
        # Write this step's k/v at position cache_len, then attend over
        # cache_len+1 valid entries.
        k_new = jax.lax.dynamic_update_slice_in_dim(cache.k, k, cache_len, axis=2)
        v_new = jax.lax.dynamic_update_slice_in_dim(cache.v, v, cache_len, axis=2)
        new_cache = KVCache(k_new, v_new)
        o = decode_attention(q, new_cache, cache_len + 1, window=window)
    else:
        if impl == "flash":
            o = flash_attention(q, k, v, causal=causal, window=window, chunk=chunk)
        else:
            o = naive_attention(q, k, v, causal=causal, window=window)
        if mode == "prefill":
            new_cache = KVCache(k, v)
    o = shard(o, "batch_attn", "heads", "attn_seq", "head_dim")
    y = jnp.einsum("bhsk,hkd->bsd", o, p["wo"],
                   preferred_element_type=interior_pref())
    return y.astype(x.dtype), new_cache


# --------------------------------------------------------------------------
# Cross attention (whisper decoder → encoder memory)
# --------------------------------------------------------------------------

def def_cross_attention(d_model, n_heads, head_dim):
    return {
        "wq": prm.ParamDef((d_model, n_heads, head_dim), ("embed", "heads", "head_dim"),
                           init="scaled_fan_in"),
        "wk": prm.ParamDef((d_model, n_heads, head_dim), ("embed", "kv_heads", "head_dim"),
                           init="scaled_fan_in"),
        "wv": prm.ParamDef((d_model, n_heads, head_dim), ("embed", "kv_heads", "head_dim"),
                           init="scaled_fan_in"),
        "wo": prm.ParamDef((n_heads, head_dim, d_model), ("heads", "head_dim", "embed"),
                           init="scaled_fan_in"),
    }


def cross_attention(p, x, memory=None, mem_kv=None):
    """x: (B, S, d) queries; memory: (B, S_enc, d) or precomputed mem_kv."""
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"], preferred_element_type=jnp.float32).astype(x.dtype)
    if mem_kv is None:
        k = jnp.einsum("bsd,dhk->bhsk", memory, p["wk"], preferred_element_type=jnp.float32).astype(x.dtype)
        v = jnp.einsum("bsd,dhk->bhsk", memory, p["wv"], preferred_element_type=jnp.float32).astype(x.dtype)
    else:
        k, v = mem_kv
    o = naive_attention(q, k, v, causal=False)
    y = jnp.einsum("bhsk,hkd->bsd", o, p["wo"], preferred_element_type=jnp.float32)
    return y.astype(x.dtype), (k, v)
