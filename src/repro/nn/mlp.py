"""Dense MLP blocks: SwiGLU (llama family) and GELU (whisper)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.nn import params as prm
from repro.nn.layers import activation
from repro.nn.policy import interior_pref
from repro.parallel import shard


def def_mlp(d_model, d_ff, act="silu", use_bias=False):
    gated = act in ("silu",)
    d = {
        "up": prm.matrix(d_model, d_ff, "embed", "mlp"),
        "down": prm.matrix(d_ff, d_model, "mlp", "embed"),
    }
    if gated:
        d["gate"] = prm.matrix(d_model, d_ff, "embed", "mlp")
    if use_bias:
        d["up_b"] = prm.bias(d_ff, "mlp")
        d["down_b"] = prm.bias(d_model, "embed")
    return d


def mlp(p, x, act="silu"):
    fn = activation(act)
    up = jnp.einsum("...d,df->...f", x, p["up"],
                    preferred_element_type=interior_pref())
    if "up_b" in p:
        up = up + p["up_b"].astype(up.dtype)
    if "gate" in p:
        gate = jnp.einsum("...d,df->...f", x, p["gate"],
                          preferred_element_type=interior_pref())
        h = fn(gate.astype(jnp.float32)) * up.astype(jnp.float32)
    else:
        h = fn(up.astype(jnp.float32))
    h = shard(h.astype(x.dtype), "batch", "seq", "mlp")
    y = jnp.einsum("...f,fd->...d", h, p["down"],
                   preferred_element_type=interior_pref())
    if "down_b" in p:
        y = y + p["down_b"].astype(y.dtype)
    return y.astype(x.dtype)
