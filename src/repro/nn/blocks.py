"""Per-layer blocks and the layer-stack machinery.

Block kinds (``cfg.block_pattern`` entries):
  * ``attn``  — GQA attention (+ dense MLP or MoE FFN)
  * ``rglru`` — Griffin recurrent block (+ dense MLP)
  * ``mlstm`` — xLSTM matrix-LSTM block (self-contained up/down projections)
  * ``slstm`` — xLSTM scalar-LSTM block (self-contained gated FFN)

``stack_*`` drives a homogeneous stack through ``lax.scan`` over stacked
params (compile-time O(1) in depth — essential for the 94-layer MoE) or an
unrolled loop for heterogeneous patterns; both honor the remat policy.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn import params as prm
from repro.nn.attention import KVCache, def_gqa, gqa_attention
from repro.nn.layers import def_norm, norm
from repro.nn.mlp import def_mlp, mlp
from repro.nn.moe import def_moe, moe_ffn
from repro.nn.policy import interior_pref
from repro.nn.recurrent import (
    MLSTMState,
    SLSTMState,
    blockdiag,
    causal_conv,
    causal_conv_step,
    conv_state_init,
    def_blockdiag,
    def_causal_conv,
    def_rglru,
    def_slstm_core,
    mlstm_chunkwise,
    mlstm_state_init,
    mlstm_step,
    rglru,
    rglru_step,
    slstm_scan,
    slstm_state_init,
    slstm_step,
)
from repro.parallel import shard


# --------------------------------------------------------------------------
# defs
# --------------------------------------------------------------------------

def def_attn_block(cfg: ModelConfig):
    d = {
        "norm1": def_norm(cfg.d_model, cfg.rms_norm),
        "attn": def_gqa(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                        cfg.qkv_bias, cfg.qk_norm),
        "norm2": def_norm(cfg.d_model, cfg.rms_norm),
    }
    if cfg.is_moe:
        d["moe"] = def_moe(cfg.d_model, cfg.n_experts, cfg.moe_d_ff, cfg.top_k)
    else:
        d["mlp"] = def_mlp(cfg.d_model, cfg.d_ff, cfg.act)
    return d


def def_rglru_block(cfg: ModelConfig):
    w = cfg.lru_width or cfg.d_model
    return {
        "norm1": def_norm(cfg.d_model, cfg.rms_norm),
        "w_gate": prm.matrix(cfg.d_model, w, "embed", "lru"),
        "w_x": prm.matrix(cfg.d_model, w, "embed", "lru"),
        "conv": def_causal_conv(cfg.conv_width, w),
        "lru": def_rglru(w, cfg.n_heads),
        "w_out": prm.matrix(w, cfg.d_model, "lru", "embed"),
        "norm2": def_norm(cfg.d_model, cfg.rms_norm),
        "mlp": def_mlp(cfg.d_model, cfg.d_ff, cfg.act),
    }


def def_mlstm_block(cfg: ModelConfig):
    d, nh = cfg.d_model, cfg.n_heads
    di = 2 * d
    return {
        "norm": def_norm(d, cfg.rms_norm),
        "wu": prm.matrix(d, di, "embed", "lru"),
        "wg": prm.matrix(d, di, "embed", "lru"),
        "conv": def_causal_conv(cfg.conv_width, di),
        "wq": prm.matrix(di, di, "lru", None),
        "wk": prm.matrix(di, di, "lru", None),
        "wv": prm.matrix(di, di, "lru", None),
        "wi": prm.matrix(di, nh, "lru", "heads"),
        "bi": prm.bias(nh, "heads"),
        "wf": prm.matrix(di, nh, "lru", "heads"),
        "bf": prm.bias(nh, "heads"),
        "out_norm": prm.ParamDef((di,), ("lru",), init="ones", dtype="float32"),
        "wo": prm.matrix(di, d, "lru", "embed"),
    }


def def_slstm_block(cfg: ModelConfig):
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    ffw = max(1, round(cfg.d_model * 4 / 3))
    return {
        "norm": def_norm(d, cfg.rms_norm),
        "conv": def_causal_conv(cfg.conv_width, d),
        "wi": prm.matrix(d, d, "embed", "lru"),
        "wf": prm.matrix(d, d, "embed", "lru"),
        "wz": prm.matrix(d, d, "embed", "lru"),
        "wo_g": prm.matrix(d, d, "embed", "lru"),
        "r": def_slstm_core(nh, dh),
        "out_norm": prm.ParamDef((d,), ("lru",), init="ones", dtype="float32"),
        "ffn": def_mlp(cfg.d_model, ffw, "silu"),
    }


_DEFS = {
    "attn": def_attn_block,
    "rglru": def_rglru_block,
    "mlstm": def_mlstm_block,
    "slstm": def_slstm_block,
}


def def_block(cfg: ModelConfig, kind: str):
    return _DEFS[kind](cfg)


# --------------------------------------------------------------------------
# per-head group norm used by xLSTM outputs
# --------------------------------------------------------------------------

def _group_rms(scale, x, n_heads, eps=1e-6):
    """x: (B, S, D) normalized per head-group of D/n_heads channels."""
    b, s, dd = x.shape
    xh = x.reshape(b, s, n_heads, dd // n_heads).astype(jnp.float32)
    var = jnp.mean(xh * xh, axis=-1, keepdims=True)
    y = (xh * jax.lax.rsqrt(var + eps)).reshape(b, s, dd) * scale
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# state init (decode)
# --------------------------------------------------------------------------

def init_block_state(cfg: ModelConfig, kind: str, batch: int, s_max: int,
                     dtype=jnp.bfloat16, compact: bool = False):
    if kind == "attn":
        window = cfg.local_window
        # compact=True bounds a local-attention cache at the window (used by
        # the dry-run to size long_500k honestly); executed serving keeps
        # the full allocation so linear cache_len indexing stays valid.
        s_alloc = min(s_max, window + 1) if (window and compact) else s_max
        return KVCache(
            k=jnp.zeros((batch, cfg.n_kv_heads, s_alloc, cfg.hd), dtype),
            v=jnp.zeros((batch, cfg.n_kv_heads, s_alloc, cfg.hd), dtype),
        )
    if kind == "rglru":
        w = cfg.lru_width or cfg.d_model
        return {
            "conv": conv_state_init(batch, cfg.conv_width, w, dtype),
            "h": jnp.zeros((batch, w), jnp.float32),
        }
    if kind == "mlstm":
        d, nh = cfg.d_model, cfg.n_heads
        di = 2 * d
        return {
            "conv": conv_state_init(batch, cfg.conv_width, di, dtype),
            "state": mlstm_state_init(batch, nh, di // nh, di // nh),
        }
    if kind == "slstm":
        d, nh = cfg.d_model, cfg.n_heads
        return {
            "conv": conv_state_init(batch, cfg.conv_width, d, dtype),
            "state": slstm_state_init(batch, nh, d // nh),
        }
    raise ValueError(kind)


# --------------------------------------------------------------------------
# block apply — mode in {train, prefill, decode}
# --------------------------------------------------------------------------

def apply_attn_block(p, x, cfg: ModelConfig, *, positions, mode="train",
                     state=None, cache_len=None):
    window = cfg.local_window
    h = norm(p["norm1"], x, cfg.rms_norm)
    attn_out, new_cache = gqa_attention(
        p["attn"], h,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        positions=positions, rope_theta=cfg.rope_theta, use_rope=cfg.use_rope,
        causal=True, window=window, chunk=cfg.attn_chunk,
        cache=state, cache_len=cache_len, mode=mode,
    )
    x = x + attn_out
    x = shard(x, "batch", "seq", "embed")
    h = norm(p["norm2"], x, cfg.rms_norm)
    if cfg.is_moe:
        ffn_out, aux = moe_ffn(p["moe"], h, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor, act=cfg.act)
    else:
        ffn_out, aux = mlp(p["mlp"], h, cfg.act), jnp.zeros((), jnp.float32)
    x = x + ffn_out
    x = shard(x, "batch", "seq", "embed")
    return x, new_cache, aux


def apply_rglru_block(p, x, cfg: ModelConfig, *, mode="train", state=None):
    w = cfg.lru_width or cfg.d_model
    del w
    h = norm(p["norm1"], x, cfg.rms_norm)
    gate = jax.nn.gelu(jnp.einsum(
        "bsd,dw->bsw", h, p["w_gate"],
        preferred_element_type=interior_pref()).astype(jnp.float32)
    ).astype(x.dtype)
    u = jnp.einsum("bsd,dw->bsw", h, p["w_x"],
                   preferred_element_type=interior_pref()).astype(x.dtype)
    new_state = None
    if mode == "decode":
        u1, conv_state = causal_conv_step(p["conv"], u[:, 0], state["conv"])
        r, h_new = rglru_step(p["lru"], u1, state["h"], cfg.n_heads)
        r = r[:, None]
        new_state = {"conv": conv_state, "h": h_new}
    else:
        u_raw = u
        u = causal_conv(p["conv"], u)
        u = shard(u, "batch", "seq", "lru")
        r, h_last = rglru(p["lru"], u, cfg.n_heads,
                          h0=state["h"] if state is not None else None)
        if mode == "prefill":
            width = p["conv"]["w"].shape[0]
            conv_state = jax.lax.dynamic_slice_in_dim(
                u_raw, u_raw.shape[1] - (width - 1), width - 1, axis=1)
            new_state = {"conv": conv_state, "h": h_last}
    y = jnp.einsum("bsw,wd->bsd", (r * gate).astype(x.dtype), p["w_out"],
                   preferred_element_type=interior_pref()).astype(x.dtype)
    x = x + y
    x = shard(x, "batch", "seq", "embed")
    x = x + mlp(p["mlp"], norm(p["norm2"], x, cfg.rms_norm), cfg.act)
    x = shard(x, "batch", "seq", "embed")
    return x, new_state, jnp.zeros((), jnp.float32)


def apply_mlstm_block(p, x, cfg: ModelConfig, *, mode="train", state=None):
    d, nh = cfg.d_model, cfg.n_heads
    di = 2 * d
    dh = di // nh
    h = norm(p["norm"], x, cfg.rms_norm)
    u = jnp.einsum("bsd,de->bse", h, p["wu"],
                   preferred_element_type=interior_pref()).astype(x.dtype)
    g = jnp.einsum("bsd,de->bse", h, p["wg"],
                   preferred_element_type=interior_pref()).astype(x.dtype)
    if mode == "decode":
        c, conv_state = causal_conv_step(p["conv"], u[:, 0], state["conv"])
        c = jax.nn.silu(c.astype(jnp.float32)).astype(x.dtype)
        q = (c @ p["wq"]).reshape(-1, nh, dh)
        k = (c @ p["wk"]).reshape(-1, nh, dh)
        v = (u[:, 0] @ p["wv"]).reshape(-1, nh, dh)
        ig = (c @ p["wi"] + p["bi"]).astype(jnp.float32)
        fg = (c @ p["wf"] + p["bf"] + 3.0).astype(jnp.float32)
        hout, mstate = mlstm_step(q, k, v, ig, fg, state["state"])
        hout = hout.reshape(-1, 1, di)
        new_state = {"conv": conv_state, "state": mstate}
    else:
        c = causal_conv(p["conv"], u)
        c = jax.nn.silu(c.astype(jnp.float32)).astype(x.dtype)
        b, s, _ = c.shape
        q = (c @ p["wq"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
        k = (c @ p["wk"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
        v = (u @ p["wv"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
        ig = (c @ p["wi"] + p["bi"]).astype(jnp.float32).transpose(0, 2, 1)
        fg = (c @ p["wf"] + p["bf"] + 3.0).astype(jnp.float32).transpose(0, 2, 1)
        hout, mstate = mlstm_chunkwise(q, k, v, ig, fg,
                                       state["state"] if state else None,
                                       chunk=min(cfg.attn_chunk, s))
        hout = hout.transpose(0, 2, 1, 3).reshape(b, s, di)
        new_state = None
        if mode == "prefill":
            width = p["conv"]["w"].shape[0]
            conv_state = jax.lax.dynamic_slice_in_dim(u, s - (width - 1), width - 1, 1)
            new_state = {"conv": conv_state, "state": mstate}
    hout = _group_rms(p["out_norm"], hout, nh)
    y = ((hout * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)) @ p["wo"])
    x = x + y.astype(x.dtype)
    x = shard(x, "batch", "seq", "embed")
    return x, new_state, jnp.zeros((), jnp.float32)


def apply_slstm_block(p, x, cfg: ModelConfig, *, mode="train", state=None):
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    h = norm(p["norm"], x, cfg.rms_norm)
    if mode == "decode":
        c, conv_state = causal_conv_step(p["conv"], h[:, 0], state["conv"])
        c = jax.nn.silu(c.astype(jnp.float32)).astype(x.dtype)
        gates = {
            "i": (c @ p["wi"]).reshape(-1, nh, dh),
            "f": (c @ p["wf"]).reshape(-1, nh, dh),
            "z": (h[:, 0] @ p["wz"]).reshape(-1, nh, dh),
            "o": (h[:, 0] @ p["wo_g"]).reshape(-1, nh, dh),
        }
        hout, sstate = slstm_step(p["r"], gates, state["state"])
        hout = hout.reshape(-1, 1, d).astype(x.dtype)
        new_state = {"conv": conv_state, "state": sstate}
    else:
        b, s, _ = h.shape
        c = causal_conv(p["conv"], h)
        c = jax.nn.silu(c.astype(jnp.float32)).astype(x.dtype)
        gates = {
            "i": (c @ p["wi"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3),
            "f": (c @ p["wf"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3),
            "z": (h @ p["wz"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3),
            "o": (h @ p["wo_g"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3),
        }
        hout, sstate = slstm_scan(p["r"], gates,
                                  state["state"] if state else None)
        hout = hout.transpose(0, 2, 1, 3).reshape(b, s, d).astype(x.dtype)
        new_state = None
        if mode == "prefill":
            width = p["conv"]["w"].shape[0]
            conv_state = jax.lax.dynamic_slice_in_dim(h, s - (width - 1), width - 1, 1)
            new_state = {"conv": conv_state, "state": sstate}
    hout = _group_rms(p["out_norm"], hout, nh)
    x = x + hout
    x = x + mlp(p["ffn"], x, "silu")
    x = shard(x, "batch", "seq", "embed")
    return x, new_state, jnp.zeros((), jnp.float32)


def apply_block(p, x, cfg: ModelConfig, kind: str, *, positions=None,
                mode="train", state=None, cache_len=None):
    if kind == "attn":
        return apply_attn_block(p, x, cfg, positions=positions, mode=mode,
                                state=state, cache_len=cache_len)
    if kind == "rglru":
        return apply_rglru_block(p, x, cfg, mode=mode, state=state)
    if kind == "mlstm":
        return apply_mlstm_block(p, x, cfg, mode=mode, state=state)
    if kind == "slstm":
        return apply_slstm_block(p, x, cfg, mode=mode, state=state)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# layer stack
# --------------------------------------------------------------------------

def _stackable(cfg: ModelConfig) -> bool:
    return cfg.scan_layers and len(set(cfg.block_pattern)) == 1 \
        and cfg.block_pattern[0] == "attn"


def def_stack(cfg: ModelConfig):
    """Def-tree for the full stack of decoder blocks."""
    if _stackable(cfg):
        one = def_block(cfg, "attn")

        def add_layer_axis(d: prm.ParamDef) -> prm.ParamDef:
            return prm.ParamDef((cfg.n_layers,) + tuple(d.shape),
                                ("layers",) + tuple(d.axes),
                                init=d.init, scale=d.scale, dtype=d.dtype)

        return {"scan": jax.tree.map(add_layer_axis, one, is_leaf=prm.is_def)}
    pattern = cfg.pattern_for_layers()
    return {"layers": [def_block(cfg, k) for k in pattern]}


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


def stack_apply(p, x, cfg: ModelConfig, *, positions=None, mode="train",
                states=None, cache_len=None):
    """Run all decoder blocks. Returns (x, new_states, total_aux).

    ``states`` is a list (unrolled) or stacked pytree (scan) of block states,
    or None for train mode.
    """
    if _stackable(cfg):
        def body(carry, xs):
            h, aux = carry
            layer_p, layer_state = xs if mode == "decode" else (xs, None)
            h, new_state, a = apply_attn_block(
                layer_p, h, cfg, positions=positions, mode=mode,
                state=layer_state, cache_len=cache_len)
            return (h, aux + a), new_state

        body = _remat_wrap(body, cfg) if mode == "train" else body
        xs = (p["scan"], states) if mode == "decode" else p["scan"]
        (x, aux), new_states = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), xs)
        if mode == "train":
            new_states = None
        return x, new_states, aux

    aux = jnp.zeros((), jnp.float32)
    pattern = cfg.pattern_for_layers()
    new_states = []
    for i, kind in enumerate(pattern):
        st = states[i] if states is not None else None

        def one(h, layer_p, st=st, kind=kind):
            return apply_block(layer_p, h, cfg, kind, positions=positions,
                               mode=mode, state=st, cache_len=cache_len)

        if mode == "train":
            one = _remat_wrap(one, cfg)
        x, ns, a = one(x, p["layers"][i])
        new_states.append(ns)
        aux = aux + a
    return x, new_states if states is not None or mode == "prefill" else None, aux


def init_stack_state(cfg: ModelConfig, batch: int, s_max: int,
                     dtype=jnp.bfloat16, compact: bool = False):
    """Decode-time state for the whole stack (stacked for scan models)."""
    if _stackable(cfg):
        one = init_block_state(cfg, "attn", batch, s_max, dtype, compact)
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (cfg.n_layers,) + l.shape), one)
    return [init_block_state(cfg, k, batch, s_max, dtype, compact)
            for k in cfg.pattern_for_layers()]


# --------------------------------------------------------------------------
# logical axes of decode state (for dry-run sharding of KV caches etc.)
# --------------------------------------------------------------------------

def block_state_axes(cfg: ModelConfig, kind: str):
    if kind == "attn":
        kv = ("batch", "kv_heads", "kv_seq", "head_dim")
        return KVCache(k=kv, v=kv)
    if kind == "rglru":
        return {"conv": ("batch", None, "lru"), "h": ("batch", "lru")}
    if kind == "mlstm":
        return {
            "conv": ("batch", None, "lru"),
            "state": MLSTMState(c=("batch", "heads", None, None),
                                n=("batch", "heads", None),
                                m=("batch", "heads")),
        }
    if kind == "slstm":
        return {
            "conv": ("batch", None, "lru"),
            "state": SLSTMState(c=("batch", "heads", None),
                                n=("batch", "heads", None),
                                m=("batch", "heads", None),
                                h=("batch", "heads", None)),
        }
    raise ValueError(kind)


def stack_state_axes(cfg: ModelConfig):
    if _stackable(cfg):
        one = block_state_axes(cfg, "attn")
        return jax.tree.map(lambda a: ("layers",) + a, one,
                            is_leaf=lambda l: isinstance(l, tuple) and
                            all(isinstance(x, (str, type(None))) for x in l))
    return [block_state_axes(cfg, k) for k in cfg.pattern_for_layers()]
