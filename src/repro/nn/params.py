"""Parameter definition & materialization.

Layers declare parameters as trees of :class:`ParamDef` (shape + logical axes
+ initializer). Generic code turns a def-tree into:

  * a concrete parameter tree (``materialize`` — pure & traceable, so
    ``jax.eval_shape`` gives abstract params for the dry-run without ever
    allocating 235B-parameter models), and
  * a logical-axes tree (``axes_of`` — consumed by parallel.param_shardings).

This is the no-flax substrate the whole model stack is built on.
"""

from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.utils.trees import tree_map_with_path


@dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple  # logical axis names, same length as shape (None allowed)
    init: str = "normal"  # normal | zeros | ones | scaled_fan_in | truncated
    scale: Optional[float] = None
    dtype: Optional[str] = None  # override model dtype (e.g. fp32 norms)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: tuple) -> int:
    # For (in, out) matrices fan-in is dim 0; for stacked expert weights
    # (experts, in, out) it's dim 1; vectors have fan-in 1.
    if len(shape) >= 2:
        return shape[-2]
    return 1


def _init_leaf(key, d: ParamDef, default_dtype) -> jax.Array:
    dtype = jnp.dtype(d.dtype) if d.dtype else default_dtype
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "normal":
        scale = d.scale if d.scale is not None else 0.02
        return (scale * jax.random.normal(key, d.shape, jnp.float32)).astype(dtype)
    if d.init == "scaled_fan_in":
        scale = d.scale if d.scale is not None else 1.0
        std = scale / math.sqrt(max(_fan_in(d.shape), 1))
        return (std * jax.random.normal(key, d.shape, jnp.float32)).astype(dtype)
    raise ValueError(f"unknown init {d.init}")


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def materialize(key: jax.Array, defs, dtype=jnp.bfloat16):
    """Turn a ParamDef tree into a parameter tree. Pure; eval_shape-able.

    Each leaf gets an independent key derived by folding the leaf path's hash
    into ``key`` so parameter values do not depend on tree iteration order.
    """

    def build(path: str, d: ParamDef):
        # zlib.crc32, not hash(): Python salts str hashes per process, which
        # would make init non-deterministic across restarts.
        leaf_key = jax.random.fold_in(key, zlib.crc32(path.encode()) % (2**31))
        return _init_leaf(leaf_key, d, dtype)

    return tree_map_with_path(build, defs)


def abstract(defs, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree for a ParamDef tree (no allocation)."""
    return jax.eval_shape(lambda: materialize(jax.random.key(0), defs, dtype))


def axes_of(defs):
    """Logical-axes tree (leaves = tuples) mirroring the params tree."""
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=is_def)


# --- tiny declaration helpers used throughout repro.nn -------------------


def matrix(d_in: int, d_out: int, ax_in: str, ax_out: str, **kw) -> ParamDef:
    return ParamDef((d_in, d_out), (ax_in, ax_out), init="scaled_fan_in", **kw)


def bias(d: int, ax: str, **kw) -> ParamDef:
    return ParamDef((d,), (ax,), init="zeros", **kw)


def norm_scale(d: int, ax: str = "embed") -> ParamDef:
    # Norm scales stay fp32 for numerical robustness (maxtext convention).
    return ParamDef((d,), (ax,), init="ones", dtype="float32")


def embedding(vocab: int, d: int) -> ParamDef:
    return ParamDef((vocab, d), ("vocab", "embed"), init="normal", scale=0.02)
