"""Mixture-of-Experts FFN with top-k routing.

Two execution paths, same math:

* ``moe_ffn_local`` — single-device reference: local sort-based dispatch into
  per-expert capacity buffers + ``jax.lax.ragged_dot`` grouped matmul. FLOPs
  scale with *active* (routed) tokens, never with ``n_experts x tokens``.
* ``moe_ffn`` — distributed: the local path wrapped in ``jax.shard_map`` over
  the DP mesh axes with experts sharded over the ``model`` axis (expert
  parallelism). Each model shard gathers only the rows routed to *its*
  experts (token activations are replicated across the model axis at MoE
  block entry, so no all-to-all is needed); per-shard contributions are
  combined with a single ``psum`` over ``model`` — the same collective cost
  as a Megatron TP MLP. Dispatch uses a *local* sort per DP shard, avoiding
  GSPMD's cross-device bitonic sort entirely.

Token dropping follows GShard/Switch capacity semantics: per-expert capacity
C = ceil(T_local * top_k / n_experts * capacity_factor); overflow rows are
dropped (contribute zero, weight renormalization optional off).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import params as prm
from repro.nn.layers import activation
from repro.parallel import current_env


def def_moe(d_model, n_experts, moe_d_ff, top_k, act="silu"):
    del top_k, act
    return {
        "router": prm.matrix(d_model, n_experts, "embed", "experts",
                             dtype="float32"),
        "up": prm.ParamDef((n_experts, d_model, moe_d_ff),
                           ("experts", "embed", "expert_mlp"), init="scaled_fan_in"),
        "gate": prm.ParamDef((n_experts, d_model, moe_d_ff),
                             ("experts", "embed", "expert_mlp"), init="scaled_fan_in"),
        "down": prm.ParamDef((n_experts, moe_d_ff, d_model),
                             ("experts", "expert_mlp", "embed"), init="scaled_fan_in"),
    }


def capacity(t_local: int, top_k: int, n_experts: int, factor: float,
             min_capacity: int = 4) -> int:
    c = math.ceil(t_local * top_k / n_experts * factor)
    return max(min(max(c, min_capacity), t_local * top_k), 1)


def router_topk(p_router, x, top_k: int):
    """x: (T, d) → weights (T, k) fp32 (softmax over the selected k),
    indices (T, k) int32, plus load-balancing aux loss (Switch-style)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p_router)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # aux loss: n_experts * mean(frac_tokens_e * mean_prob_e)
    n_experts = logits.shape[-1]
    hard = jax.nn.one_hot(idx[:, 0], n_experts, dtype=jnp.float32)
    aux = n_experts * jnp.mean(jnp.mean(hard, axis=0) * jnp.mean(probs, axis=0))
    return w, idx, aux


def _dispatch_indices(idx, n_experts: int, cap: int, e_start, e_local: int):
    """Build the gather map for experts [e_start, e_start + e_local).

    idx: (T, k) expert assignment. Returns:
      src:  (e_local * cap,) int32 — source row in the flattened (T*k) stream
            (T*k means "empty slot"),
      sizes: (e_local,) int32 — valid rows per local expert (<= cap).
    """
    t, k = idx.shape
    flat = idx.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat, stable=True)  # rows grouped by expert
    sorted_e = flat[order]
    # Position of each sorted row within its expert group.
    counts = jnp.bincount(flat, length=n_experts)  # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[sorted_e]
    # Keep only local experts and rows under capacity.
    local_e = sorted_e - e_start
    keep = (local_e >= 0) & (local_e < e_local) & (pos_in_e < cap)
    dest = jnp.where(keep, local_e * cap + pos_in_e, e_local * cap)
    src = jnp.full((e_local * cap + 1,), t * k, jnp.int32)
    src = src.at[dest].set(order.astype(jnp.int32), mode="drop")[:-1]
    local_counts = jax.lax.dynamic_slice_in_dim(counts, e_start, e_local)
    sizes = jnp.minimum(local_counts, cap).astype(jnp.int32)
    return src, sizes


def _expert_ffn(up, gate, down, rows, sizes, act="silu", impl="einsum"):
    """Grouped expert FFN over capacity buffers.

    rows: (E_local*C, d) grouped by expert (fixed capacity C per expert);
    sizes: (E_local,) valid rows per expert (only used by the ragged path).

    impl="einsum" (default): reshape to (E_local, C, d) and run batched
    einsums — flops = E_local*C*d*f = active_tokens*capacity_factor, the
    GShard/megablox-equivalent dense-buffer formulation (MXU-native tiles,
    no dynamic shapes). impl="ragged": jax.lax.ragged_dot — equivalent math,
    but decomposes into a dense per-expert loop on non-TPU backends (kept
    for comparison; see EXPERIMENTS.md §Perf iteration 1).
    """
    fn = activation(act)
    if impl == "ragged":
        h_up = jax.lax.ragged_dot(rows, up, sizes)
        h_gate = jax.lax.ragged_dot(rows, gate, sizes)
        h = (fn(h_gate.astype(jnp.float32)) * h_up.astype(jnp.float32)
             ).astype(rows.dtype)
        return jax.lax.ragged_dot(h, down, sizes)
    e_local = up.shape[0]
    buf = rows.reshape(e_local, -1, rows.shape[-1])  # (E_local, C, d)
    h_up = jnp.einsum("ecd,edf->ecf", buf, up)
    h_gate = jnp.einsum("ecd,edf->ecf", buf, gate)
    h = (fn(h_gate.astype(jnp.float32)) * h_up.astype(jnp.float32)
         ).astype(rows.dtype)
    out = jnp.einsum("ecf,efd->ecd", h, down)
    return out.reshape(rows.shape[0], -1)


def moe_ffn_local(p, x, *, top_k: int, capacity_factor: float = 1.25,
                  act: str = "silu", e_start=0, e_local: Optional[int] = None):
    """MoE FFN on local rows for experts [e_start, e_start+e_local).

    x: (T, d). Returns (y (T, d), aux_loss ()). Caller combines shards.
    """
    t, d = x.shape
    n_experts = p["router"].shape[-1]
    e_local = n_experts if e_local is None else e_local
    w, idx, aux = router_topk(p["router"], x, top_k)
    cap = capacity(t, top_k, n_experts, capacity_factor)
    src, sizes = _dispatch_indices(idx, n_experts, cap, e_start, e_local)
    # Gather rows (empty slots read row 0 but are zero-weighted on combine).
    safe_src = jnp.minimum(src, t * top_k - 1)
    rows = x[safe_src // top_k]  # (e_local*cap, d)
    out_rows = _expert_ffn(p["up"], p["gate"], p["down"], rows, sizes, act)
    # Combine: scatter-add weighted expert outputs back to token rows.
    w_flat = w.reshape(-1)  # (T*k,)
    row_w = jnp.where(src < t * top_k, w_flat[safe_src], 0.0)  # (e_local*cap,)
    contrib = out_rows.astype(jnp.float32) * row_w[:, None]
    y = jnp.zeros((t + 1, d), jnp.float32)
    y = y.at[jnp.where(src < t * top_k, safe_src // top_k, t)].add(contrib,
                                                                   mode="drop")
    return y[:t].astype(x.dtype), aux


def moe_ffn(p, x, *, top_k: int, capacity_factor: float = 1.25, act: str = "silu"):
    """Distributed MoE FFN. x: (B, S, d) → (B, S, d), aux ().

    When no mesh env is active, falls back to the local path.
    """
    env = current_env()
    b, s, d = x.shape

    if not env.active:
        y, aux = moe_ffn_local(p, x.reshape(-1, d), top_k=top_k,
                               capacity_factor=capacity_factor, act=act)
        return y.reshape(b, s, d), aux

    mesh = env.mesh
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    model_ax = "model"
    n_model = mesh.shape[model_ax]
    n_experts = p["router"].shape[-1]
    # Experts shard over model when divisible (expert parallelism). When not
    # (granite: 40 experts on a 16-way axis), fall back to TOKEN-parallel
    # MoE: sequence sharded over the model axis, experts replicated — every
    # shard routes/computes only its own tokens, no collectives inside the
    # block at all (EXPERIMENTS.md §Perf granite it.7).
    ep = n_model if n_experts % n_model == 0 else 1
    token_parallel = ep == 1 and s % n_model == 0
    e_local = n_experts // ep

    if token_parallel:
        in_specs = (
            {"router": P(), "up": P(), "gate": P(), "down": P()},
            P(dp_axes, model_ax, None),
        )
        out_specs = (P(dp_axes, model_ax, None), P())

        def tp_fn(p_loc, x_loc):
            bl, sl, dl = x_loc.shape
            y, aux = moe_ffn_local(p_loc, x_loc.reshape(-1, dl), top_k=top_k,
                                   capacity_factor=capacity_factor, act=act)
            aux = jax.lax.pmean(aux, dp_axes + (model_ax,))
            return y.reshape(bl, sl, dl), aux

        return jax.shard_map(tp_fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)(p, x)

    expert_spec = P(model_ax) if ep > 1 else P()
    in_specs = (
        {
            "router": P(),
            "up": expert_spec,
            "gate": expert_spec,
            "down": expert_spec,
        },
        P(dp_axes, None, None),  # x: batch over DP, replicated over model
    )
    out_specs = (P(dp_axes, None, None), P())

    def shard_fn(p_loc, x_loc):
        bl, sl, dl = x_loc.shape
        m_idx = jax.lax.axis_index(model_ax)
        e_start = (m_idx * e_local) if ep > 1 else 0
        y, aux = moe_ffn_local(p_loc, x_loc.reshape(-1, dl), top_k=top_k,
                               capacity_factor=capacity_factor, act=act,
                               e_start=e_start, e_local=e_local)
        if ep > 1:
            y = jax.lax.psum(y, model_ax)
            aux = jax.lax.pmean(aux, model_ax)
        else:
            # Experts replicated: every model shard computed the same thing.
            y = y / 1.0
        aux = jax.lax.pmean(aux, dp_axes)
        return y.reshape(bl, sl, dl), aux

    y, aux = jax.shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )(p, x)
    return y, aux
