"""Basic layers: linear application, norms, rotary embeddings, activations.

All layers are functional: ``def_*`` builds ParamDef trees, ``apply``-style
functions consume (params, inputs). Matmuls accumulate in fp32 via
``preferred_element_type`` — bf16 params, fp32 accumulation is the TPU MXU
native mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import params as prm
from repro.nn.policy import interior_pref


# --------------------------------------------------------------------------
# Linear / embedding
# --------------------------------------------------------------------------

def def_linear(d_in, d_out, ax_in, ax_out, use_bias=False, scale=None):
    d = {"w": prm.matrix(d_in, d_out, ax_in, ax_out, scale=scale)}
    if use_bias:
        d["b"] = prm.bias(d_out, ax_out)
    return d


def linear(p, x):
    y = jnp.einsum("...i,io->...o", x, p["w"],
                   preferred_element_type=interior_pref())
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y.astype(x.dtype)


def embed_lookup(table, ids):
    return jnp.take(table, ids, axis=0)


def unembed(table, x):
    """Tied unembedding: x @ table.T → logits in fp32."""
    return jnp.einsum("...d,vd->...v", x, table,
                      preferred_element_type=jnp.float32)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def def_rmsnorm(d):
    return {"scale": prm.norm_scale(d)}


def rmsnorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


def def_layernorm(d):
    return {"scale": prm.norm_scale(d), "bias": prm.ParamDef((d,), ("embed",), init="zeros", dtype="float32")}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def def_norm(d, rms=True):
    return def_rmsnorm(d) if rms else def_layernorm(d)


def norm(p, x, rms=True):
    return rmsnorm(p, x) if rms else layernorm(p, x)


# Per-head norm used by qk-norm archs (qwen3, chameleon): normalizes head_dim.
def def_headnorm(head_dim):
    return {"scale": prm.ParamDef((head_dim,), ("head_dim",), init="ones", dtype="float32")}


def headnorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim, theta):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta=10000.0):
    """x: (..., seq, head_dim); positions: (..., seq) int32."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq, d_model, offset=0):
    """Classic transformer sinusoidal table (whisper-style abs positions)."""
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    emb = jnp.zeros((seq, d_model), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(angle))
    emb = emb.at[:, 1::2].set(jnp.cos(angle))
    return emb


# --------------------------------------------------------------------------
# Activations
# --------------------------------------------------------------------------

def activation(name):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "tanh": jnp.tanh,
    }[name]
