"""Recurrent sequence mixers: RG-LRU (RecurrentGemma/Griffin), mLSTM and
sLSTM (xLSTM). All sub-quadratic — these are the archs that run the 500k
long-context shape.

TPU adaptation notes (see DESIGN.md §2): the GPU reference implementations
use custom CUDA scan kernels; here the linear recurrences are expressed as

  * RG-LRU: ``jax.lax.associative_scan`` (log-depth, parallel, MXU-free) for
    train/prefill and an O(1) step for decode;
  * mLSTM: a *chunkwise-parallel* formulation (quadratic inside a chunk via
    masked matmuls — MXU-friendly — linear across chunks via a carried
    (C, n, m) state), the TPU-native analogue of the paper's fused kernel;
  * sLSTM: inherently sequential (recurrent weights R), expressed as
    ``lax.scan`` with per-step block-diagonal matmuls.

Pure-jnp reference oracles for tests live alongside in this module
(``*_ref`` functions, step-by-step scans).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.nn import params as prm


# --------------------------------------------------------------------------
# Causal depthwise conv1d (width w), used by RG-LRU and xLSTM blocks
# --------------------------------------------------------------------------

def def_causal_conv(width, channels):
    return {
        "w": prm.ParamDef((width, channels), ("conv", "lru"), init="scaled_fan_in"),
        "b": prm.bias(channels, "lru"),
    }


def causal_conv(p, x):
    """x: (B, S, C) → same shape; causal depthwise conv, width = p.w.shape[0]."""
    width = p["w"].shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(width):
        xj = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xj.astype(jnp.float32) * p["w"][width - 1 - j].astype(jnp.float32)
    out = out + p["b"].astype(jnp.float32)
    return out.astype(x.dtype)


def causal_conv_step(p, x_t, state):
    """x_t: (B, C); state: (B, width-1, C) past inputs. Returns (y_t, state')."""
    width = p["w"].shape[0]
    window = jnp.concatenate([state, x_t[:, None]], axis=1)  # (B, width, C)
    y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   p["w"].astype(jnp.float32)) + p["b"].astype(jnp.float32)
    return y.astype(x_t.dtype), window[:, 1:]


def conv_state_init(batch, width, channels, dtype):
    return jnp.zeros((batch, width - 1, channels), dtype)


# --------------------------------------------------------------------------
# Block-diagonal linear (Griffin's gate projections; xLSTM recurrent R)
# --------------------------------------------------------------------------

def def_blockdiag(n_blocks, block_w, n_out_per_block=None):
    out_w = n_out_per_block or block_w
    return {
        "w": prm.ParamDef((n_blocks, block_w, out_w), ("heads", "lru", None),
                          init="scaled_fan_in"),
        "b": prm.ParamDef((n_blocks, out_w), ("heads", None), init="zeros"),
    }


def blockdiag(p, x):
    """x: (..., n_blocks, block_w) → (..., n_blocks, out_w).

    Computed in fp32: these are small per-head gate projections, and the CPU
    backend lacks a bf16xbf16→f32 thunk for multi-batch-dim dots.
    """
    y = jnp.einsum("...nb,nbo->...no", x.astype(jnp.float32),
                   p["w"].astype(jnp.float32))
    return (y + p["b"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RG-LRU
# --------------------------------------------------------------------------

_RG_C = 8.0  # Griffin's fixed exponent scale
_LAMBDA_SHIFT = -5.0  # softplus(raw - 5) ≈ 0.0067 → a ≈ 0.95 at r=1


def def_rglru(width, n_heads):
    block_w = width // n_heads
    return {
        "a_gate": def_blockdiag(n_heads, block_w),
        "i_gate": def_blockdiag(n_heads, block_w),
        "lam": prm.ParamDef((width,), ("lru",), init="zeros", dtype="float32"),
    }


def _rglru_coeffs(p, x, n_heads):
    """x: (B, S, W) → log_a (B,S,W) fp32, gated input b (B,S,W) fp32."""
    b_, s, w = x.shape
    xh = x.reshape(b_, s, n_heads, w // n_heads)
    r = jax.nn.sigmoid(blockdiag(p["a_gate"], xh).astype(jnp.float32)).reshape(b_, s, w)
    i = jax.nn.sigmoid(blockdiag(p["i_gate"], xh).astype(jnp.float32)).reshape(b_, s, w)
    log_a = -_RG_C * jax.nn.softplus(p["lam"] + _LAMBDA_SHIFT) * r  # (B,S,W)
    gated_x = i * x.astype(jnp.float32)
    # sqrt(1 - a^2) input normalizer (Griffin eq. 4), computed stably in logs.
    multiplier = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return log_a, multiplier * gated_x


def rglru(p, x, n_heads, h0=None):
    """Parallel RG-LRU over a sequence. x: (B,S,W) → (y (B,S,W), h_last)."""
    log_a, b = _rglru_coeffs(p, x, n_heads)
    a = jnp.exp(log_a)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_c, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(p, x_t, h, n_heads):
    """One decode step. x_t: (B, W); h: (B, W) fp32 state."""
    log_a, b = _rglru_coeffs(p, x_t[:, None], n_heads)
    h_new = jnp.exp(log_a[:, 0]) * h + b[:, 0]
    return h_new.astype(x_t.dtype), h_new


def rglru_ref(p, x, n_heads, h0=None):
    """Step-by-step oracle."""
    log_a, b = _rglru_coeffs(p, x, n_heads)
    h = jnp.zeros_like(x[:, 0], dtype=jnp.float32) if h0 is None else h0

    def step(h, inputs):
        la, bt = inputs
        h = jnp.exp(la) * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h, (log_a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1).astype(x.dtype)


# --------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory) — chunkwise parallel
# --------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    c: jax.Array  # (B, H, dk, dv) stabilized matrix memory C_hat
    n: jax.Array  # (B, H, dk)    stabilized normalizer n_hat
    m: jax.Array  # (B, H)        log stabilizer


def mlstm_state_init(batch, n_heads, dk, dv):
    return MLSTMState(
        c=jnp.zeros((batch, n_heads, dk, dv), jnp.float32),
        n=jnp.zeros((batch, n_heads, dk), jnp.float32),
        m=jnp.full((batch, n_heads), -1e30, jnp.float32),
    )


def mlstm_chunkwise(q, k, v, i_gate, f_gate, state=None, chunk=256):
    """Chunkwise-parallel stabilized mLSTM.

    q,k: (B, H, S, dk); v: (B, H, S, dv); i_gate/f_gate: (B, H, S) raw
    (pre-activation) gates; f uses log-sigmoid, i uses exp with shared
    stabilizer m. Returns (h (B,H,S,dv), final MLSTMState).
    """
    b, hn, s, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        state = mlstm_state_init(b, hn, dk, dv)
    L = min(chunk, s)
    assert s % L == 0
    nc = s // L
    scale = dk ** -0.5

    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))  # (B,H,S)
    logi = i_gate.astype(jnp.float32)

    def rc(x):  # reshape to chunks, chunk axis leading for scan
        return x.reshape(b, hn, nc, L, *x.shape[3:]).transpose(2, 0, 1, 3, *range(4, x.ndim + 1))

    qc, kc, vc = rc(q * scale), rc(k), rc(v)
    lf, li = rc(logf), rc(logi)

    def chunk_step(carry, inp):
        C0, n0, m0 = carry
        qi, ki, vi, lfi, lii = inp  # (B,H,L,*)
        bcum = jnp.cumsum(lfi, axis=-1)  # (B,H,L) inclusive log prod of f
        btot = bcum[..., -1]
        # log weight of intra source t for target j: bcum_j - bcum_t + li_t
        g_src = lii - bcum  # (B,H,L)
        # Stabilizers per target position.
        idx = jnp.arange(L)
        tri = idx[:, None] >= idx[None, :]  # (L, L) causal within chunk
        intra_log = bcum[..., :, None] + g_src[..., None, :]  # (B,H,L,L)
        intra_log = jnp.where(tri, intra_log, -jnp.inf)
        m_intra = jnp.max(intra_log, axis=-1)  # (B,H,L)
        m_inter = bcum + m0[..., None]  # (B,H,L)
        m_j = jnp.maximum(m_inter, m_intra)
        # Intra-chunk attention-style term.
        d_mat = jnp.exp(intra_log - m_j[..., None])  # (B,H,L,L)
        s_qk = jnp.einsum("bhld,bhtd->bhlt", qi.astype(jnp.float32),
                          ki.astype(jnp.float32))
        num_intra = jnp.einsum("bhlt,bhtv->bhlv", s_qk * d_mat,
                               vi.astype(jnp.float32))
        den_intra = jnp.sum(s_qk * d_mat, axis=-1)  # (B,H,L)
        # Inter-chunk term from carried state.
        w_inter = jnp.exp(m_inter - m_j)  # (B,H,L)
        num_inter = jnp.einsum("bhld,bhdv->bhlv", qi.astype(jnp.float32), C0)
        den_inter = jnp.einsum("bhld,bhd->bhl", qi.astype(jnp.float32), n0)
        num = num_inter * w_inter[..., None] + num_intra
        den = den_inter * w_inter + den_intra
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_j))[..., None]
        # State update to end of chunk.
        m_new = jnp.maximum(btot + m0, jnp.max(lii + (btot[..., None] - bcum), axis=-1))
        w_old = jnp.exp(btot + m0 - m_new)  # (B,H)
        w_src = jnp.exp(lii + btot[..., None] - bcum - m_new[..., None])  # (B,H,L)
        C_new = C0 * w_old[..., None, None] + jnp.einsum(
            "bhld,bhlv->bhdv", ki.astype(jnp.float32) * w_src[..., None],
            vi.astype(jnp.float32))
        n_new = n0 * w_old[..., None] + jnp.sum(
            ki.astype(jnp.float32) * w_src[..., None], axis=2)
        return (C_new, n_new, m_new), h

    (c, n, m), hs = jax.lax.scan(chunk_step, tuple(state), (qc, kc, vc, lf, li))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(b, hn, s, dv)
    return h.astype(q.dtype), MLSTMState(c, n, m)


def mlstm_step(q, k, v, i_gate, f_gate, state: MLSTMState):
    """One decode step. q,k: (B,H,dk); v: (B,H,dv); gates (B,H)."""
    dk = q.shape[-1]
    scale = dk ** -0.5
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    logi = i_gate.astype(jnp.float32)
    m_new = jnp.maximum(logf + state.m, logi)
    w_old = jnp.exp(logf + state.m - m_new)
    w_in = jnp.exp(logi - m_new)
    kf = k.astype(jnp.float32) * w_in[..., None]
    c = state.c * w_old[..., None, None] + kf[..., :, None] * v.astype(jnp.float32)[..., None, :]
    n = state.n * w_old[..., None] + kf
    qf = q.astype(jnp.float32) * scale
    num = jnp.einsum("bhd,bhdv->bhv", qf, c)
    den = jnp.einsum("bhd,bhd->bh", qf, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h.astype(q.dtype), MLSTMState(c, n, m_new)


def mlstm_ref(q, k, v, i_gate, f_gate, state=None):
    """Step-by-step oracle for mlstm_chunkwise."""
    b, hn, s, dk = q.shape
    dv = v.shape[-1]
    st = state or mlstm_state_init(b, hn, dk, dv)

    def step(st, inp):
        qt, kt, vt, it, ft = inp
        h, st = mlstm_step(qt, kt, vt, it, ft, st)
        return st, h

    xs = (q.swapaxes(0, 2).swapaxes(1, 2), k.swapaxes(0, 2).swapaxes(1, 2),
          v.swapaxes(0, 2).swapaxes(1, 2), i_gate.transpose(2, 0, 1),
          f_gate.transpose(2, 0, 1))
    st, hs = jax.lax.scan(step, st, xs)
    return hs.transpose(1, 2, 0, 3), st


# --------------------------------------------------------------------------
# sLSTM (xLSTM scalar memory with recurrence) — sequential scan
# --------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: jax.Array  # (B, H, dh)
    n: jax.Array  # (B, H, dh)
    m: jax.Array  # (B, H, dh)
    h: jax.Array  # (B, H, dh) hidden fed back through R


def slstm_state_init(batch, n_heads, dh):
    z = jnp.zeros((batch, n_heads, dh), jnp.float32)
    return SLSTMState(z, z, jnp.full_like(z, -1e30), z)


def def_slstm_core(n_heads, dh):
    # Recurrent block-diagonal weights for the four gates (i, f, z, o).
    return {g: prm.ParamDef((n_heads, dh, dh), ("heads", None, None),
                            init="scaled_fan_in", scale=0.3)
            for g in ("ri", "rf", "rz", "ro")}


def slstm_step(p, x_gates, state: SLSTMState):
    """One step. x_gates: dict of (B,H,dh) pre-activations from the input."""
    hf = state.h
    gi = x_gates["i"].astype(jnp.float32) + jnp.einsum("bhd,hde->bhe", hf, p["ri"].astype(jnp.float32))
    gf = x_gates["f"].astype(jnp.float32) + jnp.einsum("bhd,hde->bhe", hf, p["rf"].astype(jnp.float32))
    gz = x_gates["z"].astype(jnp.float32) + jnp.einsum("bhd,hde->bhe", hf, p["rz"].astype(jnp.float32))
    go = x_gates["o"].astype(jnp.float32) + jnp.einsum("bhd,hde->bhe", hf, p["ro"].astype(jnp.float32))
    logf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(logf + state.m, gi)
    i_p = jnp.exp(gi - m_new)
    f_p = jnp.exp(logf + state.m - m_new)
    c = f_p * state.c + i_p * jnp.tanh(gz)
    n = f_p * state.n + i_p
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
    return h, SLSTMState(c, n, m_new, h)


def slstm_scan(p, x_gates, state=None):
    """x_gates: dict of (B, H, S, dh). Returns (h (B,H,S,dh), final state)."""
    b, hn, s, dh = x_gates["i"].shape
    st = state or slstm_state_init(b, hn, dh)

    def step(st, inp):
        h, st = slstm_step(p, inp, st)
        return st, h

    xs = {k: v.transpose(2, 0, 1, 3) for k, v in x_gates.items()}
    st, hs = jax.lax.scan(step, st, xs)
    return hs.transpose(1, 2, 0, 3).astype(x_gates["i"].dtype), st
