"""Unified fault-injection plane + the gray-failure defense primitives.

FfDL's dependability study (Boag et al. 2018) catalogs the faults that
actually hurt a multi-tenant platform: not clean crashes (those the LB
and the shard liveness flag already mask) but *gray* failures — slow
disks, hung components, flaky object stores. This module provides both
halves of the resilience story:

* **Injection** — :class:`FaultPlane` is a seeded registry of named
  interposition points (:data:`FAULT_POINTS`) threaded through the
  stack (WAL append/flush, object-store get/put, shard tick, per-verb
  gateway dispatch, HTTP transport send/recv, volume provisioning).
  A :class:`FaultPlan` installed on a point deterministically injects
  added latency, one-shot/persistent errors, or a full hang; plans are
  runtime-controllable via the ``/v2/admin/faults`` routes and the
  same registry serves :class:`~repro.core.chaos.ChaosMonkey`'s legacy
  point-failure queries.

* **Defenses** — a thread-local deadline context
  (:func:`deadline_scope` / :func:`remaining` / :func:`deadline_sleep`)
  that bounds every blocking wait on the request path (the gateway
  wraps each v1 verb in a scope; ``RWLock`` bounds its condition
  waits; injected hangs and sleeps observe the ambient deadline), and
  a per-shard circuit breaker (:class:`BreakerPolicy`, pure and
  property-testable like the operator policy, fronted by the
  thread-safe :class:`ShardBreaker`) that quarantines a wedged-but-
  alive shard the way a dead one is quarantined.

Core must stay importable without the API tier, so the deadline error
here is a plain exception (:class:`DeadlineExceeded`); the gateway
translates it to the wire-stable ``DEADLINE_EXCEEDED`` ApiError.
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

# The pinned interposition-point registry. Sites pass one of these names
# (plans may also use a trailing-`*` wildcard, e.g. ``objstore.*``).
FAULT_POINTS = (
    "wal.append",         # MetaStore._append — every durable mutation
    "wal.flush",          # MetaStore._commit — group-commit flush
    "objstore.get",       # ObjectStore.get — checkpoint/dataset reads
    "objstore.put",       # ObjectStore.put — checkpoint/result writes
    "shard.tick",         # FfDLPlatform.tick — the shard's control loop
    "gateway.dispatch",   # ApiGateway per-verb dispatch (key = verb name)
    "http.send",          # HttpTransport request send
    "http.recv",          # HttpTransport response read
    "volume.provision",   # guardian volume staging (ChaosMonkey compat)
)

FAULT_MODES = ("persistent", "one_shot")

# Safety valve: an injected hang whose plan is never cleared releases
# after this long so an un-cleared plan cannot wedge a test run forever.
MAX_HANG_S = 30.0


class DeadlineExceeded(Exception):
    """A blocking wait outlived the ambient deadline budget."""


class FaultInjected(RuntimeError):
    """Default error raised by an error-mode plan when the interposition
    site does not supply its own exception factory."""


# -- thread-local deadline context ---------------------------------------

_TLS = threading.local()


class _DeadlineScope:
    """Context manager installing a deadline ``budget_s`` from now on the
    current thread. Nested scopes never *extend* the outer deadline."""

    def __init__(self, budget_s: float):
        self._budget_s = budget_s
        self._prev: Optional[float] = None

    def __enter__(self):
        deadline = time.monotonic() + self._budget_s
        self._prev = getattr(_TLS, "deadline", None)
        if self._prev is not None:
            deadline = min(self._prev, deadline)
        _TLS.deadline = deadline
        return self

    def __exit__(self, *exc):
        _TLS.deadline = self._prev
        return False


def deadline_scope(budget_s: float) -> _DeadlineScope:
    """Bound every deadline-aware wait on this thread to ``budget_s``."""
    return _DeadlineScope(budget_s)


def remaining() -> Optional[float]:
    """Seconds left in the ambient deadline, or ``None`` outside any
    scope. May be negative once the budget is exhausted."""
    deadline = getattr(_TLS, "deadline", None)
    return None if deadline is None else deadline - time.monotonic()


def check_deadline(what: str = "operation"):
    """Raise :class:`DeadlineExceeded` if the ambient budget is spent."""
    rem = remaining()
    if rem is not None and rem <= 0:
        raise DeadlineExceeded(f"{what} exceeded its deadline budget")


def deadline_sleep(seconds: float, what: str = "sleep"):
    """Sleep ``seconds``, but never past the ambient deadline: if the
    budget runs out first, sleep what is left and raise."""
    rem = remaining()
    if rem is None:
        time.sleep(seconds)
        return
    if rem <= 0:
        raise DeadlineExceeded(f"{what} exceeded its deadline budget")
    if seconds >= rem:
        time.sleep(rem)
        raise DeadlineExceeded(f"{what} exceeded its deadline budget")
    time.sleep(seconds)


# -- fault plans + the plane ---------------------------------------------

@dataclass
class FaultPlan:
    """One installed fault: where it bites, whom, and how."""

    point: str                       # FAULT_POINTS name or "prefix.*"
    key: Optional[str] = None        # exact site-key match (None = any)
    latency_s: float = 0.0           # added delay before the op
    error: Optional[str] = None      # raise with this message
    hang: bool = False               # block until cleared / deadline
    mode: str = "persistent"         # or "one_shot"
    probability: float = 1.0         # seeded draw per matching call
    fault_id: str = ""
    hits: int = 0
    spent: bool = False              # one_shot already consumed
    cleared: threading.Event = field(default_factory=threading.Event,
                                     repr=False, compare=False)

    def matches(self, point: str, key: Optional[str]) -> bool:
        if self.spent:
            return False
        if self.point.endswith(".*"):
            if not point.startswith(self.point[:-1]):
                return False
        elif self.point != point:
            return False
        return self.key is None or self.key == key

    def view(self) -> dict:
        return {"fault_id": self.fault_id, "point": self.point,
                "key": self.key, "latency_s": self.latency_s,
                "error": self.error, "hang": self.hang, "mode": self.mode,
                "probability": self.probability, "hits": self.hits,
                "spent": self.spent}


def _validate_point(point) -> str:
    if not isinstance(point, str) or not point:
        raise ValueError(f"point must be a non-empty string, got {point!r}")
    if point in FAULT_POINTS:
        return point
    if point.endswith(".*") and any(p.startswith(point[:-1])
                                    for p in FAULT_POINTS):
        return point
    raise ValueError(f"unknown fault point {point!r}; "
                     f"known points: {', '.join(FAULT_POINTS)}")


class FaultPlane:
    """Seeded registry of live :class:`FaultPlan` s, one per federation
    (shared by every shard) or per standalone platform.

    ``on(point, key)`` is the interposition hook sites call on the hot
    path: with no matching plan it is one dict lookup under a lock.
    All probability draws come from one seeded RNG stream so a campaign
    is reproducible end to end.
    """

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self._plans: Dict[str, FaultPlan] = {}
        self._ctr = itertools.count(1)
        self._lock = threading.Lock()
        self.triggered: Dict[str, int] = {}   # point -> trigger count

    # -- registry management (the /v2/admin/faults verbs land here) ------
    def install(self, point: str, *, key: Optional[str] = None,
                latency_s: float = 0.0, error: Optional[str] = None,
                hang: bool = False, mode: str = "persistent",
                probability: float = 1.0) -> dict:
        point = _validate_point(point)
        if mode not in FAULT_MODES:
            raise ValueError(f"mode must be one of {FAULT_MODES}, "
                             f"got {mode!r}")
        if not (isinstance(latency_s, (int, float)) and latency_s >= 0):
            raise ValueError(f"latency_s must be >= 0, got {latency_s!r}")
        if not (isinstance(probability, (int, float))
                and 0.0 < probability <= 1.0):
            raise ValueError(f"probability must be in (0, 1], "
                             f"got {probability!r}")
        if error is not None and not isinstance(error, str):
            raise ValueError(f"error must be a message string, got {error!r}")
        if latency_s == 0 and error is None and not hang:
            raise ValueError("plan has no effect: set latency_s, error, "
                             "or hang")
        plan = FaultPlan(point=point, key=key, latency_s=float(latency_s),
                         error=error, hang=bool(hang), mode=mode,
                         probability=float(probability))
        with self._lock:
            plan.fault_id = f"fault-{next(self._ctr)}"
            self._plans[plan.fault_id] = plan
        return plan.view()

    def list(self) -> List[dict]:
        with self._lock:
            return [self._plans[fid].view() for fid in sorted(
                self._plans, key=lambda f: int(f.split("-")[1]))]

    def clear(self, fault_id: Optional[str] = None) -> int:
        """Remove one plan (or all); hung waiters are released."""
        with self._lock:
            ids = ([fault_id] if fault_id is not None
                   else list(self._plans))
            removed = 0
            for fid in ids:
                plan = self._plans.pop(fid, None)
                if plan is not None:
                    plan.cleared.set()
                    removed += 1
        return removed

    # -- the interposition hook ------------------------------------------
    def _match(self, point: str, key: Optional[str]) -> Optional[FaultPlan]:
        with self._lock:
            if not self._plans:
                return None
            for fid in sorted(self._plans,
                              key=lambda f: int(f.split("-")[1])):
                plan = self._plans[fid]
                if not plan.matches(point, key):
                    continue
                if plan.probability < 1.0 and \
                        self.rng.random() >= plan.probability:
                    continue
                plan.hits += 1
                self.triggered[point] = self.triggered.get(point, 0) + 1
                if plan.mode == "one_shot":
                    if plan.hang:
                        plan.spent = True   # keep it; clear() must wake us
                    else:
                        del self._plans[fid]
                return plan
        return None

    def on(self, point: str, key: Optional[str] = None,
           exc: Optional[Callable[[str], BaseException]] = None):
        """Interposition hook. No matching plan: near-free. Otherwise
        apply the plan's latency / hang / error, observing the ambient
        deadline (latency and hangs raise :class:`DeadlineExceeded`
        when they outlive the caller's budget)."""
        plan = self._match(point, key)
        if plan is None:
            return
        what = f"injected fault at {point}"
        if plan.latency_s > 0:
            deadline_sleep(plan.latency_s, what=what)
        if plan.hang:
            self._hang(plan, what)
        if plan.error is not None:
            raise (exc or FaultInjected)(plan.error)

    def should_fail(self, point: str, key: Optional[str] = None) -> bool:
        """Boolean query form of :meth:`on` for legacy ChaosMonkey-style
        call sites that raise their own failures. Consumes one-shots."""
        return self._match(point, key) is not None

    def _hang(self, plan: FaultPlan, what: str):
        """Block until the plan is cleared, the ambient deadline expires
        (raises), or the :data:`MAX_HANG_S` safety valve releases."""
        release_at = time.monotonic() + MAX_HANG_S
        while True:
            rem = remaining()
            if rem is not None and rem <= 0:
                raise DeadlineExceeded(f"{what} exceeded its deadline "
                                       f"budget (hang)")
            cap = release_at - time.monotonic()
            if cap <= 0:
                return
            wait = cap if rem is None else min(rem, cap)
            if plan.cleared.wait(wait):
                return


# -- circuit breaker ------------------------------------------------------

BREAKER_STATES = ("closed", "half_open", "open")
# numeric encoding used by the ffdl_breaker_state metric family
BREAKER_STATE_VALUE = {"closed": 0, "half_open": 1, "open": 2}


@dataclass(frozen=True)
class BreakerConfig:
    failure_threshold: int = 3    # consecutive failures that open it
    cooldown_s: float = 5.0       # open -> half_open after this long
    probe_successes: int = 1      # half_open successes that close it


class BreakerPolicy:
    """Pure closed → open → half-open circuit-breaker state machine.

    Like :class:`~repro.obs.operator.OperatorPolicy`, the transition
    function is deliberately free of I/O and wall clocks: callers feed
    it explicit ``now`` timestamps and *aggregate* outcome counts via
    :meth:`step` / :meth:`observe`. Within one step the aggregation
    rule is order-independent by construction — successes reset the
    failure streak first, then failures extend it — so replaying a
    shuffled observation batch yields the identical transition journal
    (property-tested in ``tests/test_faults.py``).
    """

    def __init__(self, config: Optional[BreakerConfig] = None):
        self.cfg = config or BreakerConfig()
        self.state = "closed"
        self.failure_streak = 0
        self.opened_at: Optional[float] = None
        self.probe_successes = 0
        self.transitions: List[dict] = []   # journal of state changes

    def _to(self, now: float, state: str, reason: str):
        self.transitions.append({"at": now, "from": self.state,
                                 "to": state, "reason": reason})
        self.state = state
        if state == "open":
            self.opened_at = now
            self.probe_successes = 0
        elif state == "half_open":
            self.probe_successes = 0
        elif state == "closed":
            self.failure_streak = 0
            self.opened_at = None

    def _maybe_half_open(self, now: float):
        if self.state == "open" and \
                now - self.opened_at >= self.cfg.cooldown_s:
            self._to(now, "half_open", "cooldown elapsed")

    def step(self, now: float, successes: int = 0, failures: int = 0):
        """Consume aggregate outcome counts observed since last step."""
        self._maybe_half_open(now)
        if self.state == "closed":
            if successes > 0:
                self.failure_streak = 0
            if failures > 0:
                self.failure_streak += failures
                if self.failure_streak >= self.cfg.failure_threshold:
                    self._to(now, "open",
                             f"{self.failure_streak} consecutive failures")
        elif self.state == "half_open":
            if failures > 0:
                self._to(now, "open", "probe failed")
            elif successes > 0:
                self.probe_successes += successes
                if self.probe_successes >= self.cfg.probe_successes:
                    self._to(now, "closed", "probe succeeded")
        # open: outcomes of straggler in-flight requests are ignored

    def observe(self, now: float, outcomes) -> str:
        """Batch form: ``outcomes`` is any iterable of ``"ok"``/``"fail"``
        strings. Aggregated before stepping, so the result is invariant
        under reordering of the batch. Returns the post-step state."""
        outcomes = list(outcomes)
        self.step(now, successes=sum(1 for o in outcomes if o == "ok"),
                  failures=sum(1 for o in outcomes if o != "ok"))
        return self.state

    def allow_request(self, now: float) -> bool:
        """Admission check: closed and half-open admit (half-open traffic
        is the probe); open fast-fails until the cooldown elapses."""
        self._maybe_half_open(now)
        return self.state != "open"


class ShardBreaker:
    """Thread-safe live front for :class:`BreakerPolicy`, one per
    :class:`~repro.api.backend.Backend`. The gateway records one
    outcome per v1 verb; ``Federation.tick`` records tick deadline
    overruns; ``allow()`` gates shard selection."""

    def __init__(self, config: Optional[BreakerConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        self._policy = BreakerPolicy(config)
        self._clock = clock
        self._lock = threading.Lock()
        self.deadline_exceeded_total = 0

    @property
    def state(self) -> str:
        with self._lock:
            # surface time-driven open -> half_open without an outcome
            self._policy._maybe_half_open(self._clock())
            return self._policy.state

    @property
    def transitions(self) -> List[dict]:
        with self._lock:
            return list(self._policy.transitions)

    def record_success(self):
        with self._lock:
            self._policy.step(self._clock(), successes=1)

    def record_failure(self, deadline: bool = False):
        with self._lock:
            if deadline:
                self.deadline_exceeded_total += 1
            self._policy.step(self._clock(), failures=1)

    def allow(self) -> bool:
        with self._lock:
            return self._policy.allow_request(self._clock())

    def reset(self):
        """Fresh closed state (used on shard restart: a restart clears
        the gray-failure presumption; if the shard is still wedged the
        breaker re-opens within ``failure_threshold`` requests)."""
        with self._lock:
            self._policy = BreakerPolicy(self._policy.cfg)
