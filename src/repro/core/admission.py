"""Admission control + priority management (FfDL §3.6).

"Given that there is no overcommitment, admission control becomes
necessary; there is a component above FfDL that performs AC — based on
quotas for internal users [...] the AC component also pre-empts 2 job types
as necessary: (1) free users during heavy load, and (2) user A exceeded
their quota; their job was scheduled because user B wasn't using their
quota; user B subsequently wants to use his quota."

Implemented: per-tenant chip quotas; over-quota jobs admitted
opportunistically when idle capacity exists (marked preemptible);
reclamation preempts over-quota jobs of other tenants (HALT → checkpoint →
requeue); free-tier jobs preempted under heavy load when paid jobs queue.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.types import EventLog, JobManifest, JobStatus, gang_chips

HEAVY_LOAD_UTIL = 0.9


@dataclass
class Tenant:
    name: str
    quota_chips: int
    tier: str = "paid"


class AdmissionController:
    def __init__(self, platform, events: EventLog):
        self.p = platform
        self.events = events
        self.tenants: dict[str, Tenant] = {}
        # job_id → True if admitted above quota (preemptible on reclaim)
        self.over_quota: dict[str, bool] = {}

    def register_tenant(self, name: str, quota_chips: int, tier: str = "paid"):
        self.tenants[name] = Tenant(name, quota_chips, tier)

    def unregister_tenant(self, name: str):
        """Drop a tenant's quota (v2 admin tenant delete): its submissions
        fall back to the 'no quota configured' open admission."""
        self.tenants.pop(name, None)

    def _tenant_usage(self, tenant: str) -> int:
        """Chips held by a tenant's active (non-terminal, non-halted) jobs."""
        used = 0
        for rec in self.p.meta.jobs(tenant=tenant):
            if rec.status in (JobStatus.QUEUED, JobStatus.DEPLOYING,
                              JobStatus.DOWNLOADING, JobStatus.PROCESSING,
                              JobStatus.STORING, JobStatus.RESUMED,
                              JobStatus.PENDING):
                used += gang_chips(rec.manifest)
        return used

    def check(self, manifest: JobManifest) -> tuple[bool, str]:
        """Admit or reject a submission. Over-quota → opportunistic admit
        when the cluster has idle capacity, else reject."""
        tenant = self.tenants.get(manifest.tenant)
        if tenant is None:
            return True, "no quota configured"
        need = gang_chips(manifest)
        usage = self._tenant_usage(manifest.tenant)
        if usage + need <= tenant.quota_chips:
            return True, "within quota"
        idle = self.p.cluster.total_chips - self.p.cluster.used_chips
        if idle >= need:
            self.events.emit("admission", "over_quota_admit",
                             tenant=manifest.tenant, chips=need)
            return True, "over quota (opportunistic)"
        return False, (f"quota exceeded: {usage}+{need} > "
                       f"{tenant.quota_chips} and no idle capacity")

    def mark(self, job_id: str, manifest: JobManifest):
        tenant = self.tenants.get(manifest.tenant)
        if tenant is None:
            return
        usage = self._tenant_usage(manifest.tenant)
        self.over_quota[job_id] = usage > tenant.quota_chips

    # -- preemption ------------------------------------------------------
    def _active_jobs(self):
        for rec in self.p.meta.jobs():
            if rec.status in (JobStatus.DOWNLOADING, JobStatus.PROCESSING,
                              JobStatus.STORING, JobStatus.RESUMED):
                yield rec

    def tick(self):
        """Reclaim quota + heavy-load free-tier preemption."""
        queued = [r for r in self.p.meta.jobs()
                  if r.status == JobStatus.QUEUED]
        if not queued:
            return
        util = self.p.cluster.utilization()
        for waiter in queued:
            w_tenant = self.tenants.get(waiter.manifest.tenant)
            if w_tenant is None:
                continue
            w_usage = self._tenant_usage(waiter.manifest.tenant)
            within_quota = w_usage <= w_tenant.quota_chips
            if not within_quota:
                continue  # over-quota jobs don't trigger preemption
            need = gang_chips(waiter.manifest)
            free = self.p.cluster.total_chips - self.p.cluster.used_chips
            if free >= need:
                continue  # scheduler will get to it
            # candidates: (1) over-quota jobs of other tenants,
            # (2) free-tier jobs under heavy load
            victims = []
            for rec in self._active_jobs():
                if rec.manifest.tenant == waiter.manifest.tenant:
                    continue
                if self.over_quota.get(rec.job_id):
                    victims.append((0, rec))
                elif rec.manifest.tier == "free" and util >= HEAVY_LOAD_UTIL \
                        and waiter.manifest.tier == "paid":
                    victims.append((1, rec))
            victims.sort(key=lambda t: (t[0], -t[1].submitted_at))
            reclaimed = 0
            for _, victim in victims:
                if free + reclaimed >= need:
                    break
                self.events.emit("admission", "preempt", job=victim.job_id,
                                 beneficiary=waiter.job_id,
                                 reason="quota_reclaim" if
                                 self.over_quota.get(victim.job_id)
                                 else "free_tier_heavy_load")
                # control-plane action: must work even with the API tier down
                self.p._halt_internal(victim.job_id, requeue=True)
                reclaimed += gang_chips(victim.manifest)
