"""Shared platform types: clock, events, job/pod model, statuses.

Status vocabulary is the paper's (§2: "DL-specific job statuses (e.g.,
DOWNLOADING, PROCESSING, STORING, HALTED, RESUMED)" + §3.3 FAILED/COMPLETED
+ the implicit QUEUED/DEPLOYING stages of the Guardian workflow).
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Optional


# --------------------------------------------------------------------------
# Clock — simulated (deterministic benchmarks) or wall (examples)
# --------------------------------------------------------------------------

class SimClock:
    """Discrete-event clock. Components schedule callbacks; run() drains."""

    def __init__(self, start: float = 0.0):
        self._now = start
        self._heap: list = []
        self._counter = itertools.count()

    def now(self) -> float:
        return self._now

    def advance(self, dt: float):
        self._now += max(dt, 0.0)

    def call_at(self, t: float, fn: Callable[[], None]):
        heapq.heappush(self._heap, (max(t, self._now), next(self._counter), fn))

    def call_later(self, dt: float, fn: Callable[[], None]):
        self.call_at(self._now + dt, fn)

    def run_until(self, t_end: float):
        while self._heap and self._heap[0][0] <= t_end:
            t, _, fn = heapq.heappop(self._heap)
            self._now = max(self._now, t)
            fn()
        self._now = max(self._now, t_end)

    def pending(self) -> int:
        return len(self._heap)


class WallClock:
    def __init__(self):
        import time
        self._time = time

    def now(self) -> float:
        return self._time.time()

    def advance(self, dt: float):
        if dt > 0:
            self._time.sleep(dt)


# --------------------------------------------------------------------------
# Structured event log (drives the §5.6 failure-analysis benchmark).
# Promoted into the observability plane's per-shard event bus: sequence
# numbers, bounded retention, tenant stamping, wire visibility via
# GET /v2/events. `EventLog` stays as the historical name — same emit /
# of_kind / count surface, now backed by repro.obs.bus.EventBus.
# --------------------------------------------------------------------------

from repro.obs.bus import Event, EventBus  # noqa: E402  (re-export)

EventLog = EventBus


# --------------------------------------------------------------------------
# Job / pod model
# --------------------------------------------------------------------------

class JobStatus(str, Enum):
    PENDING = "PENDING"          # accepted, metadata durable, not yet deployed
    QUEUED = "QUEUED"            # waiting for gang resources
    DEPLOYING = "DEPLOYING"      # guardian provisioning
    DOWNLOADING = "DOWNLOADING"  # load-data helper streaming the dataset
    PROCESSING = "PROCESSING"    # learners training
    STORING = "STORING"          # store-results helper uploading model
    COMPLETED = "COMPLETED"
    FAILED = "FAILED"
    HALTED = "HALTED"            # user/AC-initiated checkpoint-and-stop
    RESUMED = "RESUMED"          # transitional status after HALT → requeue


TERMINAL = {JobStatus.COMPLETED, JobStatus.FAILED}


class PodPhase(str, Enum):
    PENDING = "Pending"
    RUNNING = "Running"
    SUCCEEDED = "Succeeded"
    FAILED = "Failed"
    DELETED = "Deleted"


# The pinned ``train:`` sub-spec vocabulary — exactly the keys the learner
# runtime consumes (core/executor.py). The v1 submit path rejects anything
# else with INVALID_ARGUMENT instead of silently ignoring it, so a typo in
# a manifest-derived spec ("step" for "steps") surfaces at submit time
# rather than as a job that trains with defaults. Pinned in docs/api.md.
TRAIN_SPEC_FIELDS = ("tiny", "overrides", "steps", "lr", "warmup",
                     "seq", "batch", "seed")


def unknown_spec_fields(m: "JobManifest") -> list:
    """Typo'd keys in the manifest's ``train`` sub-spec (sorted), or a
    one-element sentinel when ``train`` is not a mapping at all."""
    if not isinstance(m.train, dict):
        return ["train (must be a mapping)"]
    return sorted(set(m.train) - set(TRAIN_SPEC_FIELDS))


@dataclass
class JobManifest:
    """What the user submits — FfDL's 'natural language job description':
    code ref (here: arch/workload), data location, resources per learner."""

    name: str
    tenant: str = "default"
    n_learners: int = 1
    chips_per_learner: int = 1
    tier: str = "paid"  # paid | free (admission-control preemption class)
    # Real training workload (arch id + trainer overrides), or simulated:
    arch: Optional[str] = None
    train: dict = field(default_factory=dict)  # steps, batch, seq, ckpt_every
    sim_duration: Optional[float] = None       # simulated job runtime (s)
    data_bucket: str = "datasets"
    results_bucket: str = "results"
    checkpoint_interval: int = 50   # steps between checkpoints (real jobs)
    max_restarts: int = 3
    max_deploy_retries: int = 3
    # straggler mitigation: restart a learner whose progress stalls for this
    # many seconds while a peer advances (0 = disabled). Catches silent
    # stalls that exit-code monitoring cannot (degraded-but-alive nodes).
    straggler_timeout_s: float = 0.0


@dataclass
class Pod:
    name: str
    job_id: str
    kind: str  # learner | helper | guardian-proxy
    chips: int
    host: Optional[str] = None
    phase: PodPhase = PodPhase.PENDING
    restarts: int = 0
    started_at: Optional[float] = None
    finished_at: Optional[float] = None


@dataclass
class JobRecord:
    """Durable metadata (MongoDB analogue content)."""

    job_id: str
    manifest: JobManifest
    status: JobStatus = JobStatus.PENDING
    status_history: list = field(default_factory=list)  # [(ts, status, msg)]
    submitted_at: float = 0.0
    scheduled_at: Optional[float] = None
    finished_at: Optional[float] = None
    placement: Optional[dict] = None  # pod_name → host
    restarts: int = 0
    deploy_retries: int = 0
    progress_step: int = 0
    message: str = ""

    def set_status(self, ts: float, status: JobStatus, msg: str = ""):
        self.status = status
        self.message = msg
        self.status_history.append((ts, status.value, msg))


def gang_chips(m: JobManifest) -> int:
    return m.n_learners * m.chips_per_learner
