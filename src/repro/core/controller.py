"""Controller helper (FfDL §3.8 'Detecting Failure or Completion of Learner
Processes' + 'Reliable Status Updates').

Runs in the helper pod, isolated from learners but sharing the job's NFS
volume. Each tick it reads learner status/exit files from the volume and
records per-learner status in etcd (under a lease so stale state vanishes if
the whole job disappears). The Guardian watches etcd and aggregates.

Crash-resilience contract reproduced from the paper:
  * controller crash → K8s restarts it; statuses re-read from NFS (no loss);
  * Guardian crash → etcd still has per-learner statuses;
  * learner crash → its exit file (non-zero code) is the detection signal.
"""

from __future__ import annotations

import json
from typing import Optional

from repro.core.executor import JobVolume
from repro.core.kvstore import EtcdLike
from repro.core.types import EventLog


class Controller:
    LEASE_TTL = 30.0

    def __init__(self, job_id: str, n_learners: int, volume: JobVolume,
                 etcd: EtcdLike, clock, events: EventLog):
        self.job_id = job_id
        self.n_learners = n_learners
        self.volume = volume
        self.etcd = etcd
        self.clock = clock
        self.events = events
        self.alive = True
        self._lease: Optional[int] = None

    def _ensure_lease(self):
        if self._lease is None or not self.etcd.keepalive(self._lease):
            self._lease = self.etcd.grant_lease(self.LEASE_TTL)

    def crash(self):
        self.alive = False

    def restart(self):
        """K8s restart: stateless — everything is re-read from NFS."""
        self.alive = True
        self._lease = None

    def tick(self):
        if not self.alive:
            return
        try:
            self._ensure_lease()
            for k in range(self.n_learners):
                raw = self.volume.read(f"status/learner-{k}")
                if raw is not None:
                    self.etcd.put(f"/jobs/{self.job_id}/learners/{k}/status",
                                  json.loads(raw), lease_id=self._lease)
                exit_raw = self.volume.read(f"exit/learner-{k}")
                if exit_raw is not None:
                    self.etcd.put(f"/jobs/{self.job_id}/learners/{k}/exit",
                                  json.loads(exit_raw), lease_id=self._lease)
        except (IOError, ConnectionError) as e:
            self.events.emit("controller", "status_relay_error",
                             job=self.job_id, error=str(e))
