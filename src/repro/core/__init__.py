# The paper's primary contribution: the FfDL multi-tenant platform —
# scheduler (gang/BSA/PACK), lifecycle (LCM/Guardian), coordination
# (etcd-like), metadata (Mongo-like), helpers, admission, chaos.
from repro.core.chaos import ChaosConfig, ChaosMonkey
from repro.core.platform import FfDLPlatform
from repro.core.types import (
    EventLog,
    JobManifest,
    JobRecord,
    JobStatus,
    Pod,
    PodPhase,
    SimClock,
    WallClock,
)

__all__ = [
    "ChaosConfig",
    "ChaosMonkey",
    "FfDLPlatform",
    "EventLog",
    "JobManifest",
    "JobRecord",
    "JobStatus",
    "Pod",
    "PodPhase",
    "SimClock",
    "WallClock",
]
