# The paper's primary contribution: the FfDL multi-tenant platform —
# scheduler (gang/BSA/PACK), lifecycle (LCM/Guardian), coordination
# (etcd-like), metadata (Mongo-like), helpers, admission, chaos.
# ``FfDLPlatform`` is exported lazily (PEP 562): the platform pulls in the
# API tier (repro.api), whose modules import repro.core.types — importing
# it eagerly here would close that loop into a cycle.
from repro.core.chaos import ChaosConfig, ChaosMonkey
from repro.core.types import (
    TRAIN_SPEC_FIELDS,
    EventLog,
    JobManifest,
    JobRecord,
    JobStatus,
    Pod,
    PodPhase,
    SimClock,
    WallClock,
    unknown_spec_fields,
)

__all__ = [
    "ChaosConfig",
    "ChaosMonkey",
    "FfDLPlatform",
    "EventLog",
    "JobManifest",
    "JobRecord",
    "JobStatus",
    "Pod",
    "PodPhase",
    "SimClock",
    "TRAIN_SPEC_FIELDS",
    "WallClock",
    "unknown_spec_fields",
]


def __getattr__(name):
    if name == "FfDLPlatform":
        from repro.core.platform import FfDLPlatform
        return FfDLPlatform
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
