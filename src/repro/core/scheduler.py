"""Job scheduling (FfDL §3.4–3.6).

Two schedulers over the same ClusterModel:

* ``GangScheduler`` — the paper's production scheduler: FCFS with
  largest-gang-first tie-break, **all-or-nothing gang reservation** via BSA,
  PACK (default) or SPREAD placement, no overcommit. Guarantees zero
  temporary deadlocks (§3.5 / Fig 4). Reservations hold capacity from the
  moment of placement until the Guardian either confirms (pods bound) or
  releases (rollback/terminal) — there is never a window where two gangs
  can double-book chips.

* ``K8sDefaultScheduler`` — the baseline the paper measured against: each
  pod scheduled individually (spread-ranked), so a gang can be *partially*
  placed, holding chips while siblings queue — the temporary-deadlock
  pathology reproduced by benchmarks/gang.py.
"""

from __future__ import annotations

from bisect import insort
from collections import Counter
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.core.bsa import bsa_place
from repro.core.cluster import ClusterModel
from repro.core.types import EventLog, Pod


@dataclass
class GangRequest:
    job_id: str
    n_pods: int
    chips_per_pod: int
    submitted_at: float
    placement: Optional[list] = None  # host_id per pod, set when placed

    @property
    def total_chips(self) -> int:
        return self.n_pods * self.chips_per_pod


@dataclass
class _HostView:
    """Host with reservation-adjusted free capacity (what BSA sees)."""
    host_id: str
    n_chips: int
    coord: tuple
    free_chips: int
    schedulable: bool = True


class GangScheduler:
    """FCFS + gang + BSA + PACK/SPREAD."""

    def __init__(self, cluster: ClusterModel, events: EventLog,
                 placement: str = "pack", strict_fcfs: bool = False,
                 bsa_samples: int = 8, seed: int = 0):
        self.cluster = cluster
        self.events = events
        self.placement = placement
        self.strict_fcfs = strict_fcfs
        self.bsa_samples = bsa_samples
        self.rng = np.random.default_rng(seed)
        self.queue: list[GangRequest] = []
        # chips held by placed-but-not-yet-bound gangs
        self._reserved: dict[str, list] = {}      # job_id → host id per pod
        self._reserved_chips: Counter = Counter()  # host_id → chips
        self._chips_per_pod: dict[str, int] = {}
        self.on_placed: Optional[Callable[[GangRequest], None]] = None
        # BSA verdict cache: a gang that did not fit cannot fit again until
        # the cluster (free chips / schedulability) or this scheduler's
        # reservations change. "Does not fit" is deterministic in that
        # state (bsa_place returns None iff sum(free//cpp) < n_pods, before
        # consuming any randomness), so skipping the re-run is observably
        # identical — placements and the rng stream are unchanged.
        self._res_epoch = 0                 # bumped on reserve/confirm/release
        self._nofit: dict[str, tuple] = {}  # job_id → epoch pair at failure
        self.stats = {"bsa_runs": 0, "bsa_cache_hits": 0}

    # -- API ----------------------------------------------------------------
    def submit(self, req: GangRequest):
        # FCFS; same-instant arrivals resolved largest-gang-first (§3.6).
        # One bisect insertion keeps the queue sorted (ties land after
        # existing equals — exactly the old stable re-sort's order).
        insort(self.queue, req,
               key=lambda r: (r.submitted_at, -r.total_chips))
        self.events.emit("scheduler", "gang_queued", job=req.job_id,
                         chips=req.total_chips)

    def confirm(self, job_id: str):
        """Guardian bound the pods; chips are now held by the pods."""
        hosts = self._reserved.pop(job_id, None)
        if hosts:
            cpp = self._chips_per_pod.pop(job_id, 0)
            for h in hosts:
                self._reserved_chips[h] -= cpp
            self._res_epoch += 1

    def release(self, job_id: str):
        """Free a gang (finished/failed/preempted/rolled back)."""
        self.confirm(job_id)  # drop any unconfirmed reservation
        self.queue = [r for r in self.queue if r.job_id != job_id]
        self._nofit.pop(job_id, None)

    def queue_depth(self) -> int:
        return len(self.queue)

    def _host_views(self) -> list[_HostView]:
        # schedulable_hosts() is cached by the cluster and free_chips is an
        # O(1) counter, so building BSA's reservation-adjusted view is one
        # cheap pass — not a per-pod rescan of every pod on every host.
        return [
            _HostView(h.host_id, h.n_chips, h.coord,
                      h.free_chips - self._reserved_chips.get(h.host_id, 0))
            for h in self.cluster.schedulable_hosts()
        ]

    # -- scheduling round -------------------------------------------------
    def tick(self):
        progress = True
        while progress and self.queue:
            progress = False
            for req in list(self.queue):
                epoch = (self.cluster.epoch, self._res_epoch)
                if self._nofit.get(req.job_id) == epoch:
                    # nothing a placement can observe changed since this
                    # gang last failed to fit: the verdict stands, skip the
                    # BSA re-run (and the repeat no-nodes event)
                    self.stats["bsa_cache_hits"] += 1
                    if self.strict_fcfs:
                        return  # head-of-line still blocks
                    continue
                self.stats["bsa_runs"] += 1
                assignment = bsa_place(
                    self._host_views(), req.n_pods, req.chips_per_pod,
                    policy=self.placement, torus=self.cluster.torus,
                    samples=self.bsa_samples, rng=self.rng)
                if assignment is None:
                    self._nofit[req.job_id] = epoch
                    self.events.emit(
                        "scheduler", "no_nodes_available", job=req.job_id,
                        reason="no nodes match all predicates "
                               "(insufficient chips)")
                    if self.strict_fcfs:
                        return  # head-of-line blocks
                    continue
                # All-or-nothing reservation, atomic wrt this scheduler.
                self._nofit.pop(req.job_id, None)
                req.placement = assignment
                self._reserved[req.job_id] = assignment
                self._chips_per_pod[req.job_id] = req.chips_per_pod
                for h in assignment:
                    self._reserved_chips[h] += req.chips_per_pod
                self._res_epoch += 1
                self.queue.remove(req)
                self.events.emit("scheduler", "gang_placed", job=req.job_id,
                                 hosts=sorted(set(assignment)))
                if self.on_placed:
                    self.on_placed(req)
                progress = True
                break  # cluster state changed; re-walk the queue in order


class K8sDefaultScheduler:
    """Pod-at-a-time baseline (the §3.5 pathology).

    Binds each pod independently with the default spread ranking; a job's
    pods can land while its siblings starve, holding chips idle. Used by
    benchmarks/gang.py; the production platform uses GangScheduler.
    """

    def __init__(self, cluster: ClusterModel, events: EventLog,
                 placement: str = "spread", seed: int = 0):
        self.cluster = cluster
        self.events = events
        self.placement = placement
        self.rng = np.random.default_rng(seed)
        self.pod_queue: list[tuple[GangRequest, int]] = []
        self._assigned: dict[str, dict[int, str]] = {}
        self._reqs: dict[str, GangRequest] = {}
        self.on_placed: Optional[Callable[[GangRequest], None]] = None

    def submit(self, req: GangRequest):
        for k in range(req.n_pods):
            self.pod_queue.append((req, k))
        self._assigned.setdefault(req.job_id, {})
        self._reqs[req.job_id] = req
        # K8s processes pods roughly in arrival order with local
        # nondeterministic reordering (watch/queue races) — a full shuffle
        # would overstate the pathology vs the paper's Fig 4.
        jitter = self.rng.uniform(0, 8.0, size=len(self.pod_queue))
        order = sorted(range(len(self.pod_queue)),
                       key=lambda i: i + jitter[i])
        self.pod_queue = [self.pod_queue[i] for i in order]

    def release(self, job_id: str):
        self.pod_queue = [(r, k) for r, k in self.pod_queue
                          if r.job_id != job_id]
        for k, host in self._assigned.pop(job_id, {}).items():
            self.cluster.delete_pod(f"{job_id}-l{k}", reason="released")
        self._reqs.pop(job_id, None)

    def queue_depth(self) -> int:
        return len({r.job_id for r, _ in self.pod_queue})

    def deadlocked_learners(self) -> int:
        """Learners bound (holding chips) whose job is not fully bound —
        the paper's 'temporarily deadlocked' learners (Fig 4a)."""
        n = 0
        for job_id, req in self._reqs.items():
            done = len(self._assigned.get(job_id, {}))
            if 0 < done < req.n_pods:
                n += done
        return n

    def idle_chips(self) -> int:
        """Chips held by deadlocked learners (Fig 4b numerator)."""
        n = 0
        for job_id, req in self._reqs.items():
            done = len(self._assigned.get(job_id, {}))
            if 0 < done < req.n_pods:
                n += done * req.chips_per_pod
        return n

    def tick(self):
        # Placement is answered from the cluster's free-chips index: the
        # spread pick is min(same-job pods, -free, host id) and the pack
        # pick is min(free, host id) over eligible hosts — the same host
        # the old build-a-list-and-sort chose, without rescanning and
        # re-ranking every host for every queued pod on every tick.
        remaining = []
        for req, k in self.pod_queue:
            if self.placement == "spread":
                host = self.cluster.spread_host(req.chips_per_pod,
                                                req.job_id)
            else:
                host = self.cluster.pack_host(req.chips_per_pod)
            if host is None:
                self.events.emit("scheduler", "no_nodes_available",
                                 job=req.job_id, pod=k,
                                 reason="Insufficient chips")
                remaining.append((req, k))
                continue
            pod = Pod(name=f"{req.job_id}-l{k}", job_id=req.job_id,
                      kind="learner", chips=req.chips_per_pod)
            if not self.cluster.bind_pod(pod, host.host_id):
                remaining.append((req, k))
                continue
            self._assigned[req.job_id][k] = host.host_id
            if len(self._assigned[req.job_id]) == req.n_pods:
                req.placement = [self._assigned[req.job_id][i]
                                 for i in range(req.n_pods)]
                if self.on_placed:
                    self.on_placed(req)
        self.pod_queue = remaining
