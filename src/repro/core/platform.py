"""FfDLPlatform: the facade wiring all microservices together (FfDL Fig 1-2).

The public API surface lives in :mod:`repro.api` (FfDL §3.2): a tier of
**stateless, replicated** gateways (``ApiGateway``) behind a round-robin
``LoadBalancer``, speaking the versioned v1 contract — typed
request/response envelopes, per-tenant API-key auth with scope checks,
structured ``ApiError`` codes, client-supplied idempotency keys on
``submit`` (deduplicated durably via the metastore WAL), and
cursor-paginated listings. Crash any single replica and idempotent calls
still succeed (``benchmarks/api_tier.py`` measures this recovery claim).

This class is the **control plane**: it owns and ticks every microservice:
chaos → cluster (heartbeats/evictions) → LCM (reconcile) → guardians
(deploy/monitor) → admission (preemption) → scheduler (gang placement) →
metrics. Internal lifecycle actions (``_halt_internal``/
``_resume_internal``, used by admission preemption and requeue timers)
bypass the API tier: they must keep working while every gateway replica
is down.

All *user-facing* operations go through the API tier with a tenant-scoped
key — in-process via ``platform.api`` (the balancer), ergonomically via
``ApiClient.for_platform(platform, tenant)``, or over the wire via
``repro.api.http``. The pre-gateway raw-exception facade
(``platform.submit()`` & friends, which translated ``ApiError`` back to
``ValueError``/``KeyError``/...) is retired: every caller sees the stable
``ApiError`` codes now.

API-layer semantics reproduced (all via the gateway):
  * ``submit`` validates, persists to the metastore **before acking** and
    returns a job id — jobs survive any subsequent component crash;
  * ``status``/``status_history`` read the metastore (user-visible,
    timestamped — the paper's billing/debugging requirement);
  * ``logs``/``search_logs`` read the ElasticSearch-like index;
  * ``halt``/``resume`` drive HALT/RESUME for hyperparameter workflows;
  * API replicas are stateless: ``api_crash``/``api_restart`` only gate the
    public methods (recovery-time benchmark).

``tick()`` is one platform scheduling round; ``run_until`` drives the
simulated clock.
"""

from __future__ import annotations

import itertools
from typing import Optional

from repro.api.auth import AuthService
from repro.api.backend import Backend
from repro.api.gateway import ApiGateway
from repro.api.lb import LoadBalancer
from repro.api.router import TenantRouter
from repro.core.admission import AdmissionController
from repro.core.chaos import ChaosConfig, ChaosMonkey
from repro.core.cluster import ClusterModel
from repro.core.executor import JobVolume
from repro.core.helpers import LogIndex, MetricsService
from repro.core.kvstore import EtcdLike
from repro.core.lcm import LifecycleManager
from repro.core.metastore import MetaStore
from repro.core.scheduler import GangScheduler, K8sDefaultScheduler
from repro.core.types import (
    EventLog,
    JobStatus,
    SimClock,
    TERMINAL,
    gang_chips,
)
from repro.data.objectstore import ObjectStore
from repro.obs import DEFAULT_RETENTION, UsageMeter, install_meter


class FfDLPlatform:
    def __init__(self, n_hosts: int = 16, chips_per_host: int = 4,
                 placement: str = "pack", scheduler: str = "gang",
                 chaos: Optional[ChaosConfig] = None, clock=None,
                 tick_period: float = 1.0, seed: int = 0,
                 objstore_bandwidth: Optional[float] = None,
                 n_api_replicas: int = 3, shard_id: str = "shard-0",
                 job_id_base: int = 0, shared_reads: bool = True,
                 event_retention: int = DEFAULT_RETENTION,
                 fault_plane=None):
        # -- shard construction hooks (repro.api.federation) --------------
        # shard_id names this platform as a backend shard; job_id_base
        # offsets the job counter so ids stay globally unique across a
        # federation; shared_reads=False degrades the shard lock to the
        # pre-federation exclusive behaviour (benchmark baseline).
        self.shard_id = shard_id
        self.job_id_base = job_id_base
        self.clock = clock or SimClock()
        self.tick_period = tick_period
        self.ticks = 0  # scheduling rounds since construction (uptime)
        self.events = EventLog(self.clock, retention=event_retention,
                               shard_id=shard_id)
        self.etcd = EtcdLike(self.clock, self.events)
        # Unified fault-injection plane (repro.core.faults): every gray-
        # failure interposition point on this shard draws from this one
        # seeded registry. A Federation passes its shared plane in so one
        # /v2/admin/faults surface covers the whole fleet; standalone
        # platforms get their own.
        from repro.core.faults import FaultPlane
        self.faults = fault_plane if fault_plane is not None \
            else FaultPlane(seed=seed)
        self.meta = MetaStore(self.clock)
        self.meta.faults = self.faults
        self.meta.fault_key = shard_id
        self.objstore = ObjectStore(clock=None,
                                    bandwidth_bps=objstore_bandwidth)
        self.objstore.faults = self.faults
        self.objstore.fault_key = shard_id
        self.objstore.create_bucket("datasets")
        self.objstore.create_bucket("results")
        self.cluster = ClusterModel(n_hosts, chips_per_host, self.clock,
                                    self.etcd, self.events)
        if scheduler == "gang":
            self.scheduler = GangScheduler(self.cluster, self.events,
                                           placement=placement, seed=seed)
        else:
            self.scheduler = K8sDefaultScheduler(self.cluster, self.events,
                                                 placement=placement,
                                                 seed=seed)
        self.admission = AdmissionController(self, self.events)
        self.lcm = LifecycleManager(self, self.events)
        self.chaos = ChaosMonkey(chaos or ChaosConfig(), self)
        self.metrics = MetricsService(self.clock)
        self.log_index = LogIndex()
        # -- observability plane (repro.obs): the bus stamps events with
        # their owning tenant (so /v2/events can scope visibility) and the
        # meter accrues per-tenant usage — job outcomes + 429s via a bus
        # tap, log bytes via the index append hook, chip-seconds in tick().
        self.meter = UsageMeter()
        self.events.tenant_resolver = self._tenant_of_job
        install_meter(self.events, self.meter)
        self.log_index.on_append = self._meter_log_bytes
        self.guardians: dict[str, object] = {}
        self.volumes: dict[str, JobVolume] = {}
        self._job_ctr = itertools.count(job_id_base + 1)
        # ------------------------------------------------ API tier (§3.2)
        # A standalone platform is a one-shard federation: the gateway
        # replicas route through a TenantRouter over this platform's own
        # Backend (per-shard RW lock + health). repro.api.federation
        # reuses the same Backend when composing multi-shard tiers, so
        # there is exactly one lock per shard no matter who fronts it.
        self.auth = AuthService(seed=seed)
        self.backend = Backend(shard_id, self, shared_reads=shared_reads)
        self.router = TenantRouter([self.backend])
        self.api_replicas = [
            ApiGateway(self.router, self.auth, replica_id=f"api-{i}",
                       events=self.events)
            for i in range(max(1, n_api_replicas))]
        self.api = LoadBalancer(self.api_replicas, events=self.events)
        # v2 admin control plane (repro.api.admin): on a standalone
        # platform it manages tenants/quotas/rate limits and exposes the
        # single shard as a resource; migrations need a Federation.
        from repro.api.admin import AdminGateway, AdminPlane
        self.admin = AdminPlane(self.router, self.auth)
        self.admin.faults = self.faults
        self.admin_api = AdminGateway(self.admin, self.auth)
        # v2 workloads plane (repro.workloads): manifests are storable and
        # wire-addressable on a standalone platform, but convergence is a
        # Federation concern — Federation.tick steps the reconciler, like
        # migrations only advance under a Federation.
        from repro.workloads import WorkloadGateway, WorkloadPlane
        self.workloads = WorkloadPlane(self.router, self.auth)
        self.workloads_api = WorkloadGateway(self.workloads, self.auth)

    # ------------------------------------------------- API tier lifecycle
    @property
    def _api_up(self) -> bool:
        return any(r.alive for r in self.api_replicas)

    def api_crash(self, replica: Optional[int] = None):
        """Crash one replica (by index) or, by default, the whole tier."""
        targets = (self.api_replicas if replica is None
                   else [self.api_replicas[replica]])
        for r in targets:
            r.alive = False  # silent: a dead replica emits nothing

    def api_restart(self, replica: Optional[int] = None):
        targets = (self.api_replicas if replica is None
                   else [self.api_replicas[replica]])
        for r in targets:
            if not r.alive:
                r.restart()

    # --------------------------------------------- internal control plane
    # These bypass the API tier: admission preemption and requeue timers
    # must keep working while every gateway replica is crashed.
    def _next_job_id(self) -> str:
        return f"job-{next(self._job_ctr):05d}"

    def _halt_internal(self, job_id: str, requeue: bool = False):
        g = self.guardians.get(job_id)
        if g is not None:
            g.halt()
        else:
            self.meta.update_status(job_id, JobStatus.HALTED, "halted")
        if requeue:
            # preempted jobs go back through the queue automatically
            def do_resume(job_id=job_id):
                rec = self.meta.get(job_id)
                if rec is not None and rec.status == JobStatus.HALTED:
                    self._resume_internal(job_id)
            self.clock.call_later(3 * self.tick_period, do_resume)

    def _resume_internal(self, job_id: str):
        self.guardians.pop(job_id, None)
        self.meta.update_status(job_id, JobStatus.RESUMED, "user resume")

    def _cancel_internal(self, job_id: str):
        g = self.guardians.get(job_id)
        if g is not None:
            g._fail("user cancelled")

    # ---------------------------------------------- observability helpers
    def _tenant_of_job(self, job_id: str) -> Optional[str]:
        """Bus tenant resolver: who owns this job? None while the
        metastore is unreachable — the event stays unstamped (admin-only
        visibility) rather than blocking the emitter."""
        try:
            rec = self.meta.get(job_id)
        except Exception:
            return None
        return rec.manifest.tenant if rec is not None else None

    def _meter_log_bytes(self, rec):
        tenant = self._tenant_of_job(rec.job_id)
        if tenant is not None:
            self.meter.bump(tenant, "log_bytes", len(rec.line))

    # chip-holding statuses: the gang's chips are reserved on hosts
    _BILLABLE = frozenset({JobStatus.DEPLOYING, JobStatus.DOWNLOADING,
                           JobStatus.PROCESSING, JobStatus.STORING})

    def _accrue_chip_seconds(self):
        """One tick of per-tenant chip-second accrual — the federation
        aggregates usage at exactly this cadence (FfDL §4 billing)."""
        for job_id in list(self.guardians):
            try:
                rec = self.meta.get(job_id)
            except Exception:
                break  # metastore down this round: bill nothing, not junk
            if rec is None or rec.status not in self._BILLABLE:
                continue
            self.meter.bump(rec.manifest.tenant, "chip_seconds",
                            gang_chips(rec.manifest) * self.tick_period)

    # ------------------------------------------------------------- engine
    def tick(self):
        # shard.tick interposition: an injected hang here wedges the shard
        # exactly like a gray failure would — the tick thread holds the
        # shard write lock, verbs bound their lock waits by deadline, and
        # Federation.tick's per-shard tick budget frees the ticker itself.
        self.faults.on("shard.tick", key=self.shard_id)
        self.ticks += 1
        self.clock.advance(self.tick_period)
        self.clock.run_until(self.clock.now())
        # Group-commit scope: every metastore status flip this round rides
        # one WAL write+flush at scope exit (durable before tick returns)
        # instead of one flush per update. User-facing submits come in via
        # the gateway outside this scope and keep durable-before-ack.
        with self.meta.batch():
            self.chaos.tick()
            self.cluster.tick()
            self.lcm.tick()
            for g in list(self.guardians.values()):
                g.tick()
            self.admission.tick()
            self.scheduler.tick()
        self.metrics.sample_utilization(self.cluster.utilization())
        self._accrue_chip_seconds()
        # GC finished guardians
        for job_id, g in list(self.guardians.items()):
            if g.stage == "GC_DONE":
                rec = self.meta.get(job_id)
                if rec.status in TERMINAL or rec.status == JobStatus.HALTED:
                    del self.guardians[job_id]

    def run_for(self, sim_seconds: float):
        n = int(sim_seconds / self.tick_period)
        for _ in range(n):
            self.tick()

    def run_until_terminal(self, job_ids, max_sim_s: float = 1e5) -> bool:
        """Tick until all jobs are COMPLETED/FAILED/HALTED. True if so."""
        deadline = self.clock.now() + max_sim_s
        watch = set(job_ids)
        while self.clock.now() < deadline:
            self.tick()
            done = all(
                self.meta.get(j) is not None and
                (self.meta.get(j).status in TERMINAL or
                 self.meta.get(j).status == JobStatus.HALTED)
                for j in watch)
            if done:
                return True
        return False
