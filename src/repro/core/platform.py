"""FfDLPlatform: the facade wiring all microservices together (FfDL Fig 1-2).

API-layer semantics reproduced:
  * ``submit`` validates, persists to the metastore **before acking** and
    returns a job id — jobs survive any subsequent component crash;
  * ``status``/``status_history`` read the metastore (user-visible,
    timestamped — the paper's billing/debugging requirement);
  * ``logs``/``search_logs`` read the ElasticSearch-like index;
  * ``halt``/``resume`` drive HALT/RESUME for hyperparameter workflows;
  * API replicas are stateless: ``api_crash``/``api_restart`` only gate the
    public methods (recovery-time benchmark).

``tick()`` is one platform scheduling round; ``run_until`` drives the
simulated clock. Components ticked in dependency order: chaos → cluster
(heartbeats/evictions) → LCM (reconcile) → guardians (deploy/monitor) →
admission (preemption) → scheduler (gang placement) → metrics.
"""

from __future__ import annotations

import itertools
from typing import Optional

from repro.core.admission import AdmissionController
from repro.core.chaos import ChaosConfig, ChaosMonkey
from repro.core.cluster import ClusterModel
from repro.core.executor import JobVolume
from repro.core.helpers import LogIndex, MetricsService
from repro.core.kvstore import EtcdLike
from repro.core.lcm import LifecycleManager
from repro.core.metastore import MetaStore
from repro.core.scheduler import GangScheduler, K8sDefaultScheduler
from repro.core.types import (
    EventLog,
    JobManifest,
    JobStatus,
    SimClock,
    TERMINAL,
)
from repro.data.objectstore import ObjectStore


class FfDLPlatform:
    def __init__(self, n_hosts: int = 16, chips_per_host: int = 4,
                 placement: str = "pack", scheduler: str = "gang",
                 chaos: Optional[ChaosConfig] = None, clock=None,
                 tick_period: float = 1.0, seed: int = 0,
                 objstore_bandwidth: Optional[float] = None):
        self.clock = clock or SimClock()
        self.tick_period = tick_period
        self.events = EventLog(self.clock)
        self.etcd = EtcdLike(self.clock, self.events)
        self.meta = MetaStore(self.clock)
        self.objstore = ObjectStore(clock=None,
                                    bandwidth_bps=objstore_bandwidth)
        self.objstore.create_bucket("datasets")
        self.objstore.create_bucket("results")
        self.cluster = ClusterModel(n_hosts, chips_per_host, self.clock,
                                    self.etcd, self.events)
        if scheduler == "gang":
            self.scheduler = GangScheduler(self.cluster, self.events,
                                           placement=placement, seed=seed)
        else:
            self.scheduler = K8sDefaultScheduler(self.cluster, self.events,
                                                 placement=placement,
                                                 seed=seed)
        self.admission = AdmissionController(self, self.events)
        self.lcm = LifecycleManager(self, self.events)
        self.chaos = ChaosMonkey(chaos or ChaosConfig(), self)
        self.metrics = MetricsService(self.clock)
        self.log_index = LogIndex()
        self.guardians: dict[str, object] = {}
        self.volumes: dict[str, JobVolume] = {}
        self._job_ctr = itertools.count(1)
        self._api_up = True

    # ---------------------------------------------------------------- API
    def _api_check(self):
        if not self._api_up:
            raise ConnectionError("API service unavailable")

    def api_crash(self):
        self._api_up = False

    def api_restart(self):
        self._api_up = True
        self.events.emit("api", "api_restarted")

    def submit(self, manifest: JobManifest) -> str:
        """Durable-before-ack submission (§3.2)."""
        self._api_check()
        if manifest.n_learners < 1 or manifest.chips_per_learner < 0:
            raise ValueError("invalid manifest")
        from repro.core.types import gang_chips
        if gang_chips(manifest) > self.cluster.total_chips:
            raise ValueError(
                f"job needs {gang_chips(manifest)} chips; cluster has "
                f"{self.cluster.total_chips}")
        ok, why = self.admission.check(manifest)
        if not ok:
            self.events.emit("api", "admission_rejected",
                             tenant=manifest.tenant, reason=why)
            raise PermissionError(f"admission denied: {why}")
        job_id = f"job-{next(self._job_ctr):05d}"
        self.meta.insert_job(job_id, manifest)  # durable BEFORE ack
        self.admission.mark(job_id, manifest)
        self.events.emit("api", "job_submitted", job=job_id,
                         tenant=manifest.tenant)
        return job_id

    def status(self, job_id: str) -> JobStatus:
        self._api_check()
        rec = self.meta.get(job_id)
        if rec is None:
            raise KeyError(job_id)
        return rec.status

    def status_history(self, job_id: str) -> list:
        self._api_check()
        return list(self.meta.get(job_id).status_history)

    def logs(self, job_id: str) -> list[str]:
        self._api_check()
        return self.log_index.stream(job_id)

    def search_logs(self, query: str, job_id: Optional[str] = None):
        self._api_check()
        return self.log_index.search(query, job_id)

    def halt(self, job_id: str, requeue: bool = False):
        """HALT: checkpoint and stop; optionally auto-resume (preemption)."""
        self._api_check()
        g = self.guardians.get(job_id)
        if g is not None:
            g.halt()
        else:
            self.meta.update_status(job_id, JobStatus.HALTED, "halted")
        if requeue:
            # preempted jobs go back through the queue automatically
            def do_resume(job_id=job_id):
                rec = self.meta.get(job_id)
                if rec is not None and rec.status == JobStatus.HALTED:
                    self.resume(job_id)
            self.clock.call_later(3 * self.tick_period, do_resume)

    def resume(self, job_id: str):
        """RESUME a HALTED job: fresh deployment, learners restore from the
        latest checkpoint automatically."""
        rec = self.meta.get(job_id)
        if rec is None or rec.status != JobStatus.HALTED:
            raise ValueError(f"{job_id} is not HALTED")
        self.guardians.pop(job_id, None)
        self.meta.update_status(job_id, JobStatus.RESUMED, "user resume")

    def cancel(self, job_id: str):
        self._api_check()
        g = self.guardians.get(job_id)
        if g is not None:
            g._fail("user cancelled")

    # ------------------------------------------------------------- engine
    def tick(self):
        self.clock.advance(self.tick_period)
        self.clock.run_until(self.clock.now())
        self.chaos.tick()
        self.cluster.tick()
        self.lcm.tick()
        for g in list(self.guardians.values()):
            g.tick()
        self.admission.tick()
        self.scheduler.tick()
        self.metrics.sample_utilization(self.cluster.utilization())
        # GC finished guardians
        for job_id, g in list(self.guardians.items()):
            if g.stage == "GC_DONE":
                rec = self.meta.get(job_id)
                if rec.status in TERMINAL or rec.status == JobStatus.HALTED:
                    del self.guardians[job_id]

    def run_for(self, sim_seconds: float):
        n = int(sim_seconds / self.tick_period)
        for _ in range(n):
            self.tick()

    def run_until_terminal(self, job_ids, max_sim_s: float = 1e5) -> bool:
        """Tick until all jobs are COMPLETED/FAILED/HALTED. True if so."""
        deadline = self.clock.now() + max_sim_s
        watch = set(job_ids)
        while self.clock.now() < deadline:
            self.tick()
            done = all(
                self.meta.get(j) is not None and
                (self.meta.get(j).status in TERMINAL or
                 self.meta.get(j).status == JobStatus.HALTED)
                for j in watch)
            if done:
                return True
        return False
