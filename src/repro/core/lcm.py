"""Lifecycle Manager (FfDL §3.3): owns jobs from submission to completion.

The LCM is deliberately thin — it stores no per-job deployment state (that's
the Guardian's job, precisely so the LCM isn't a single point of failure).
Its tick reconciles the metastore against the set of live guardians: any
PENDING/RESUMED job without a guardian gets one. Because reconciliation is
metadata-driven, an LCM crash loses nothing: the replacement replays the
same scan (the paper's 'submitted jobs are never lost' property).
"""

from __future__ import annotations

from typing import Optional

from repro.core.guardian import Guardian
from repro.core.types import EventLog, JobStatus, TERMINAL


class LifecycleManager:
    GUARDIAN_CREATE_LATENCY = 1.5  # "less than 3s in our experiments"

    def __init__(self, platform, events: EventLog):
        self.p = platform
        self.events = events
        self.alive = True
        self._creating: set[str] = set()

    def crash(self):
        self.alive = False
        self._creating = set()  # in-flight creations lost; reconcile redoes

    def restart(self):
        self.alive = True
        self.events.emit("lcm", "lcm_restarted")

    def tick(self):
        if not self.alive:
            return
        for rec in self.p.meta.jobs():
            if rec.status in TERMINAL or rec.status == JobStatus.HALTED:
                continue
            if rec.job_id in self.p.guardians or rec.job_id in self._creating:
                continue
            self._creating.add(rec.job_id)
            job_id = rec.job_id

            def create(job_id=job_id):
                self._creating.discard(job_id)
                if job_id in self.p.guardians:
                    return  # idempotent: double-create is a no-op
                rec2 = self.p.meta.get(job_id)
                if rec2 is None or rec2.status in TERMINAL or \
                        rec2.status == JobStatus.HALTED:
                    return
                g = Guardian(job_id, rec2.manifest, platform=self.p)
                self.p.guardians[job_id] = g
                self.events.emit("lcm", "guardian_created", job=job_id)

            self.p.clock.call_later(self.GUARDIAN_CREATE_LATENCY, create)
