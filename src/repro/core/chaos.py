"""Chaos engineering hooks (FfDL §6 cites Simian Army / failure-as-a-service;
§5.6 reports the real fault distribution).

``ChaosMonkey`` injects, deterministically (seeded), every failure class the
paper observed: learner process crashes, node NotReady, guardian crashes,
helper/controller crashes, etcd/metastore blips, object-store faults, and
volume-provisioning failures, at configurable rates. Benchmarks/failures.py
drives a long campaign and aggregates the event log into the paper's
Table 8 / Fig 7-8 analysis.

``ChaosConfig`` remains the compat shim for the probabilistic kill/fault
rates, but the *point-failure* paths (volume provisioning, object-store
faults) now ride the unified fault-injection registry
(:class:`repro.core.faults.FaultPlane`): an admin-installed plan on
``volume.provision`` or ``objstore.*`` composes with the probability
draws below. The monkey's own RNG stream is untouched — draw order and
count are identical with or without a plane attached — so seeded
campaigns reproduce bit-for-bit (``benchmarks/failures.py`` output is
unchanged).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class ChaosConfig:
    seed: int = 0
    # per-tick probabilities (simulation granularity)
    p_learner_crash: float = 0.0
    p_host_fail: float = 0.0
    p_guardian_crash: float = 0.0
    p_controller_crash: float = 0.0
    p_volume_fail: float = 0.0   # per provisioning attempt
    p_objstore_fail: float = 0.0
    host_recovery_s: float = 120.0  # NotReady hosts reboot after this


class ChaosMonkey:
    def __init__(self, cfg: ChaosConfig, platform):
        self.cfg = cfg
        self.p = platform
        self.rng = np.random.default_rng(cfg.seed)
        self.enabled = True
        self._downed_hosts: dict[str, float] = {}

    def should_fail(self, kind: str, _key: str) -> bool:
        """Point-failure queries (e.g. volume provisioning in the Guardian).

        The probability draw stays on the monkey's own RNG stream (same
        draw order/count as before the fault plane existed), then the
        shared registry gets a say: an installed ``volume.provision``
        plan can force the failure deterministically.
        """
        if not self.enabled:
            return False
        if kind == "volume_provision":
            hit = bool(self.rng.random() < self.cfg.p_volume_fail)
            plane = getattr(self.p, "faults", None)
            if not hit and plane is not None:
                hit = plane.should_fail("volume.provision", key=_key)
            return hit
        return False

    def tick(self):
        if not self.enabled:
            return
        cfg, rng, p = self.cfg, self.rng, self.p
        # learner crashes
        if cfg.p_learner_crash > 0:
            for g in list(p.guardians.values()):
                if g.stage != "MONITOR":
                    continue
                for k, pod in enumerate(g.pods):
                    if pod.phase.value == "Running" and \
                            rng.random() < cfg.p_learner_crash:
                        rt = g.runtimes.get(k)
                        if rt is not None:
                            rt.kill()
                        p.cluster.fail_pod(pod.name, reason="chaos")
                        p.events.emit("chaos", "learner_killed",
                                      job=g.job_id, learner=k)
        # host failures
        if cfg.p_host_fail > 0:
            for hid, host in p.cluster.hosts.items():
                if host.ready and hid not in self._downed_hosts and \
                        rng.random() < cfg.p_host_fail:
                    p.cluster.fail_host(hid)
                    self._downed_hosts[hid] = p.clock.now()
                    p.events.emit("chaos", "host_killed", host=hid)
        # host recoveries
        for hid, t0 in list(self._downed_hosts.items()):
            if p.clock.now() - t0 >= cfg.host_recovery_s:
                p.cluster.recover_host(hid)
                del self._downed_hosts[hid]
        # guardian crashes (K8s restarts them next tick)
        if cfg.p_guardian_crash > 0:
            for g in list(p.guardians.values()):
                if g.alive and g.stage != "GC_DONE" and \
                        rng.random() < cfg.p_guardian_crash:
                    g.crash()
                    p.clock.call_later(2.0, g.restart)
        # controller crashes
        if cfg.p_controller_crash > 0:
            for g in list(p.guardians.values()):
                if g.controller is not None and g.controller.alive and \
                        rng.random() < cfg.p_controller_crash:
                    g.controller.crash()
                    p.events.emit("chaos", "controller_killed", job=g.job_id)
                    p.clock.call_later(3.5, g.controller.restart)
        # object-store faults: the draw stays on the monkey's stream; the
        # injection itself rides the unified registry (one-shot plan on
        # the next objstore op) when a plane is attached, falling back to
        # the legacy fail_next counter otherwise
        if cfg.p_objstore_fail > 0 and rng.random() < cfg.p_objstore_fail:
            plane = getattr(p, "faults", None)
            if plane is not None:
                plane.install("objstore.*", key=p.objstore.fault_key,
                              error="chaos object-store fault",
                              mode="one_shot")
            else:
                p.objstore.fail_next = 1
