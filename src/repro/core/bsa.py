"""Biased Sampling Algorithm (BSA) for gang placement (FfDL §3.5).

The paper (citing Tantawi [43, 44]): the gang placement problem is an
assignment of logical entities (pods) to physical entities (nodes) under
resource constraints with an objective (pack GPUs); the solution space is
combinatorially explosive, so BSA *importance-samples* candidate nodes with
a bias toward nodes that both satisfy the constraints and optimize the
objective, then keeps the best sampled assignment.

Our TPU adaptation keeps the algorithm shape — filter → bias → sample →
score → best-of-restarts — and adds an ICI-locality term to the objective:
a gang packed onto torus-adjacent hosts forms a contiguous mesh slice,
which is the TPU analogue of FfDL's communication-cost motivation for PACK.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.cluster import Host, torus_distance


@dataclass
class Placement:
    host_ids: list  # host id per pod (len == n_pods)
    score: float


def _bias_weights(hosts: Sequence[Host], free: np.ndarray, demand: int,
                  policy: str, chosen_coords: list, torus: tuple) -> np.ndarray:
    """Sampling bias per host for the next pod of the gang."""
    fits = (free >= demand).astype(np.float64)
    if policy == "pack":
        # Prefer hosts already partially used (small free), and hosts close
        # to already-placed gang members on the torus.
        used_frac = 1.0 - free / np.maximum(
            np.array([h.n_chips for h in hosts], dtype=np.float64), 1)
        w = fits * (0.25 + used_frac)
        if chosen_coords:
            d = np.array([
                min(torus_distance(h.coord, c, torus) for c in chosen_coords)
                for h in hosts], dtype=np.float64)
            w = w * (1.0 / (1.0 + d))
    elif policy == "spread":
        w = fits * (free + 1e-9)
        if chosen_coords:
            # spread avoids reusing hosts the gang already occupies
            occupied = {c for c in chosen_coords}
            for i, h in enumerate(hosts):
                if h.coord in occupied:
                    w[i] *= 0.05
    else:
        raise ValueError(policy)
    return w


def _score(hosts: Sequence[Host], free_after: np.ndarray,
           assignment: list, policy: str, torus: tuple) -> float:
    """Objective for a complete assignment (higher is better)."""
    used_idx = sorted(set(assignment))
    if policy == "pack":
        # (a) few distinct hosts; (b) little leftover fragmentation on the
        # touched hosts; (c) tight on the torus.
        n_hosts = len(used_idx)
        frag = float(sum(free_after[i] for i in used_idx))
        coords = [hosts[i].coord for i in used_idx]
        span = 0.0
        if len(coords) > 1:
            span = sum(torus_distance(a, b, torus)
                       for a in coords for b in coords) / (len(coords) ** 2)
        return -(3.0 * n_hosts + frag + span)
    # spread: many distinct hosts, balanced load
    return float(len(used_idx)) - float(np.std(free_after))


def bsa_place(hosts: Sequence[Host], n_pods: int, chips_per_pod: int,
              policy: str = "pack", torus: tuple = (1, 1),
              samples: int = 8, rng: Optional[np.random.Generator] = None,
              ) -> Optional[list]:
    """Place a gang of ``n_pods`` x ``chips_per_pod`` onto ``hosts``.

    Returns host_id per pod, or None if no feasible assignment was found.
    Deterministic for a given rng state.
    """
    if not hosts:
        return None
    rng = rng or np.random.default_rng(0)
    base_free = np.array([h.free_chips for h in hosts], dtype=np.int64)
    if int((base_free // max(chips_per_pod, 1)).sum()) < n_pods:
        return None  # quick infeasibility check

    best: Optional[Placement] = None
    for _ in range(max(samples, 1)):
        free = base_free.copy()
        assignment: list = []
        coords: list = []
        ok = True
        for _pod in range(n_pods):
            w = _bias_weights(hosts, free, chips_per_pod, policy, coords,
                              torus)
            total = w.sum()
            if total <= 0:
                ok = False
                break
            idx = int(rng.choice(len(hosts), p=w / total))
            assignment.append(idx)
            coords.append(hosts[idx].coord)
            free[idx] -= chips_per_pod
        if not ok:
            continue
        s = _score(hosts, free, assignment, policy, torus)
        if best is None or s > best.score:
            best = Placement([hosts[i].host_id for i in assignment], s)
    # Greedy fallback: first-fit-decreasing by the bias, in case sampling
    # repeatedly dead-ends on a feasible instance.
    if best is None:
        free = base_free.copy()
        assignment = []
        order = np.argsort(-free) if policy == "spread" else np.argsort(free)
        for _pod in range(n_pods):
            placed = False
            for i in order:
                if free[i] >= chips_per_pod and hosts[i].schedulable:
                    free[i] -= chips_per_pod
                    assignment.append(hosts[i].host_id)
                    placed = True
                    break
            if not placed:
                return None
        return assignment
    return best.host_ids
