"""Guardian: per-job delegate for atomic deployment + monitoring (FfDL §3.3).

"The LCM launches a delegate for atomic deployment and further monitoring of
each DL job. [...] If the Guardian crashes in the middle of a job
deployment, K8s is guaranteed to restart it. The restarted Guardian will
roll back the previous partially deployed DL job and start a fresh
deployment process. In the presence of persistent failures, this process
will be repeated for a (configurable) number of times before the Guardian
gives up and marks the DL job in MongoDB as FAILED."

Deployment step machine (one step per tick, each can fail/crash):
  VOLUME → CREDS → SCHEDULE → CREATE_PODS → WAIT_RUNNING → MONITOR

Monitoring aggregates per-learner etcd statuses into the job status
(metastore), restarts crashed learners (stateful-set semantics, resume from
checkpoint), re-places evicted learners after node failures (elastic
recovery), and garbage-collects everything at completion.
"""

from __future__ import annotations

import json
from typing import Optional

from repro.core.controller import Controller
from repro.core.executor import JobVolume, LearnerContext, make_learner
from repro.core.helpers import LogCollector
from repro.core.scheduler import GangRequest
from repro.core.types import (
    EventLog,
    JobManifest,
    JobStatus,
    Pod,
    PodPhase,
    TERMINAL,
)

DEPLOY_STAGES = ["VOLUME", "CREDS", "SCHEDULE", "CREATE_PODS",
                 "WAIT_RUNNING", "MONITOR", "GC_DONE"]


class Guardian:
    STAGE_LATENCY = {"VOLUME": 2.0, "CREDS": 1.0, "CREATE_PODS": 1.0}

    def __init__(self, job_id: str, manifest: JobManifest, *, platform):
        self.job_id = job_id
        self.manifest = manifest
        self.p = platform  # wiring: cluster, scheduler, etcd, meta, ...
        self.stage = "VOLUME"
        self.alive = True
        self.volume: Optional[JobVolume] = None
        self.gang: Optional[GangRequest] = None
        self.pods: list[Pod] = []
        self.helper_pod: Optional[Pod] = None
        self.controller: Optional[Controller] = None
        self.collector: Optional[LogCollector] = None
        self.runtimes: dict[int, object] = {}  # learner idx → runtime
        self._stage_entered = platform.clock.now()
        self._halt_requested = False
        self._was_restarted = False
        # straggler tracking: learner idx → (progress value, last-change ts)
        self._progress: dict[int, tuple] = {}

    # -- crash semantics ---------------------------------------------------
    def crash(self):
        self.alive = False
        self.p.events.emit("guardian", "guardian_crashed", job=self.job_id)

    def restart(self):
        """K8s Job restart. Mid-deploy → rollback + fresh deploy."""
        self.alive = True
        self._was_restarted = True
        self.p.events.emit("guardian", "guardian_restarted", job=self.job_id)
        if self.stage not in ("MONITOR", "GC_DONE"):
            rec = self.p.meta.get(self.job_id)
            rec.deploy_retries += 1
            if rec.deploy_retries > self.manifest.max_deploy_retries:
                self._fail("deploy retries exhausted")
                return
            self._rollback()
            self.stage = "VOLUME"
            self._stage_entered = self.p.clock.now()

    def _rollback(self):
        """Undo a partial deployment: no zombies, no leaked chips."""
        for pod in self.pods:
            self.p.cluster.delete_pod(pod.name, reason="rollback")
        if self.helper_pod is not None:
            self.p.cluster.delete_pod(self.helper_pod.name, reason="rollback")
        self.pods = []
        self.helper_pod = None
        self.runtimes = {}
        self.p.scheduler.release(self.job_id)
        self.gang = None
        if self.volume is not None:
            self.volume.provisioned = False
            self.volume = None
        self.p.events.emit("guardian", "rollback", job=self.job_id)

    # -- terminal transitions ---------------------------------------------
    def _fail(self, msg: str):
        self._teardown()
        self.p.meta.update_status(self.job_id, JobStatus.FAILED, msg)
        rec = self.p.meta.get(self.job_id)
        rec.finished_at = self.p.clock.now()
        self.p.events.emit("guardian", "job_failed", job=self.job_id, msg=msg)
        self.stage = "GC_DONE"

    def _complete(self):
        self._teardown()
        self.p.meta.update_status(self.job_id, JobStatus.COMPLETED, "done")
        rec = self.p.meta.get(self.job_id)
        rec.finished_at = self.p.clock.now()
        self.p.events.emit("guardian", "job_completed", job=self.job_id)
        self.stage = "GC_DONE"

    def halt(self):
        """User/AC-initiated HALT: checkpoint boundary is the learner's
        latest checkpoint; pods stop, chips free, job resumable."""
        self._halt_requested = True

    def _do_halt(self):
        self._teardown()
        self.p.meta.update_status(self.job_id, JobStatus.HALTED, "halted")
        self.p.events.emit("guardian", "job_halted", job=self.job_id)
        self.stage = "GC_DONE"
        self._halt_requested = False

    def _teardown(self):
        """GC: pods deleted, gang released, job's etcd data erased (§3.2)."""
        for pod in self.pods:
            self.p.cluster.delete_pod(pod.name, reason="gc")
        if self.helper_pod is not None:
            self.p.cluster.delete_pod(self.helper_pod.name, reason="gc")
        self.p.scheduler.release(self.job_id)
        self.p.etcd.delete_prefix(f"/jobs/{self.job_id}/")
        self.runtimes = {}

    # -- deployment step machine -------------------------------------------
    def tick(self):
        if not self.alive or self.stage == "GC_DONE":
            return
        if self._halt_requested and self.stage == "MONITOR":
            self._do_halt()
            return
        handler = getattr(self, f"_stage_{self.stage.lower()}")
        handler()

    def _stage_elapsed(self) -> float:
        return self.p.clock.now() - self._stage_entered

    def _advance(self, stage: str):
        self.stage = stage
        self._stage_entered = self.p.clock.now()

    def _stage_volume(self):
        self.p.meta.update_status(self.job_id, JobStatus.DEPLOYING,
                                  "provisioning volume")
        if self._stage_elapsed() < self.STAGE_LATENCY["VOLUME"]:
            return
        if self.p.chaos.should_fail("volume_provision", self.job_id):
            self.p.events.emit("guardian", "volume_provision_failed",
                               job=self.job_id,
                               reason="persistentvolumeclaim not found")
            rec = self.p.meta.get(self.job_id)
            rec.deploy_retries += 1
            if rec.deploy_retries > self.manifest.max_deploy_retries:
                self._fail("volume provisioning failed")
            self._stage_entered = self.p.clock.now()  # retry
            return
        self.volume = self.p.volumes.setdefault(self.job_id,
                                                JobVolume(self.job_id))
        self.volume.provisioned = True
        self._advance("CREDS")

    def _stage_creds(self):
        if self._stage_elapsed() < self.STAGE_LATENCY["CREDS"]:
            return
        # bind per-tenant credentials for data/results buckets
        self.volume.write(".creds", json.dumps({
            "tenant": self.manifest.tenant,
            "data": self.manifest.data_bucket,
            "results": self.manifest.results_bucket}))
        self._advance("SCHEDULE")

    def _stage_schedule(self):
        if self.gang is None:
            self.gang = GangRequest(
                job_id=self.job_id, n_pods=self.manifest.n_learners,
                chips_per_pod=self.manifest.chips_per_learner,
                submitted_at=self.p.clock.now())
            self.p.scheduler.submit(self.gang)
            self.p.meta.update_status(self.job_id, JobStatus.QUEUED,
                                      "waiting for gang placement")
        if self.gang.placement is not None:
            rec = self.p.meta.get(self.job_id)
            if rec.scheduled_at is None:
                rec.scheduled_at = self.p.clock.now()
            self._advance("CREATE_PODS")

    def _stage_create_pods(self):
        if self._stage_elapsed() < self.STAGE_LATENCY["CREATE_PODS"]:
            return
        self.p.meta.update_status(self.job_id, JobStatus.DEPLOYING,
                                  "creating pods")
        ok = True
        for k, host in enumerate(self.gang.placement):
            pod = Pod(name=f"{self.job_id}-l{k}", job_id=self.job_id,
                      kind="learner", chips=self.manifest.chips_per_learner)
            if not self.p.cluster.bind_pod(pod, host):
                ok = False
                break
            self.pods.append(pod)
        if ok:
            helper = Pod(name=f"{self.job_id}-helper", job_id=self.job_id,
                         kind="helper", chips=0)
            # helper rides on the first learner's host (no chips needed)
            ok = self.p.cluster.bind_pod(helper, self.gang.placement[0])
            if ok:
                self.helper_pod = helper
        if not ok:
            # binding race (e.g. host died between placement and bind):
            # roll back and retry the whole deployment — atomicity.
            self.p.events.emit("guardian", "bind_failed", job=self.job_id)
            rec = self.p.meta.get(self.job_id)
            rec.deploy_retries += 1
            if rec.deploy_retries > self.manifest.max_deploy_retries:
                self._fail("pod binding failed repeatedly")
                return
            self._rollback()
            self._advance("VOLUME")
            return
        self.p.scheduler.confirm(self.job_id)
        # helper containers: controller + log collector
        self.controller = Controller(self.job_id, self.manifest.n_learners,
                                     self.volume, self.p.etcd, self.p.clock,
                                     self.p.events)
        self.collector = LogCollector(self.job_id, self.manifest.n_learners,
                                      self.volume, self.p.log_index,
                                      self.p.clock)
        self._advance("WAIT_RUNNING")

    def _stage_wait_running(self):
        if any(p.phase == PodPhase.FAILED for p in self.pods):
            self._rollback()
            self._advance("VOLUME")
            return
        if all(p.phase == PodPhase.RUNNING for p in self.pods) and \
                self.helper_pod.phase == PodPhase.RUNNING:
            for k, pod in enumerate(self.pods):
                self._spawn_runtime(k)
            self.p.meta.update_status(self.job_id, JobStatus.DOWNLOADING,
                                      "learners starting")
            self._advance("MONITOR")

    def _spawn_runtime(self, k: int, resume: bool = False):
        ctx = LearnerContext(
            job_id=self.job_id, learner_idx=k, manifest=self.manifest,
            volume=self.volume, clock=self.p.clock, events=self.p.events,
            objstore=self.p.objstore)
        rt = make_learner(ctx)
        self.runtimes[k] = rt
        rt.start(resume=resume)

    # -- monitoring ---------------------------------------------------------
    def _stage_monitor(self):
        # drive learner runtimes for pods that are Running
        for k, pod in enumerate(self.pods):
            rt = self.runtimes.get(k)
            if pod.phase == PodPhase.RUNNING and rt is not None:
                rt.tick()
        if self.controller:
            self.controller.tick()
        if self.collector:
            self.collector.tick()

        statuses = {}
        exits = {}
        try:
            for k in range(self.manifest.n_learners):
                st = self.p.etcd.get(f"/jobs/{self.job_id}/learners/{k}/status")
                ex = self.p.etcd.get(f"/jobs/{self.job_id}/learners/{k}/exit")
                if st:
                    statuses[k] = st
                if ex:
                    exits[k] = ex
        except ConnectionError:
            return  # etcd blip; keep last known state (resilience by design)

        # learner process failures (non-zero exit) → stateful-set restart
        for k, ex in exits.items():
            if ex["code"] != 0:
                pod = self.pods[k]
                rec = self.p.meta.get(self.job_id)
                rec.restarts += 1
                if rec.restarts > self.manifest.max_restarts:
                    self._fail(f"learner {k} failed (exit {ex['code']}) too "
                               "many times")
                    return
                self.p.events.emit("guardian", "learner_restart",
                                   job=self.job_id, learner=k,
                                   code=ex["code"])
                # clear stale exit/status, restart pod in place, resume
                self.volume.files.pop(f"exit/learner-{k}", None)
                self.p.etcd.delete(f"/jobs/{self.job_id}/learners/{k}/exit")
                self.p.cluster.restart_pod(pod.name)
                self._spawn_runtime(k, resume=True)
                self.p.meta.update_status(self.job_id, JobStatus.RESUMED,
                                          f"learner {k} restarted")
                return

        # evicted pods (node failure) → re-place on healthy hosts
        missing = [k for k, pod in enumerate(self.pods)
                   if pod.phase == PodPhase.DELETED]
        if missing:
            self._recover_evicted(missing)
            return

        # crashed-but-not-exited learner pods → restart (stateful set)
        for k, pod in enumerate(self.pods):
            if pod.phase == PodPhase.FAILED:
                rec = self.p.meta.get(self.job_id)
                rec.restarts += 1
                if rec.restarts > self.manifest.max_restarts:
                    self._fail(f"learner {k} pod crashed too many times")
                    return
                self.p.cluster.restart_pod(pod.name)
                self._spawn_runtime(k, resume=True)
                self.p.meta.update_status(self.job_id, JobStatus.RESUMED,
                                          f"learner {k} pod restarted")
                return

        # straggler mitigation (beyond-paper, DESIGN.md §2 scale-out):
        # a learner whose progress metric stalls while a peer advances is
        # restarted (resume-from-checkpoint), catching degraded-but-alive
        # nodes that exit-code monitoring misses.
        if self.manifest.straggler_timeout_s > 0 and \
                len(statuses) == self.manifest.n_learners:
            if self._check_stragglers(statuses):
                return

        # aggregate job status (paper: Guardian aggregates learner statuses)
        if exits and all(ex.get("code") == 0 for ex in exits.values()) \
                and len(exits) == self.manifest.n_learners:
            self._complete()
            return
        agg = self._aggregate(statuses)
        if agg is not None:
            self.p.meta.update_status(self.job_id, agg, "")
            rec = self.p.meta.get(self.job_id)
            rec.progress_step = max(
                (s.get("step", 0) for s in statuses.values()), default=0)

    def _check_stragglers(self, statuses: dict) -> bool:
        """Detect and restart stalled learners. True if one was restarted."""
        now = self.p.clock.now()
        advanced = False
        stalled: list = []
        for k, st in statuses.items():
            if st.get("status") != "PROCESSING":
                self._progress.pop(k, None)
                continue
            metric = st.get("step", 0) or st.get("progress", 0.0)
            prev = self._progress.get(k)
            if prev is None or metric > prev[0]:
                self._progress[k] = (metric, now)
                advanced = advanced or prev is not None
            elif now - prev[1] >= self.manifest.straggler_timeout_s:
                stalled.append(k)
        if not stalled or len(stalled) == len(statuses):
            return False  # nobody stalled, or global stall (not a straggler)
        k = stalled[0]
        rec = self.p.meta.get(self.job_id)
        rec.restarts += 1
        if rec.restarts > self.manifest.max_restarts:
            self._fail(f"straggler learner {k} exhausted restart budget")
            return True
        self.p.events.emit("guardian", "straggler_restart", job=self.job_id,
                           learner=k)
        self._progress.pop(k, None)
        self.p.cluster.restart_pod(self.pods[k].name)
        self._spawn_runtime(k, resume=True)
        self.p.meta.update_status(self.job_id, JobStatus.RESUMED,
                                  f"straggler learner {k} restarted")
        return True

    def _aggregate(self, statuses: dict) -> Optional[JobStatus]:
        if not statuses:
            return None
        vals = [s["status"] for s in statuses.values()]
        for stage in ("FAILED", "DOWNLOADING", "PROCESSING", "STORING"):
            if any(v == stage for v in vals):
                if stage == "FAILED":
                    return None  # handled via exit codes
                return JobStatus(stage)
        if all(v == "COMPLETED" for v in vals):
            return JobStatus.STORING  # final aggregation happens via exits
        return None

    def _recover_evicted(self, missing: list):
        """Node-failure recovery: re-place evicted learners on healthy hosts
        (elastic), falling back to full gang redeploy if infeasible."""
        rec = self.p.meta.get(self.job_id)
        rec.restarts += 1
        if rec.restarts > self.manifest.max_restarts:
            self._fail("node failures exhausted restart budget")
            return
        from repro.core.bsa import bsa_place
        views = self.p.scheduler._host_views()
        assignment = bsa_place(views, len(missing),
                               self.manifest.chips_per_learner,
                               policy=self.p.scheduler.placement,
                               torus=self.p.cluster.torus,
                               rng=self.p.scheduler.rng)
        if assignment is None:
            # no capacity: full redeploy through the queue (gang semantics)
            self.p.events.emit("guardian", "gang_requeue", job=self.job_id)
            self._rollback()
            self._advance("VOLUME")
            return
        for k, host in zip(missing, assignment):
            pod = Pod(name=f"{self.job_id}-l{k}", job_id=self.job_id,
                      kind="learner", chips=self.manifest.chips_per_learner)
            if not self.p.cluster.bind_pod(pod, host):
                self._rollback()
                self._advance("VOLUME")
                return
            self.pods[k] = pod
            self.gang.placement[k] = host
            self.volume.files.pop(f"exit/learner-{k}", None)
            self.p.etcd.delete(f"/jobs/{self.job_id}/learners/{k}/exit")
            self._spawn_runtime(k, resume=True)
        # helper pod may have been evicted with the host — recreate it
        if self.helper_pod is not None and \
                self.helper_pod.phase == PodPhase.DELETED:
            helper = Pod(name=f"{self.job_id}-helper", job_id=self.job_id,
                         kind="helper", chips=0)
            if self.p.cluster.bind_pod(helper, self.gang.placement[0]):
                self.helper_pod = helper
        self.p.events.emit("guardian", "learners_replaced", job=self.job_id,
                           learners=missing)
        self.p.meta.update_status(self.job_id, JobStatus.RESUMED,
                                  f"learners {missing} re-placed after node "
                                  "failure")
