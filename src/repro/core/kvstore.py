"""EtcdLike: the coordination store (FfDL §3.2).

The paper: "We preferred etcd over MongoDB for coordination because it is
much faster and has some abstractions that MongoDB lacks, like leases on
keys and fine grained support for streaming watches at the level of a
single key." Data is small (<1KB), short-lived, erased when the job ends.

Semantics implemented (the subset FfDL relies on):
  * get / put / delete with per-key mod revision,
  * compare-and-swap (txn-lite),
  * TTL leases — keys attached to a lease vanish when it expires unless
    refreshed (the heartbeat/failure-detection primitive),
  * prefix watches — callbacks on put/delete under a prefix (the
    controller → Guardian status pipeline),
  * per-tenant namespacing (multi-tenancy isolation contract).

Replicated-etcd crash tolerance is modeled by ``crash()``/``restart()``
keeping data intact (Raft majority survives a member crash); benchmarks use
this for the recovery-time table.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional


@dataclass
class _Entry:
    value: Any
    revision: int
    lease_id: Optional[int] = None


@dataclass
class _Lease:
    ttl: float
    expires_at: float
    keys: set = field(default_factory=set)


class EtcdLike:
    def __init__(self, clock, events=None):
        self.clock = clock
        self.events = events
        self._data: dict[str, _Entry] = {}
        self._leases: dict[int, _Lease] = {}
        self._watches: list[tuple[str, Callable]] = []
        self._rev = 0
        self._lease_ctr = 0
        self.available = True

    # -- availability (chaos) ------------------------------------------
    def _check(self):
        if not self.available:
            raise ConnectionError("etcd unavailable")

    def crash(self):
        self.available = False

    def restart(self):
        self.available = True

    # -- leases ----------------------------------------------------------
    def grant_lease(self, ttl: float) -> int:
        self._check()
        self._lease_ctr += 1
        self._leases[self._lease_ctr] = _Lease(
            ttl=ttl, expires_at=self.clock.now() + ttl)
        return self._lease_ctr

    def keepalive(self, lease_id: int) -> bool:
        self._check()
        lease = self._leases.get(lease_id)
        if lease is None:
            return False
        lease.expires_at = self.clock.now() + lease.ttl
        return True

    def sweep_leases(self):
        """Expire leases; called by the platform tick."""
        now = self.clock.now()
        dead = [lid for lid, l in self._leases.items() if l.expires_at <= now]
        for lid in dead:
            lease = self._leases.pop(lid)
            for key in list(lease.keys):
                self._delete(key, expired=True)

    # -- kv ----------------------------------------------------------------
    def put(self, key: str, value: Any, lease_id: Optional[int] = None):
        self._check()
        self._rev += 1
        old = self._data.get(key)
        if old is not None and old.lease_id and old.lease_id in self._leases:
            self._leases[old.lease_id].keys.discard(key)
        self._data[key] = _Entry(value, self._rev, lease_id)
        if lease_id is not None and lease_id in self._leases:
            self._leases[lease_id].keys.add(key)
        self._notify(key, "put", value)

    def get(self, key: str, default=None):
        self._check()
        e = self._data.get(key)
        return e.value if e is not None else default

    def revision(self, key: str) -> Optional[int]:
        e = self._data.get(key)
        return e.revision if e else None

    def cas(self, key: str, expect_revision: Optional[int], value: Any) -> bool:
        """Put iff the key's mod revision matches (None = must not exist)."""
        self._check()
        cur = self._data.get(key)
        cur_rev = cur.revision if cur else None
        if cur_rev != expect_revision:
            return False
        self.put(key, value)
        return True

    def delete(self, key: str):
        self._check()
        self._delete(key)

    def _delete(self, key: str, expired: bool = False):
        e = self._data.pop(key, None)
        if e is None:
            return
        if e.lease_id and e.lease_id in self._leases:
            self._leases[e.lease_id].keys.discard(key)
        self._notify(key, "expired" if expired else "delete", None)

    def prefix(self, prefix: str) -> dict[str, Any]:
        self._check()
        return {k: e.value for k, e in self._data.items()
                if k.startswith(prefix)}

    def delete_prefix(self, prefix: str):
        self._check()
        for k in [k for k in self._data if k.startswith(prefix)]:
            self._delete(k)

    # -- watches -------------------------------------------------------
    def watch(self, prefix: str, fn: Callable[[str, str, Any], None]):
        """fn(key, op, value) on every put/delete/expire under prefix."""
        self._watches.append((prefix, fn))
        return lambda: self._watches.remove((prefix, fn))

    def _notify(self, key: str, op: str, value):
        for prefix, fn in list(self._watches):
            if key.startswith(prefix):
                fn(key, op, value)
