"""Helper services: log collection and the Training Metrics Service.

FfDL §3.2: "The Training Metrics Service is responsible for collecting
metrics about both the training jobs and FfDL microservices [...] It also
helps in streaming training logs from jobs to be indexed and stored in
ElasticSearch/Kibana."

``LogCollector`` streams learner log files off the job volume into the
searchable ``LogIndex`` (the ElasticSearch analogue), with gap-free resume
after collector crashes (offset bookkeeping — the 'surprisingly challenging'
§4 lesson). ``MetricsService`` aggregates job metrics and microservice
failure/recovery counters.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Optional

from repro.core.executor import JobVolume


@dataclass
class LogRecord:
    ts: float
    job_id: str
    learner: int
    line: str


class LogIndex:
    """ElasticSearch-like: append + substring search, per-job streams.

    Both streams and searches are append-only, so integer offsets make
    stable pagination cursors: a page served earlier never shifts when new
    records arrive (they only land past every existing cursor). The
    API gateway serves its ``logs``/``search_logs`` pages from
    ``stream_page``/``search_page``.
    """

    def __init__(self):
        self.records: list[LogRecord] = []
        self._by_job: dict[str, list[LogRecord]] = defaultdict(list)

    def append(self, rec: LogRecord):
        self.records.append(rec)
        self._by_job[rec.job_id].append(rec)

    def search(self, query: str, job_id: Optional[str] = None) -> list[LogRecord]:
        pool = self.records if job_id is None else self._by_job.get(job_id, [])
        return [r for r in pool if query in r.line]

    def stream(self, job_id: str) -> list[str]:
        return [r.line for r in self._by_job.get(job_id, [])]

    def stream_page(self, job_id: str, cursor: int = 0,
                    limit: Optional[int] = None
                    ) -> tuple[list[str], Optional[int]]:
        """One page of a job's log stream. The cursor is the offset into the
        per-job record sequence; ``None`` next-cursor means exhausted."""
        recs = self._by_job.get(job_id, [])
        if limit is None:
            return [r.line for r in recs[cursor:]], None
        page = recs[cursor:cursor + limit]
        nxt = cursor + len(page)
        return [r.line for r in page], (nxt if nxt < len(recs) else None)

    def search_page(self, query: str, job_id: Optional[str] = None,
                    cursor: int = 0, limit: Optional[int] = None,
                    allow=None) -> tuple[list[LogRecord], Optional[int]]:
        """Paginated substring search. The cursor is the scan offset into
        the (append-only) record sequence. ``allow(job_id) -> bool``
        optionally restricts matches (tenant scoping in the gateway)."""
        pool = self.records if job_id is None else self._by_job.get(job_id, [])
        out: list[LogRecord] = []
        i = cursor
        while i < len(pool):
            r = pool[i]
            i += 1
            if query in r.line and (allow is None or allow(r.job_id)):
                out.append(r)
                if limit is not None and len(out) >= limit:
                    break
        return out, (i if i < len(pool) else None)


class LogCollector:
    """Per-job helper container: tails learner logs into the index.

    Keeps per-learner byte offsets so a crash+restart never duplicates or
    drops lines (offsets themselves live on the volume → survive crashes).
    """

    def __init__(self, job_id: str, n_learners: int, volume: JobVolume,
                 index: LogIndex, clock):
        self.job_id = job_id
        self.n_learners = n_learners
        self.volume = volume
        self.index = index
        self.clock = clock
        self.alive = True

    def crash(self):
        self.alive = False

    def restart(self):
        self.alive = True

    def tick(self):
        if not self.alive:
            return
        try:
            for k in range(self.n_learners):
                content = self.volume.read(f"logs/learner-{k}") or ""
                off_raw = self.volume.read(f".collector/offset-{k}")
                offset = int(off_raw) if off_raw else 0
                new = content[offset:]
                if not new:
                    continue
                for line in new.splitlines():
                    self.index.append(LogRecord(self.clock.now(), self.job_id,
                                                k, line))
                self.volume.write(f".collector/offset-{k}", str(len(content)))
        except IOError:
            pass


class MetricsService:
    """Platform-level metrics: job throughput, component failure counters,
    cluster utilization samples."""

    def __init__(self, clock):
        self.clock = clock
        self.job_metrics: dict[str, list] = defaultdict(list)
        self.counters: dict[str, int] = defaultdict(int)
        self.util_samples: list[tuple[float, float]] = []

    def record_job(self, job_id: str, **metrics):
        self.job_metrics[job_id].append((self.clock.now(), metrics))

    def bump(self, counter: str, n: int = 1):
        self.counters[counter] += n

    def sample_utilization(self, util: float):
        self.util_samples.append((self.clock.now(), util))
