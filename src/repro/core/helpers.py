"""Helper services: log collection and the Training Metrics Service.

FfDL §3.2: "The Training Metrics Service is responsible for collecting
metrics about both the training jobs and FfDL microservices [...] It also
helps in streaming training logs from jobs to be indexed and stored in
ElasticSearch/Kibana."

``LogCollector`` streams learner log files off the job volume into the
searchable ``LogIndex`` (the ElasticSearch analogue), with gap-free resume
after collector crashes (offset bookkeeping — the 'surprisingly challenging'
§4 lesson). ``MetricsService`` aggregates job metrics and microservice
failure/recovery counters.
"""

from __future__ import annotations

import re
from array import array
from bisect import bisect_left
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Optional

from repro.core.executor import JobVolume

# Token = maximal alphanumeric/underscore run. The inverted index is keyed
# on these; everything between tokens (delimiters) is re-checked by the
# substring verification, so the tokenizer never changes result sets.
_TOKEN_RE = re.compile(r"[A-Za-z0-9_]+")


@dataclass
class LogRecord:
    ts: float
    job_id: str
    learner: int
    line: str


class LogIndex:
    """ElasticSearch-like: append + substring search, per-job streams.

    Both streams and searches are append-only, so integer offsets make
    stable pagination cursors: a page served earlier never shifts when new
    records arrive (they only land past every existing cursor). The
    API gateway serves its ``logs``/``search_logs`` pages from
    ``stream_page``/``search_page``.

    Search is served from a token-level **inverted index** (token →
    posting offsets, maintained globally and per job on ``append``):
    a query is compiled into token constraints, candidate offsets are the
    intersection of the matching posting lists, and each candidate is then
    verified with the exact ``query in line`` check — so results (and the
    integer scan-offset cursors) are identical to a full scan, without
    touching every record ever appended. Queries that contain no indexable
    token (pure punctuation/whitespace) fall back to the scan.
    """

    def __init__(self):
        # purge_jobs (tenant migration) tombstones records IN PLACE via a
        # `purged` flag: the list keeps its length and positions so
        # scan-offset cursors stay valid.
        self.records: list[LogRecord] = []
        self._by_job: dict[str, list[LogRecord]] = defaultdict(list)
        # token → sorted posting offsets (into self.records), and the same
        # per job (offsets into self._by_job[job_id])
        self._postings: dict[str, array] = {}
        self._job_postings: dict[str, dict[str, array]] = defaultdict(dict)
        # sorted vocab (+ reversed-token vocab for suffix constraints),
        # rebuilt lazily when new tokens appeared since the last search
        self._vocab: Optional[list[str]] = None
        self._rvocab: Optional[list[str]] = None
        # observability tap: called with each appended record (the usage
        # meter bills log bytes here); suppressed during import_records so
        # migrated lines are not billed twice.
        self.on_append = None

    def append(self, rec: LogRecord):
        off_g = len(self.records)
        self.records.append(rec)
        pool = self._by_job[rec.job_id]
        off_j = len(pool)
        pool.append(rec)
        job_post = self._job_postings[rec.job_id]
        for tok in set(_TOKEN_RE.findall(rec.line)):
            arr = self._postings.get(tok)
            if arr is None:
                self._postings[tok] = arr = array("q")
                self._vocab = self._rvocab = None  # new token: vocab dirty
            arr.append(off_g)
            jarr = job_post.get(tok)
            if jarr is None:
                job_post[tok] = jarr = array("q")
            jarr.append(off_j)
        if self.on_append is not None:
            self.on_append(rec)

    # -- query planning ---------------------------------------------------
    @staticmethod
    def _plan(query: str) -> Optional[list[tuple[str, str]]]:
        """Compile a substring query into token constraints.

        A token strictly inside the query is delimiter-bounded on both
        sides, so any matching line must contain it as a complete token
        (``exact``). A token touching the query's start may continue to
        the left inside the line (``suffix``: some line token ends with
        it); one touching the end may continue right (``prefix``); a token
        spanning the whole query may continue both ways (``substr``).
        ``None`` = no token to index on (fall back to scanning).
        """
        matches = list(_TOKEN_RE.finditer(query))
        if not matches:
            return None
        cons = []
        for m in matches:
            bounded_l = m.start() > 0
            bounded_r = m.end() < len(query)
            if bounded_l and bounded_r:
                cons.append(("exact", m.group()))
            elif bounded_l:
                cons.append(("prefix", m.group()))
            elif bounded_r:
                cons.append(("suffix", m.group()))
            else:
                cons.append(("substr", m.group()))
        return cons

    def _ensure_vocab(self):
        # Concurrent searches share the shard's read lock, so two threads
        # may rebuild at once: publish _vocab LAST — readers gate on it,
        # and seeing it non-None must imply _rvocab is usable too.
        if self._vocab is None:
            rvocab = sorted(t[::-1] for t in self._postings)
            vocab = sorted(self._postings)
            self._rvocab = rvocab
            self._vocab = vocab

    def _vocab_match(self, kind: str, text: str) -> list[str]:
        """All indexed tokens compatible with one non-exact constraint."""
        self._ensure_vocab()
        if kind == "prefix":
            lo = bisect_left(self._vocab, text)
            hi = bisect_left(self._vocab, text + "\uffff")
            return self._vocab[lo:hi]
        if kind == "suffix":
            rt = text[::-1]
            lo = bisect_left(self._rvocab, rt)
            hi = bisect_left(self._rvocab, rt + "\uffff")
            return [t[::-1] for t in self._rvocab[lo:hi]]
        return [t for t in self._vocab if text in t]  # substr

    def _candidates(self, query: str,
                    job_id: Optional[str]) -> Optional[list[int]]:
        """Sorted candidate offsets (into the global or per-job pool) that
        can possibly match ``query``; ``None`` = no usable constraint."""
        cons = self._plan(query)
        if cons is None:
            return None
        postings = (self._postings if job_id is None
                    else self._job_postings.get(job_id, {}))
        infos: list[tuple[int, list]] = []  # (candidate count, posting arrays)
        for kind, text in cons:
            if kind == "exact":
                arr = postings.get(text)
                if not arr:
                    return []
                infos.append((len(arr), [arr]))
            else:
                arrs = [postings[tok]
                        for tok in self._vocab_match(kind, text)
                        if tok in postings]
                est = sum(len(a) for a in arrs)
                if est == 0:
                    return []
                infos.append((est, arrs))
        # Every candidate gets the exact ``query in line`` check anyway, so
        # constraints are only a pre-filter: seed from the most selective
        # one and intersect only peers of comparable size — materialising a
        # token that appears on every line would cost more than it prunes.
        infos.sort(key=lambda x: x[0])
        base: set[int] = set()
        for a in infos[0][1]:
            base.update(a)
        for est, arrs in infos[1:]:
            if est > 4 * len(base):
                break
            s: set[int] = set()
            for a in arrs:
                s.update(a)
            base.intersection_update(s)
            if not base:
                return []
        return sorted(base)

    # -- tenant rebalancing (repro.api.admin migrations) -------------------
    def export_job(self, job_id: str, since: int = 0) -> list[dict]:
        """One job's records past a per-job watermark, as JSON-able dicts.
        ``since + len(result)`` is the watermark for the next delta export.
        Call under the shard's lock for a consistent cut."""
        return [{"ts": r.ts, "job_id": r.job_id, "learner": r.learner,
                 "line": r.line}
                for r in self._by_job.get(job_id, [])[since:]]

    def import_records(self, recs: list[dict]):
        """Append exported records into THIS index (normal ``append`` path,
        so the inverted index stays consistent). Per-job offsets — the log
        cursors clients hold — are preserved because deltas arrive in
        order and start where the previous import stopped."""
        hook, self.on_append = self.on_append, None
        try:  # migrated lines were billed on their source shard already
            for d in recs:
                self.append(LogRecord(**d))
        finally:
            self.on_append = hook

    def purge_jobs(self, job_ids) -> int:
        """Tombstone every record of ``job_ids`` (post-cutover source
        cleanup). The global record list keeps its LENGTH and positions —
        records are flagged in place — so the integer scan-offset cursors
        other tenants hold against this shard stay valid. Cost is
        O(purged jobs' records), not a scan of the whole shard (the purge
        runs under BOTH shards' write locks at cutover): the per-job
        pools reference the same record objects, so flagging through them
        tombstones the global list too. Returns the tombstone count."""
        n = 0
        for jid in set(job_ids):
            for rec in self._by_job.pop(jid, []):
                rec.purged = True  # visible through self.records as well
                n += 1
            self._job_postings.pop(jid, None)
        return n

    # -- search -----------------------------------------------------------
    def search(self, query: str, job_id: Optional[str] = None) -> list[LogRecord]:
        return self.search_page(query, job_id=job_id)[0]

    def stream(self, job_id: str) -> list[str]:
        return [r.line for r in self._by_job.get(job_id, [])]

    def stream_page(self, job_id: str, cursor: int = 0,
                    limit: Optional[int] = None
                    ) -> tuple[list[str], Optional[int]]:
        """One page of a job's log stream. The cursor is the offset into the
        per-job record sequence; ``None`` next-cursor means exhausted."""
        recs = self._by_job.get(job_id, [])
        if limit is None:
            return [r.line for r in recs[cursor:]], None
        page = recs[cursor:cursor + limit]
        nxt = cursor + len(page)
        return [r.line for r in page], (nxt if nxt < len(recs) else None)

    def search_page(self, query: str, job_id: Optional[str] = None,
                    cursor: int = 0, limit: Optional[int] = None,
                    allow=None) -> tuple[list[LogRecord], Optional[int]]:
        """Paginated substring search. The cursor is the scan offset into
        the (append-only) record sequence — exactly the pre-index meaning,
        so cursors minted before an index rebuild stay valid. ``allow``
        (``job_id -> bool``) optionally restricts matches (tenant scoping
        in the gateway)."""
        pool = self.records if job_id is None else self._by_job.get(job_id, [])
        cands = self._candidates(query, job_id)
        if cands is None:  # no indexable token: legacy linear scan
            out: list[LogRecord] = []
            i = cursor
            while i < len(pool):
                r = pool[i]
                i += 1
                if not getattr(r, "purged", False) and query in r.line \
                        and (allow is None or allow(r.job_id)):
                    out.append(r)
                    if limit is not None and len(out) >= limit:
                        break
            return out, (i if i < len(pool) else None)
        out = []
        for off in cands[bisect_left(cands, cursor):]:
            r = pool[off]  # purged = tombstone of a migrated-away job
            if not getattr(r, "purged", False) and query in r.line \
                    and (allow is None or allow(r.job_id)):
                out.append(r)
                if limit is not None and len(out) >= limit:
                    # the scan would have stopped right after this record
                    return out, (off + 1 if off + 1 < len(pool) else None)
        return out, None


class LogCollector:
    """Per-job helper container: tails learner logs into the index.

    Keeps per-learner byte offsets so a crash+restart never duplicates or
    drops lines (offsets themselves live on the volume → survive crashes).
    """

    def __init__(self, job_id: str, n_learners: int, volume: JobVolume,
                 index: LogIndex, clock):
        self.job_id = job_id
        self.n_learners = n_learners
        self.volume = volume
        self.index = index
        self.clock = clock
        self.alive = True

    def crash(self):
        self.alive = False

    def restart(self):
        self.alive = True

    def tick(self):
        if not self.alive:
            return
        try:
            for k in range(self.n_learners):
                content = self.volume.read(f"logs/learner-{k}") or ""
                off_raw = self.volume.read(f".collector/offset-{k}")
                offset = int(off_raw) if off_raw else 0
                new = content[offset:]
                if not new:
                    continue
                for line in new.splitlines():
                    self.index.append(LogRecord(self.clock.now(), self.job_id,
                                                k, line))
                self.volume.write(f".collector/offset-{k}", str(len(content)))
        except IOError:
            pass


class MetricsService:
    """Platform-level metrics: job throughput, component failure counters,
    cluster utilization samples."""

    def __init__(self, clock):
        self.clock = clock
        self.job_metrics: dict[str, list] = defaultdict(list)
        self.counters: dict[str, int] = defaultdict(int)
        self.util_samples: list[tuple[float, float]] = []

    def record_job(self, job_id: str, **metrics):
        self.job_metrics[job_id].append((self.clock.now(), metrics))

    def bump(self, counter: str, n: int = 1):
        self.counters[counter] += n

    def sample_utilization(self, util: float):
        self.util_samples.append((self.clock.now(), util))
