"""Helper services: log collection and the Training Metrics Service.

FfDL §3.2: "The Training Metrics Service is responsible for collecting
metrics about both the training jobs and FfDL microservices [...] It also
helps in streaming training logs from jobs to be indexed and stored in
ElasticSearch/Kibana."

``LogCollector`` streams learner log files off the job volume into the
searchable ``LogIndex`` (the ElasticSearch analogue), with gap-free resume
after collector crashes (offset bookkeeping — the 'surprisingly challenging'
§4 lesson). ``MetricsService`` aggregates job metrics and microservice
failure/recovery counters.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Optional

from repro.core.executor import JobVolume


@dataclass
class LogRecord:
    ts: float
    job_id: str
    learner: int
    line: str


class LogIndex:
    """ElasticSearch-like: append + substring search, per-job streams."""

    def __init__(self):
        self.records: list[LogRecord] = []

    def append(self, rec: LogRecord):
        self.records.append(rec)

    def search(self, query: str, job_id: Optional[str] = None) -> list[LogRecord]:
        return [r for r in self.records
                if query in r.line and (job_id is None or r.job_id == job_id)]

    def stream(self, job_id: str) -> list[str]:
        return [r.line for r in self.records if r.job_id == job_id]


class LogCollector:
    """Per-job helper container: tails learner logs into the index.

    Keeps per-learner byte offsets so a crash+restart never duplicates or
    drops lines (offsets themselves live on the volume → survive crashes).
    """

    def __init__(self, job_id: str, n_learners: int, volume: JobVolume,
                 index: LogIndex, clock):
        self.job_id = job_id
        self.n_learners = n_learners
        self.volume = volume
        self.index = index
        self.clock = clock
        self.alive = True

    def crash(self):
        self.alive = False

    def restart(self):
        self.alive = True

    def tick(self):
        if not self.alive:
            return
        try:
            for k in range(self.n_learners):
                content = self.volume.read(f"logs/learner-{k}") or ""
                off_raw = self.volume.read(f".collector/offset-{k}")
                offset = int(off_raw) if off_raw else 0
                new = content[offset:]
                if not new:
                    continue
                for line in new.splitlines():
                    self.index.append(LogRecord(self.clock.now(), self.job_id,
                                                k, line))
                self.volume.write(f".collector/offset-{k}", str(len(content)))
        except IOError:
            pass


class MetricsService:
    """Platform-level metrics: job throughput, component failure counters,
    cluster utilization samples."""

    def __init__(self, clock):
        self.clock = clock
        self.job_metrics: dict[str, list] = defaultdict(list)
        self.counters: dict[str, int] = defaultdict(int)
        self.util_samples: list[tuple[float, float]] = []

    def record_job(self, job_id: str, **metrics):
        self.job_metrics[job_id].append((self.clock.now(), metrics))

    def bump(self, counter: str, n: int = 1):
        self.counters[counter] += n

    def sample_utilization(self, util: float):
        self.util_samples.append((self.clock.now(), util))
