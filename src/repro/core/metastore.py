"""MetaStore: the MongoDB analogue (FfDL §3.2).

"When a job deployment request arrives, the API layer stores all the
metadata in MongoDB *before acknowledging the request*. This ensures that
submitted jobs are never lost [...] even if a catastrophic failure
temporarily takes down all machines in the cluster and all of FfDL core
microservices."

We reproduce exactly that contract: ``insert_job`` is durable-before-ack
(write-ahead journal appended and flushed before returning), and the whole
store can be rebuilt from the journal after a crash (``recover``).
Long-lived (spans jobs), per-tenant query-able job history included.

API-tier support: the idempotency-key index (``find_idempotent``) rides the
same WAL record as the insert, so duplicate-submit detection survives a
catastrophic crash/recover; ``jobs_page`` serves the gateway's
cursor-paginated, tenant-scoped listings (cursors key on the monotonically
increasing job id, so pages are stable under concurrent submits).
"""

from __future__ import annotations

import copy
import json
from dataclasses import asdict
from typing import Optional

from repro.core.types import JobManifest, JobRecord, JobStatus


class MetaStore:
    def __init__(self, clock, journal_path: Optional[str] = None):
        self.clock = clock
        self._jobs: dict[str, JobRecord] = {}
        self._journal: list[dict] = []  # in-memory WAL (file-backed if path)
        # (tenant, idempotency_key) → job_id; rebuilt from the WAL on recover
        self._idem: dict[tuple[str, str], str] = {}
        self.journal_path = journal_path
        self._fh = open(journal_path, "a") if journal_path else None
        self.available = True

    # -- chaos -----------------------------------------------------------
    def _check(self):
        if not self.available:
            raise ConnectionError("metastore unavailable")

    def crash(self):
        self.available = False

    def restart(self):
        self.available = True

    # -- WAL --------------------------------------------------------------
    def _append(self, op: dict):
        self._journal.append(op)
        if self._fh:
            self._fh.write(json.dumps(op, default=str) + "\n")
            self._fh.flush()

    @classmethod
    def recover(cls, clock, journal_path: str) -> "MetaStore":
        """Rebuild from the journal (catastrophic-failure recovery path)."""
        store = cls(clock)
        with open(journal_path) as fh:
            for line in fh:
                op = json.loads(line)
                store._replay(op)
        store.journal_path = journal_path
        store._fh = open(journal_path, "a")
        return store

    def replay_journal(self, journal: list[dict]):
        for op in journal:
            self._replay(op)

    def _replay(self, op: dict):
        if op["op"] == "insert":
            m = JobManifest(**op["manifest"])
            rec = JobRecord(job_id=op["job_id"], manifest=m,
                            submitted_at=op["ts"])
            rec.set_status(op["ts"], JobStatus.PENDING, "recovered")
            self._jobs[op["job_id"]] = rec
            if op.get("idem"):
                self._idem[(m.tenant, op["idem"])] = op["job_id"]
        elif op["op"] == "status" and op["job_id"] in self._jobs:
            self._jobs[op["job_id"]].set_status(
                op["ts"], JobStatus(op["status"]), op.get("msg", ""))

    # -- API ----------------------------------------------------------------
    def insert_job(self, job_id: str, manifest: JobManifest,
                   idempotency_key: Optional[str] = None) -> JobRecord:
        """Durable before ack — the WAL append happens before returning.
        The idempotency mapping rides the same WAL record as the insert, so
        duplicate detection survives crash/recover."""
        self._check()
        rec = JobRecord(job_id=job_id, manifest=manifest,
                        submitted_at=self.clock.now())
        rec.set_status(self.clock.now(), JobStatus.PENDING, "accepted")
        self._jobs[job_id] = rec
        if idempotency_key is not None:
            self._idem[(manifest.tenant, idempotency_key)] = job_id
        self._append({"op": "insert", "job_id": job_id, "ts": self.clock.now(),
                      "manifest": asdict(manifest),
                      "idem": idempotency_key})
        return rec

    def find_idempotent(self, tenant: str, key: str) -> Optional[str]:
        """Job id previously acked for this (tenant, idempotency_key)."""
        self._check()
        return self._idem.get((tenant, key))

    def get(self, job_id: str) -> Optional[JobRecord]:
        self._check()
        return self._jobs.get(job_id)

    def update_status(self, job_id: str, status: JobStatus, msg: str = ""):
        self._check()
        rec = self._jobs[job_id]
        if rec.status != status or msg != rec.message:
            rec.set_status(self.clock.now(), status, msg)
            self._append({"op": "status", "job_id": job_id,
                          "ts": self.clock.now(), "status": status.value,
                          "msg": msg})

    def jobs(self, tenant: Optional[str] = None,
             status: Optional[JobStatus] = None) -> list[JobRecord]:
        self._check()
        out = []
        for rec in self._jobs.values():
            if tenant and rec.manifest.tenant != tenant:
                continue
            if status and rec.status != status:
                continue
            out.append(rec)
        return sorted(out, key=lambda r: r.submitted_at)

    def jobs_page(self, tenant: Optional[str] = None,
                  status: Optional[JobStatus] = None,
                  cursor: Optional[str] = None,
                  limit: int = 20) -> tuple[list[JobRecord], Optional[str]]:
        """Cursor-paginated job listing in job-id order.

        The cursor is the last job id of the previous page; job ids are
        zero-padded and monotonically increasing, so already-served pages
        never shift when new jobs are submitted concurrently.
        Returns ``(records, next_cursor)``; ``next_cursor`` is ``None``
        once exhausted.
        """
        self._check()
        if limit is not None and limit < 1:
            raise ValueError(f"limit must be >= 1, got {limit}")
        matches = []
        for job_id in sorted(self._jobs):
            if cursor is not None and job_id <= cursor:
                continue
            rec = self._jobs[job_id]
            if tenant and rec.manifest.tenant != tenant:
                continue
            if status and rec.status != status:
                continue
            matches.append(rec)
            if limit is not None and len(matches) > limit:
                break
        if limit is not None and len(matches) > limit:
            return matches[:limit], matches[limit - 1].job_id
        return matches, None

    def history(self, tenant: str) -> list[dict]:
        """Per-tenant job history (the 'business artifact' query)."""
        return [
            {"job_id": r.job_id, "name": r.manifest.name,
             "status": r.status.value, "submitted_at": r.submitted_at,
             "finished_at": r.finished_at}
            for r in self.jobs(tenant=tenant)
        ]
