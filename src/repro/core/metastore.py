"""MetaStore: the MongoDB analogue (FfDL §3.2).

"When a job deployment request arrives, the API layer stores all the
metadata in MongoDB *before acknowledging the request*. This ensures that
submitted jobs are never lost [...] even if a catastrophic failure
temporarily takes down all machines in the cluster and all of FfDL core
microservices."

We reproduce exactly that contract: ``insert_job`` is durable-before-ack
(write-ahead journal appended and flushed before returning), and the whole
store can be rebuilt from the journal after a crash (``recover``).
Long-lived (spans jobs), per-tenant query-able job history included.

API-tier support: the idempotency-key index (``find_idempotent``) rides the
same WAL record as the insert, so duplicate-submit detection survives a
catastrophic crash/recover; ``jobs_page`` serves the gateway's
cursor-paginated, tenant-scoped listings (cursors key on the monotonically
increasing job id, so pages are stable under concurrent submits).

Hot-path indexing: listings used to re-sort every job id per request, so a
page cost O(total jobs ever) forever. The store now maintains sorted
secondary indexes — all ids, per tenant, per status, and per
(tenant, status) — incrementally on ``insert_job``/``update_status``;
``jobs_page`` resolves a page with one ``bisect`` + an index slice, and
``jobs``/``history`` walk the tenant index instead of scanning the table.

WAL group-commit: journal ops buffer in memory and are made durable by ONE
``write``+``flush`` per *public mutation* (or per ``batch()`` scope, which
amortises the flush across many mutations — the control-plane tick and
bulk ingest use this). ``insert_job`` outside a batch keeps the exact
durable-before-ack contract: its op is on disk before it returns.

Tenant rebalancing (the v2 admin plane, ``repro.api.admin``): a tenant's
slice of the store can be moved between shards with
``export_tenant``/``import_tenant``/``purge_tenant``. An export carries
(a) the tenant's journal ops past a watermark — replayed into the
destination's own WAL so the move is durable there — and (b) exact record
snapshots overlaying the fields the WAL does not journal (``finished_at``,
``progress_step``, restarts, the verbatim status history), so the imported
records are bit-for-bit equal to the source's. Re-exporting from the new
watermark yields only the mutations that landed during the copy (the
CATCHUP phase); ``purge_tenant`` journals the removal so a recovered
source shard does not resurrect a moved tenant.
"""

from __future__ import annotations

import json
from bisect import bisect_left, bisect_right, insort
from contextlib import contextmanager
from dataclasses import asdict
from typing import Optional

from repro.core.types import JobManifest, JobRecord, JobStatus


def _idx_add(lst: list, jid: str):
    """Insert ``jid`` keeping ``lst`` sorted. Ids are minted monotonically,
    so the overwhelmingly common case is an append."""
    if not lst or lst[-1] < jid:
        lst.append(jid)
        return
    i = bisect_left(lst, jid)
    if i >= len(lst) or lst[i] != jid:  # tolerate re-inserts (replay)
        lst.insert(i, jid)


def _idx_del(lst: list, jid: str):
    i = bisect_left(lst, jid)
    if i < len(lst) and lst[i] == jid:
        del lst[i]


class MetaStore:
    def __init__(self, clock, journal_path: Optional[str] = None):
        self.clock = clock
        self._jobs: dict[str, JobRecord] = {}
        self._journal: list[dict] = []  # in-memory WAL (file-backed if path)
        # (tenant, idempotency_key) → job_id; rebuilt from the WAL on recover
        self._idem: dict[tuple[str, str], str] = {}
        # -- secondary indexes (sorted job-id lists), incrementally
        #    maintained; every read path below serves from these ----------
        self._order: list[str] = []
        self._by_tenant: dict[str, list[str]] = {}
        self._by_status: dict[JobStatus, list[str]] = {}
        self._by_tenant_status: dict[tuple[str, JobStatus], list[str]] = {}
        # -- WAL group-commit state ---------------------------------------
        self._pending: list[dict] = []  # ops not yet written to the file
        self._batch_depth = 0
        self.flushes = 0  # durability flushes issued (benchmark telemetry)
        self.journal_path = journal_path
        self._fh = open(journal_path, "a") if journal_path else None
        self.available = True
        # gray-failure interposition (wal.append / wal.flush): wired by the
        # owning platform to the shared FaultPlane; key scopes per shard
        self.faults = None
        self.fault_key: Optional[str] = None

    # -- chaos -----------------------------------------------------------
    def _check(self):
        if not self.available:
            raise ConnectionError("metastore unavailable")

    def crash(self):
        self.available = False

    def restart(self):
        self.available = True

    # -- WAL --------------------------------------------------------------
    def _append(self, op: dict):
        if self.faults is not None:
            # a slow/hung/failed WAL append surfaces as the same
            # ConnectionError the availability flag raises -> UNAVAILABLE
            self.faults.on("wal.append", key=self.fault_key,
                           exc=ConnectionError)
        self._journal.append(op)
        if self._fh:
            self._pending.append(op)

    def _commit(self):
        """Group commit: everything buffered since the last commit goes out
        in one write+flush. No-op inside a ``batch()`` scope — the batch
        exit issues the single flush for the whole group."""
        if self._batch_depth > 0:
            return
        if self.faults is not None:
            self.faults.on("wal.flush", key=self.fault_key,
                           exc=ConnectionError)
        if not self._pending:
            return
        if self._fh:
            self._fh.write("".join(json.dumps(op, default=str) + "\n"
                                   for op in self._pending))
            self._fh.flush()
            self.flushes += 1
        self._pending.clear()

    @contextmanager
    def batch(self):
        """Group-commit scope: ops from every mutation inside are made
        durable by ONE write+flush at exit (durable before the batch
        returns). Nested batches commit once, at the outermost exit."""
        self._batch_depth += 1
        try:
            yield self
        finally:
            self._batch_depth -= 1
            self._commit()

    @classmethod
    def recover(cls, clock, journal_path: str) -> "MetaStore":
        """Rebuild from the journal (catastrophic-failure recovery path)."""
        store = cls(clock)
        with open(journal_path) as fh:
            for line in fh:
                op = json.loads(line)
                store._replay(op)
        store.journal_path = journal_path
        store._fh = open(journal_path, "a")
        return store

    def replay_journal(self, journal: list[dict]):
        for op in journal:
            self._replay(op)

    def _replay(self, op: dict):
        if op["op"] == "insert":
            m = JobManifest(**op["manifest"])
            rec = JobRecord(job_id=op["job_id"], manifest=m,
                            submitted_at=op["ts"])
            rec.set_status(op["ts"], JobStatus.PENDING, "recovered")
            self._jobs[op["job_id"]] = rec
            self._index_insert(op["job_id"], m.tenant, JobStatus.PENDING)
            if op.get("idem"):
                self._idem[(m.tenant, op["idem"])] = op["job_id"]
        elif op["op"] == "status" and op["job_id"] in self._jobs:
            rec = self._jobs[op["job_id"]]
            old = rec.status
            rec.set_status(op["ts"], JobStatus(op["status"]),
                           op.get("msg", ""))
            self._index_restatus(op["job_id"], rec.manifest.tenant,
                                 old, rec.status)
        elif op["op"] == "purge_tenant":
            self._purge_tenant_state(op["tenant"])

    # -- index maintenance ------------------------------------------------
    def _index_insert(self, job_id: str, tenant: str, status: JobStatus):
        _idx_add(self._order, job_id)
        _idx_add(self._by_tenant.setdefault(tenant, []), job_id)
        _idx_add(self._by_status.setdefault(status, []), job_id)
        _idx_add(self._by_tenant_status.setdefault((tenant, status), []),
                 job_id)

    def _index_restatus(self, job_id: str, tenant: str,
                        old: JobStatus, new: JobStatus):
        if old == new:
            return
        _idx_del(self._by_status.get(old, []), job_id)
        _idx_del(self._by_tenant_status.get((tenant, old), []), job_id)
        _idx_add(self._by_status.setdefault(new, []), job_id)
        _idx_add(self._by_tenant_status.setdefault((tenant, new), []),
                 job_id)

    def _index_for(self, tenant: Optional[str],
                   status: Optional[JobStatus]) -> list[str]:
        """The narrowest sorted id list matching the filters."""
        if tenant is not None and status is not None:
            return self._by_tenant_status.get((tenant, status), [])
        if tenant is not None:
            return self._by_tenant.get(tenant, [])
        if status is not None:
            return self._by_status.get(status, [])
        return self._order

    # -- API ----------------------------------------------------------------
    def insert_job(self, job_id: str, manifest: JobManifest,
                   idempotency_key: Optional[str] = None) -> JobRecord:
        """Durable before ack — the WAL write+flush happens before
        returning (one group commit). The idempotency mapping rides the
        same WAL record as the insert, so duplicate detection survives
        crash/recover."""
        self._check()
        rec = JobRecord(job_id=job_id, manifest=manifest,
                        submitted_at=self.clock.now())
        rec.set_status(self.clock.now(), JobStatus.PENDING, "accepted")
        self._jobs[job_id] = rec
        self._index_insert(job_id, manifest.tenant, JobStatus.PENDING)
        if idempotency_key is not None:
            self._idem[(manifest.tenant, idempotency_key)] = job_id
        self._append({"op": "insert", "job_id": job_id, "ts": self.clock.now(),
                      "manifest": asdict(manifest),
                      "idem": idempotency_key})
        self._commit()
        return rec

    def find_idempotent(self, tenant: str, key: str) -> Optional[str]:
        """Job id previously acked for this (tenant, idempotency_key)."""
        self._check()
        return self._idem.get((tenant, key))

    def get(self, job_id: str) -> Optional[JobRecord]:
        self._check()
        return self._jobs.get(job_id)

    def update_status(self, job_id: str, status: JobStatus, msg: str = ""):
        self._check()
        rec = self._jobs[job_id]
        if rec.status != status or msg != rec.message:
            old = rec.status
            rec.set_status(self.clock.now(), status, msg)
            self._index_restatus(job_id, rec.manifest.tenant, old, status)
            self._append({"op": "status", "job_id": job_id,
                          "ts": self.clock.now(), "status": status.value,
                          "msg": msg})
            self._commit()

    def jobs(self, tenant: Optional[str] = None,
             status: Optional[JobStatus] = None) -> list[JobRecord]:
        self._check()
        recs = [self._jobs[jid] for jid in self._index_for(tenant, status)]
        return sorted(recs, key=lambda r: r.submitted_at)

    def jobs_page(self, tenant: Optional[str] = None,
                  status: Optional[JobStatus] = None,
                  cursor: Optional[str] = None,
                  limit: int = 20) -> tuple[list[JobRecord], Optional[str]]:
        """Cursor-paginated job listing in job-id order.

        The cursor is the last job id of the previous page; job ids are
        zero-padded and monotonically increasing, so already-served pages
        never shift when new jobs are submitted concurrently.
        Served from the matching secondary index: one ``bisect`` to find
        the cursor position, one slice for the page — exactly ``limit``
        records, with the next-cursor derived from the index position.
        Returns ``(records, next_cursor)``; ``next_cursor`` is ``None``
        once exhausted.
        """
        self._check()
        if limit is not None and limit < 1:
            raise ValueError(f"limit must be >= 1, got {limit}")
        idx = self._index_for(tenant, status)
        start = bisect_right(idx, cursor) if cursor is not None else 0
        if limit is None:
            return [self._jobs[jid] for jid in idx[start:]], None
        page_ids = idx[start:start + limit]
        more = start + limit < len(idx)
        return ([self._jobs[jid] for jid in page_ids],
                page_ids[-1] if more else None)

    def jobs_span(self, lo: Optional[str] = None, hi: Optional[str] = None,
                  status: Optional[JobStatus] = None,
                  cursor: Optional[str] = None,
                  limit: int = 20) -> list[JobRecord]:
        """Records with ``max(lo, cursor) < job_id <= hi`` in id order, at
        most ``limit``. The federated admin walk uses this to page one
        *minting-shard id stream* (a contiguous id interval) out of any
        shard's index — including ids that migrated in from another shard.
        """
        self._check()
        idx = self._index_for(None, status)
        start_key = lo
        if cursor is not None and (start_key is None or cursor > start_key):
            start_key = cursor
        start = bisect_right(idx, start_key) if start_key is not None else 0
        end = bisect_right(idx, hi) if hi is not None else len(idx)
        return [self._jobs[jid] for jid in idx[start:min(start + limit, end)]]

    def history(self, tenant: str) -> list[dict]:
        """Per-tenant job history (the 'business artifact' query)."""
        return [
            {"job_id": r.job_id, "name": r.manifest.name,
             "status": r.status.value, "submitted_at": r.submitted_at,
             "finished_at": r.finished_at}
            for r in self.jobs(tenant=tenant)
        ]

    # -- tenant rebalancing (repro.api.admin migrations) -------------------
    @staticmethod
    def _record_to_wire(rec: JobRecord) -> dict:
        """Exact, JSON-able snapshot of one record (models a wire copy)."""
        return {
            "job_id": rec.job_id, "manifest": asdict(rec.manifest),
            "status": rec.status.value,
            "status_history": [list(h) for h in rec.status_history],
            "submitted_at": rec.submitted_at,
            "scheduled_at": rec.scheduled_at,
            "finished_at": rec.finished_at,
            "placement": dict(rec.placement) if rec.placement else None,
            "restarts": rec.restarts, "deploy_retries": rec.deploy_retries,
            "progress_step": rec.progress_step, "message": rec.message,
        }

    @staticmethod
    def _record_from_wire(d: dict) -> JobRecord:
        rec = JobRecord(job_id=d["job_id"],
                        manifest=JobManifest(**d["manifest"]),
                        submitted_at=d["submitted_at"])
        rec.status = JobStatus(d["status"])
        rec.status_history = [tuple(h) for h in d["status_history"]]
        rec.scheduled_at = d["scheduled_at"]
        rec.finished_at = d["finished_at"]
        rec.placement = dict(d["placement"]) if d["placement"] else None
        rec.restarts = d["restarts"]
        rec.deploy_retries = d["deploy_retries"]
        rec.progress_step = d["progress_step"]
        rec.message = d["message"]
        return rec

    def export_tenant(self, tenant: str, since: int = 0) -> dict:
        """Consistent snapshot of one tenant's slice of the store.

        ``ops`` are the tenant's journal entries with index >= ``since``
        (only for jobs still live — a previously purged tenant exports
        nothing); ``records`` are exact snapshots carrying the fields the
        WAL does not journal. A FULL export (``since=0``) snapshots every
        record; a delta export snapshots only the jobs the delta ops
        touched — any record still mutating mutates through journaled
        status flips (the migration quiesce guarantees this before the
        final delta), so a delta-untouched record is identical to the
        copy the previous export already delivered. ``watermark`` is the
        journal position to pass as ``since`` on the next export. Call
        under the shard's lock for a consistent cut.
        """
        self._check()
        jids = set(self._by_tenant.get(tenant, []))
        ops = []
        for op in self._journal[since:]:
            if op["op"] == "purge_tenant":
                continue  # a fresh import must not carry an old purge
            if op.get("job_id") in jids:
                ops.append(op)
        snap_ids = jids if since == 0 else {op["job_id"] for op in ops}
        return {
            "tenant": tenant,
            "ops": ops,
            "records": {jid: self._record_to_wire(self._jobs[jid])
                        for jid in snap_ids},
            "idem": {key: jid for (t, key), jid in self._idem.items()
                     if t == tenant},
            "watermark": len(self._journal),
        }

    def import_tenant(self, snap: dict):
        """Install an ``export_tenant`` snapshot into THIS store.

        The source's ops are appended to the local WAL (one group commit),
        so the moved tenant survives a crash/recover of the destination;
        the record snapshots then overwrite the in-memory records exactly
        (bit-for-bit with the source, including status history and the
        non-journaled fields). Re-imports are idempotent: a record already
        present is replaced, not duplicated.
        """
        self._check()
        with self.batch():
            for op in snap["ops"]:
                self._append(op)
            for jid, wire in snap["records"].items():
                old = self._jobs.get(jid)
                if old is not None:
                    self._index_remove(jid, old.manifest.tenant, old.status)
                rec = self._record_from_wire(wire)
                self._jobs[jid] = rec
                self._index_insert(jid, rec.manifest.tenant, rec.status)
            for key, jid in snap["idem"].items():
                self._idem[(snap["tenant"], key)] = jid

    def purge_tenant(self, tenant: str) -> list[str]:
        """Remove every record of ``tenant`` (post-cutover source cleanup,
        or rollback of a partial import on an aborted migration). Journaled,
        so recovering this shard's WAL does not resurrect the moved tenant.
        Returns the purged job ids."""
        self._check()
        purged = self._purge_tenant_state(tenant)
        if purged:
            self._append({"op": "purge_tenant", "tenant": tenant,
                          "ts": self.clock.now()})
            self._commit()
        return purged

    def _purge_tenant_state(self, tenant: str) -> list[str]:
        jids = list(self._by_tenant.get(tenant, []))
        for jid in jids:
            rec = self._jobs.pop(jid)
            self._index_remove(jid, tenant, rec.status)
        for key in [k for k in self._idem if k[0] == tenant]:
            del self._idem[key]
        return jids

    def _index_remove(self, job_id: str, tenant: str, status: JobStatus):
        _idx_del(self._order, job_id)
        _idx_del(self._by_tenant.get(tenant, []), job_id)
        _idx_del(self._by_status.get(status, []), job_id)
        _idx_del(self._by_tenant_status.get((tenant, status), []), job_id)
