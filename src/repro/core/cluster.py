"""ClusterModel: hosts with TPU chips, heartbeats, failures, pod lifecycle.

The K8s/node layer of the platform (DESIGN.md §2 mapping):

  * Host = machine with ``chips_per_host`` TPU chips at coordinates (x, y) on
    the pod's 2D ICI torus (locality input for the BSA PACK bias — the TPU
    analogue of FfDL's "Spread increases communication cost" observation).
  * Heartbeat leases in the coordination store; a host whose lease lapses
    goes NotReady and the node controller **evicts** its pods (the paper's
    NodeControllerEviction behavior, §5.6).
  * Pods are granted exclusive chips (no overcommit, §3.6); stateful-set
    pods are restarted by the cluster after crash (§3.8), which is what
    makes learner recovery work without Guardian involvement.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.kvstore import EtcdLike
from repro.core.types import EventLog, Pod, PodPhase


@dataclass
class Host:
    host_id: str
    n_chips: int
    coord: tuple  # (x, y) on the torus
    ready: bool = True
    cordoned: bool = False
    pods: dict = field(default_factory=dict)  # pod_name → Pod
    # incrementally maintained by ClusterModel on every pod phase
    # transition (chips held by PENDING/RUNNING pods) — what used to be
    # recomputed by summing every pod on every free_chips read
    used_chips: int = 0
    # pods present per job (any phase) — the spread ranking's same-job
    # count, maintained on bind/delete
    job_pods: dict = field(default_factory=dict)

    @property
    def free_chips(self) -> int:
        return self.n_chips - self.used_chips

    @property
    def schedulable(self) -> bool:
        return self.ready and not self.cordoned


def torus_distance(a: tuple, b: tuple, size: tuple) -> int:
    return sum(min(abs(ai - bi), si - abs(ai - bi))
               for ai, bi, si in zip(a, b, size))


class ClusterModel:
    HEARTBEAT_TTL = 15.0      # lease ttl (node NotReady after this lapses)
    HEARTBEAT_PERIOD = 5.0
    POD_START_LATENCY = {     # Table 3-calibrated start costs (seconds)
        "learner": 12.0,      # binding object store + volumes: 10-20s
        "helper": 3.0,
        "guardian": 1.5,
    }

    def __init__(self, n_hosts: int, chips_per_host: int, clock,
                 etcd: EtcdLike, events: EventLog, torus_width: int = 0):
        self.clock = clock
        self.etcd = etcd
        self.events = events
        w = torus_width or max(1, int(math.isqrt(n_hosts)))
        self.torus = (w, max(1, (n_hosts + w - 1) // w))
        self.hosts: dict[str, Host] = {}
        for i in range(n_hosts):
            hid = f"host-{i:04d}"
            self.hosts[hid] = Host(hid, chips_per_host,
                                   (i % w, i // w))
        self.pods: dict[str, Pod] = {}
        self._restart_hooks: list[Callable[[Pod], None]] = []
        self._eviction_hooks: list[Callable[[Pod, str], None]] = []
        self._heartbeat_leases: dict[str, int] = {}
        self._failed_heartbeat: set[str] = set()
        # -- free-chips index (scheduler hot path) -------------------------
        # Schedulable hosts bucketed by current free chips, kept in sync on
        # every pod phase transition and node health flip, so a placement
        # query ("smallest/largest free >= k") never rescans the cluster.
        self._free_buckets: dict[int, set[str]] = {}
        self._bucket_of: dict[str, int] = {}
        self._max_chips = chips_per_host
        self._sched_cache: Optional[list[Host]] = None
        # placement epoch: bumped whenever anything a placement decision
        # can observe changes (free chips, schedulability). GangScheduler
        # caches "gang does not fit" verdicts keyed on it.
        self.epoch = 0
        for hid in self.hosts:
            self._heartbeat_leases[hid] = etcd.grant_lease(self.HEARTBEAT_TTL)
            etcd.put(f"/nodes/{hid}", "Ready",
                     lease_id=self._heartbeat_leases[hid])
            self._reindex(self.hosts[hid])

    # -- capacity -----------------------------------------------------------
    @property
    def total_chips(self) -> int:
        return sum(h.n_chips for h in self.hosts.values())

    @property
    def used_chips(self) -> int:
        return sum(h.used_chips for h in self.hosts.values())

    def utilization(self) -> float:
        return self.used_chips / max(self.total_chips, 1)

    def schedulable_hosts(self) -> list[Host]:
        """Schedulable hosts in stable host order. Cached; invalidated
        only when a host's schedulability flips (rare), not on every
        placement query."""
        if self._sched_cache is None:
            self._sched_cache = [h for h in self.hosts.values()
                                 if h.schedulable]
        return self._sched_cache

    # -- free-chips index --------------------------------------------------
    def _reindex(self, host: Host):
        """Move ``host`` to the bucket for its current free capacity
        (schedulable hosts only)."""
        self.epoch += 1
        old = self._bucket_of.pop(host.host_id, None)
        if old is not None:
            self._free_buckets[old].discard(host.host_id)
        if host.schedulable:
            f = host.free_chips
            self._free_buckets.setdefault(f, set()).add(host.host_id)
            self._bucket_of[host.host_id] = f

    def _schedulable_flip(self, host: Host):
        self._sched_cache = None
        self._reindex(host)

    def _account(self, host: Host, delta: int):
        host.used_chips += delta
        self._reindex(host)

    def pack_host(self, min_free: int) -> Optional[Host]:
        """Best-fit: the schedulable host with the SMALLEST free capacity
        >= ``min_free`` (lowest host id on ties) — the pack ranking's
        ``sort(key=free)[0]``, answered from the buckets."""
        for f in range(min_free, self._max_chips + 1):
            bucket = self._free_buckets.get(f)
            if bucket:
                return self.hosts[min(bucket)]
        return None

    def spread_host(self, min_free: int, job_id: str) -> Optional[Host]:
        """The spread ranking's pick: minimal ``(same-job pods, -free,
        host id)`` over schedulable hosts with free >= ``min_free`` —
        identical to sorting every host, served from the buckets. Walks
        free levels descending; the first level holding a host with no
        same-job pods wins outright (no lower level can beat it)."""
        best = None  # (same_job, -free, host_id)
        for f in range(self._max_chips, min_free - 1, -1):
            bucket = self._free_buckets.get(f)
            if not bucket:
                continue
            zero_best = nz_best = None
            for hid in bucket:
                same = self.hosts[hid].job_pods.get(job_id, 0)
                if same == 0:
                    if zero_best is None or hid < zero_best:
                        zero_best = hid
                elif nz_best is None or (same, hid) < nz_best:
                    nz_best = (same, hid)
            if zero_best is not None:
                return self.hosts[zero_best]
            if nz_best is not None:
                cand = (nz_best[0], -f, nz_best[1])
                if best is None or cand < best:
                    best = cand
        return None if best is None else self.hosts[best[2]]

    # -- pod lifecycle -------------------------------------------------------
    def bind_pod(self, pod: Pod, host_id: str) -> bool:
        """Bind a pod to a host (exclusive chip grant). False if rejected."""
        host = self.hosts[host_id]
        if not host.schedulable or host.free_chips < pod.chips:
            self.events.emit("k8s", "binding_rejected", pod=pod.name,
                             host=host_id)
            return False
        pod.host = host_id
        pod.phase = PodPhase.PENDING
        host.pods[pod.name] = pod
        host.job_pods[pod.job_id] = host.job_pods.get(pod.job_id, 0) + 1
        self.pods[pod.name] = pod
        self._account(host, pod.chips)
        latency = self.POD_START_LATENCY.get(pod.kind, 3.0)
        self.clock.call_later(latency, lambda: self._start_pod(pod))
        self.events.emit("k8s", "pod_bound", pod=pod.name, host=host_id,
                         chips=pod.chips)
        return True

    def _start_pod(self, pod: Pod):
        if pod.phase == PodPhase.PENDING and pod.host is not None:
            pod.phase = PodPhase.RUNNING
            pod.started_at = self.clock.now()
            self.events.emit("k8s", "pod_running", pod=pod.name)

    def delete_pod(self, pod_name: str, reason: str = "deleted"):
        pod = self.pods.pop(pod_name, None)
        if pod is None:
            return
        holds_chips = pod.phase in (PodPhase.PENDING, PodPhase.RUNNING)
        if pod.host and pod.host in self.hosts:
            host = self.hosts[pod.host]
            if host.pods.pop(pod.name, None) is not None:
                n = host.job_pods.get(pod.job_id, 0) - 1
                if n > 0:
                    host.job_pods[pod.job_id] = n
                else:
                    host.job_pods.pop(pod.job_id, None)
                if holds_chips:
                    self._account(host, -pod.chips)
        pod.phase = PodPhase.DELETED
        pod.finished_at = self.clock.now()
        self.events.emit("k8s", "pod_deleted", pod=pod_name, reason=reason)

    def fail_pod(self, pod_name: str, reason: str = "crash"):
        """Pod process crash. Stateful-set pods get restarted in place."""
        pod = self.pods.get(pod_name)
        if pod is None or pod.phase != PodPhase.RUNNING:
            return
        pod.phase = PodPhase.FAILED
        if pod.host and pod.host in self.hosts:  # FAILED pods hold no chips
            self._account(self.hosts[pod.host], -pod.chips)
        self.events.emit("k8s", "pod_failed", pod=pod_name, reason=reason)

    def restart_pod(self, pod_name: str):
        """K8s stateful-set restart: same host, new container."""
        pod = self.pods.get(pod_name)
        if pod is None or pod.host is None:
            return
        pod.restarts += 1
        if pod.phase not in (PodPhase.PENDING, PodPhase.RUNNING) \
                and pod.host in self.hosts:
            self._account(self.hosts[pod.host], pod.chips)
        pod.phase = PodPhase.PENDING
        latency = self.POD_START_LATENCY.get(pod.kind, 3.0)
        self.clock.call_later(latency, lambda: self._start_pod(pod))
        self.events.emit("k8s", "pod_restarted", pod=pod_name,
                         restarts=pod.restarts)

    def complete_pod(self, pod_name: str):
        pod = self.pods.get(pod_name)
        if pod is not None:
            if pod.phase in (PodPhase.PENDING, PodPhase.RUNNING) \
                    and pod.host and pod.host in self.hosts:
                self._account(self.hosts[pod.host], -pod.chips)
            pod.phase = PodPhase.SUCCEEDED
            pod.finished_at = self.clock.now()

    def on_eviction(self, fn: Callable[[Pod, str], None]):
        self._eviction_hooks.append(fn)

    # -- node health -----------------------------------------------------
    def fail_host(self, host_id: str):
        """Chaos: host stops heartbeating (hardware fault / reboot)."""
        self._failed_heartbeat.add(host_id)

    def recover_host(self, host_id: str):
        self._failed_heartbeat.discard(host_id)
        host = self.hosts[host_id]
        if not host.ready:
            host.ready = True
            self._schedulable_flip(host)
            lease = self.etcd.grant_lease(self.HEARTBEAT_TTL)
            self._heartbeat_leases[host_id] = lease
            self.etcd.put(f"/nodes/{host_id}", "Ready", lease_id=lease)
            self.events.emit("node_controller", "node_ready", host=host_id)

    def cordon(self, host_id: str):
        host = self.hosts[host_id]
        host.cordoned = True
        self._schedulable_flip(host)
        self.events.emit("node_controller", "node_cordoned", host=host_id)

    def tick(self):
        """Heartbeats + NotReady detection + eviction. Call every few sim-s."""
        now = self.clock.now()
        for hid, host in self.hosts.items():
            if hid not in self._failed_heartbeat and host.ready:
                self.etcd.keepalive(self._heartbeat_leases[hid])
        self.etcd.sweep_leases()
        for hid, host in self.hosts.items():
            alive = self.etcd.get(f"/nodes/{hid}") is not None
            if host.ready and not alive:
                host.ready = False
                self._schedulable_flip(host)
                self.events.emit("node_controller", "node_notready", host=hid)
                self._evict_host_pods(hid)

    def _evict_host_pods(self, host_id: str):
        """NodeControllerEviction: delete all pods on a NotReady node."""
        host = self.hosts[host_id]
        for pod in list(host.pods.values()):
            self.events.emit("node_controller", "pod_evicted", pod=pod.name,
                             host=host_id, pod_kind=pod.kind)
            self.delete_pod(pod.name, reason="node_failure")
            for fn in self._eviction_hooks:
                fn(pod, "node_failure")
