"""Learner runtime: what actually runs inside learner pods.

FfDL treats the framework as opaque — learners communicate with the platform
only through "lowest common denominator" channels (§7): a shared filesystem
(exit-code and status files on the job's NFS volume), environment-style
config, and logs to stdout. We reproduce that contract:

  * ``JobVolume`` — the shared NFS volume: plain key→bytes files, persistent
    across pod crashes (it's a PVC), deleted at job GC.
  * ``SimLearner`` — workload model for scheduler-scale benchmarks: runs for
    ``sim_duration`` clock-seconds, optionally writing checkpoints.
  * ``RealLearner`` — an actual JAX training loop (model from configs/,
    optimizer, data pipeline, checkpoint/restore through the object store):
    the platform path used by examples/ and the overhead benchmark. On
    restart it searches the bucket for the latest valid checkpoint and
    resumes — the paper's recovery contract.

Learners never talk to the Guardian directly: they write
``status/learner-<k>`` and ``exit/learner-<k>`` files; the controller helper
(controller.py) relays them to etcd.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Optional

from repro.ckpt import checkpoint as ckpt
from repro.core.types import EventLog, JobManifest


class JobVolume:
    """Shared NFS volume (PVC): survives pod crashes, deleted at job GC."""

    def __init__(self, job_id: str):
        self.job_id = job_id
        self.files: dict[str, str] = {}
        self.provisioned = True

    def write(self, path: str, content: str):
        if not self.provisioned:
            raise IOError(f"volume for {self.job_id} not provisioned")
        self.files[path] = content

    def read(self, path: str) -> Optional[str]:
        if not self.provisioned:
            raise IOError(f"volume for {self.job_id} not provisioned")
        return self.files.get(path)

    def list(self, prefix: str = "") -> list[str]:
        return sorted(k for k in self.files if k.startswith(prefix))


@dataclass
class LearnerContext:
    job_id: str
    learner_idx: int
    manifest: JobManifest
    volume: JobVolume
    clock: Any
    events: EventLog
    objstore: Any  # ObjectStore (checkpoints + results)

    @property
    def pod_name(self) -> str:
        return f"{self.job_id}-l{self.learner_idx}"

    def set_status(self, status: str, extra: Optional[dict] = None):
        payload = {"status": status, "ts": self.clock.now(),
                   "step": (extra or {}).get("step", 0)}
        payload.update(extra or {})
        self.volume.write(f"status/learner-{self.learner_idx}",
                          json.dumps(payload))

    def write_exit(self, code: int, msg: str = ""):
        self.volume.write(f"exit/learner-{self.learner_idx}",
                          json.dumps({"code": code, "msg": msg,
                                      "ts": self.clock.now()}))

    def log(self, line: str):
        prev = self.volume.files.get(f"logs/learner-{self.learner_idx}", "")
        self.volume.write(f"logs/learner-{self.learner_idx}",
                          prev + line + "\n")


class SimLearner:
    """Clock-driven workload model (used by scale/scheduling benchmarks).

    Phases: DOWNLOADING (data_latency) → PROCESSING (sim_duration) →
    STORING (store_latency) → exit 0. ``kill()`` models a process crash;
    progress resumes from the last checkpoint boundary.
    """

    DATA_LATENCY = 30.0
    STORE_LATENCY = 10.0
    CKPT_PERIOD = 120.0  # sim-seconds of work per checkpoint

    def __init__(self, ctx: LearnerContext, slowdown: float = 1.0):
        self.ctx = ctx
        self.slowdown = slowdown
        self.phase = "INIT"
        self.progress = 0.0  # seconds of work completed
        self.checkpointed = 0.0  # durable progress
        self._phase_started = None
        self.done = False
        self.stalled = False  # chaos: silent straggler (alive, no progress)

    def stall(self):
        self.stalled = True

    def start(self, resume: bool = False):
        self.phase = "DOWNLOADING"
        self._phase_started = self.ctx.clock.now()
        if resume:
            # durable progress lives on the volume (survives process death)
            raw = self.ctx.volume.read(f"ckpt/learner-{self.ctx.learner_idx}")
            self.checkpointed = float(raw) if raw else 0.0
            self.progress = self.checkpointed
        self.ctx.set_status("DOWNLOADING")

    def kill(self):
        self.phase = "DEAD"

    def tick(self):
        if self.phase in ("INIT", "DEAD") or self.done:
            return
        now = self.ctx.clock.now()
        dur = self.ctx.manifest.sim_duration or 60.0
        if self.phase == "DOWNLOADING":
            if now - self._phase_started >= self.DATA_LATENCY:
                self.phase = "PROCESSING"
                self._phase_started = now
                self._last = now
                self.ctx.set_status("PROCESSING")
                # learners log to stdout; the LogCollector tails it into
                # the searchable index (§3.2) — and `logs --follow` streams
                # it live over the wire
                self.ctx.log(f"processing started "
                             f"(target {dur:.0f} sim-seconds)")
            return
        if self.phase == "PROCESSING":
            if not self.stalled:
                self.progress += (now - self._last) / self.slowdown
            self._last = now
            self.ctx.set_status("PROCESSING", {"progress": self.progress})
            if self.progress - self.checkpointed >= self.CKPT_PERIOD:
                self.checkpointed = self.progress
                self.ctx.volume.write(
                    f"ckpt/learner-{self.ctx.learner_idx}",
                    str(self.checkpointed))
                self.ctx.log(f"checkpointed at progress "
                             f"{self.checkpointed:.0f}/{dur:.0f}")
            if self.progress >= dur:
                self.phase = "STORING"
                self._phase_started = now
                self.ctx.set_status("STORING")
                self.ctx.log("storing results")
            return
        if self.phase == "STORING":
            if now - self._phase_started >= self.STORE_LATENCY:
                self.done = True
                self.ctx.set_status("COMPLETED", {"progress": self.progress})
                self.ctx.log("completed")
                self.ctx.write_exit(0)


class RealLearner:
    """An actual JAX training job driven through the platform.

    Runs ``steps_per_tick`` real optimizer steps per platform tick;
    checkpoints every ``manifest.checkpoint_interval`` steps to the object
    store; on (re)start, resumes from the newest valid checkpoint.
    """

    def __init__(self, ctx: LearnerContext, steps_per_tick: int = 5):
        self.ctx = ctx
        self.steps_per_tick = steps_per_tick
        self.phase = "INIT"
        self.done = False
        self._state = None
        self._train_step = None
        self._data = None
        self._bucket = None
        self.loss_history: list[tuple[int, float]] = []

    # -- setup ----------------------------------------------------------
    def _build(self):
        import jax
        from repro.configs import get_tiny_config, get_config
        from repro.data.objectstore import MountedBucket
        from repro.data.pipeline import DataConfig, SyntheticLM
        from repro.models import steps as msteps
        from repro.optim import adamw

        m = self.ctx.manifest
        t = m.train
        cfg = (get_tiny_config(m.arch) if t.get("tiny", True)
               else get_config(m.arch))
        for k, v in t.get("overrides", {}).items():
            cfg = cfg.replace(**{k: v})
        self.cfg = cfg
        self.total_steps = int(t.get("steps", 100))
        opt_cfg = adamw.AdamWConfig(
            lr=t.get("lr", 3e-4), warmup_steps=t.get("warmup", 10),
            total_steps=self.total_steps)
        self._train_step = jax.jit(msteps.make_train_step(cfg, opt_cfg))
        self._data = SyntheticLM(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=t.get("seq", 128),
            global_batch=t.get("batch", 8), seed=t.get("seed", 0)))
        self._bucket = MountedBucket(self.ctx.objstore,
                                     self.ctx.manifest.results_bucket)
        self.ctx.objstore.create_bucket(self.ctx.manifest.results_bucket)
        self._ckpt_prefix = f"{self.ctx.job_id}/ckpt"

        # Resume from the latest valid checkpoint if one exists (§3.8).
        latest = ckpt.latest_step(self._bucket, self._ckpt_prefix)
        if latest is not None:
            abstract = jax.eval_shape(
                lambda: msteps.init_train_state(cfg, jax.random.key(0)))
            self._state, meta = ckpt.restore(self._bucket, self._ckpt_prefix,
                                             latest, like=abstract)
            self._state = jax.tree.map(jax.numpy.asarray, self._state)
            self.ctx.log(f"resumed from checkpoint step {latest}")
            self.ctx.events.emit("learner", "resume_from_checkpoint",
                                 job=self.ctx.job_id, step=latest)
        else:
            self._state = msteps.init_train_state(
                cfg, jax.random.key(int(t.get("seed", 0))))

    def start(self, resume: bool = False):
        self.phase = "DOWNLOADING"
        self.ctx.set_status("DOWNLOADING")

    def kill(self):
        self.phase = "DEAD"
        self._state = None  # lose in-memory state, like a real process crash
        self._train_step = None

    @property
    def step(self) -> int:
        return int(self._state.step) if self._state is not None else 0

    def tick(self):
        if self.phase in ("INIT", "DEAD") or self.done:
            return
        if self.phase == "DOWNLOADING":
            try:
                self._build()
            except Exception as e:  # surfaces as learner failure
                self.ctx.log(f"fatal: {e}")
                self.ctx.set_status("FAILED", {"error": str(e)})
                self.ctx.write_exit(1, str(e))
                self.done = True
                return
            self.phase = "PROCESSING"
            self.ctx.set_status("PROCESSING", {"step": self.step})
            return
        if self.phase == "PROCESSING":
            import numpy as np
            m = self.ctx.manifest
            last_metrics = None
            for _ in range(self.steps_per_tick):
                step = self.step
                if step >= self.total_steps:
                    break
                batch = self._data.batch_at(step)
                self._state, metrics = self._train_step(self._state, batch)
                last_metrics = (step, metrics)
                if (step + 1) % m.checkpoint_interval == 0:
                    loss = float(metrics["loss"])
                    ckpt.save(self._bucket, self._ckpt_prefix, step + 1,
                              self._state, {"loss": loss})
                    self.ctx.events.emit("learner", "checkpoint",
                                         job=self.ctx.job_id, step=step + 1)
            # status/metric sync once per tick (periodic updates, §2) — not
            # per step, so the platform never serializes the device queue.
            if last_metrics is not None:
                step, metrics = last_metrics
                loss = float(metrics["loss"])
                self.loss_history.append((step, loss))
                if not np.isfinite(loss):
                    self.ctx.set_status("FAILED", {"error": "nan loss"})
                    self.ctx.write_exit(2, "non-finite loss")
                    self.done = True
                    return
            self.ctx.set_status("PROCESSING", {"step": self.step})
            if self.step >= self.total_steps:
                self.phase = "STORING"
                self.ctx.set_status("STORING", {"step": self.step})
            return
        if self.phase == "STORING":
            ckpt.save(self._bucket, self._ckpt_prefix, self.step,
                      self._state, {"final": True})
            self._bucket.write(f"{self.ctx.job_id}/model/DONE",
                               json.dumps({"steps": self.step}))
            self.done = True
            self.ctx.set_status("COMPLETED", {"step": self.step})
            self.ctx.write_exit(0)


def make_learner(ctx: LearnerContext):
    if ctx.manifest.arch is not None:
        return RealLearner(ctx)
    return SimLearner(ctx)
