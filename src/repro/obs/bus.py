"""The per-shard platform event bus (FfDL §4's audit/event trail).

Promoted from the original ``core.types.EventLog`` (an unbounded
in-process list) into a first-class observability primitive:

  * every event carries a **monotonic sequence number** — per shard,
    starting at 1, never reused for the life of the process — so a wire
    cursor (``seq``) identifies a position in the stream exactly once;
  * retention is a **bounded ring**: at least the most recent
    ``retention`` events are kept; older ones are dropped in batches
    (amortised O(1) per emit) and every drop is explicit —
    ``dropped_total`` counts them and a cursor reader is told how many
    events in its range were lost (``missed``), never silently skipped;
  * events are stamped with the owning **tenant** where one can be
    resolved (an explicit ``tenant=`` field, else the ``job=`` field
    through ``tenant_resolver``), which is what makes tenant-scoped
    visibility on ``GET /v2/events`` possible: a tenant key sees only
    events stamped with its own tenant, an admin key sees everything;
  * ``subscribe()`` lets in-process taps (the usage meter) observe every
    emit without polling.

Compatibility: ``EventLog(clock)`` construction still works (retention
and shard id default), ``emit``/``of_kind`` keep their shapes, and
``count(kind)`` stays exact for the **whole lifetime** of the bus — a
per-kind counter survives ring compaction, so a test that counts
``job_failed`` over a long campaign is unaffected by retention.
``of_kind``/``events`` expose the *retained* window only.

Emits and reads take a small internal mutex: emit sites run under shard
write locks, but the rate limiter emits ``rate_limited`` from HTTP
handler threads without any shard lock, and ``/v2/events`` reads under
the shard read lock — the bus must be safe under that mix.
"""

from __future__ import annotations

import threading
from collections import Counter
from dataclasses import dataclass, field
from typing import Callable, Optional

# Default retained-window size. Large enough that every existing test and
# benchmark consumer sees the same counts it saw when the log was
# unbounded; campaigns that mine the full history (benchmarks/failures.py)
# pass an explicit larger retention.
DEFAULT_RETENTION = 1_000_000

# The pinned wire vocabulary: event kinds operators (and the operator
# loop) may key automation on, mapped to their emit sites in
# docs/architecture.md ("Observability plane") and checked by
# tests/test_docs_api.py. This list is COMPLETE by construction: the
# REG-EVENT analyzer (python -m repro.analysis) fails the build if any
# component emits a literal kind that is not registered here — an
# operator keying automation on /v2/events can trust the vocabulary.
PLATFORM_EVENT_KINDS = (
    # job lifecycle (guardian / api)
    "job_submitted", "submit_deduplicated", "admission_rejected",
    "job_completed", "job_failed", "job_halted",
    # scheduler / admission
    "gang_queued", "gang_placed", "no_nodes_available",
    "over_quota_admit", "preempt",
    # cluster / chaos
    "node_cordoned", "node_notready", "pod_evicted",
    "learner_killed", "host_killed", "controller_killed",
    # control plane
    "migration_phase", "lb_failover", "replica_crashed", "api_restarted",
    # backpressure (emitted by the rate limiter, no shard lock held)
    "rate_limited",
    # autonomous operator (repro.obs.operator: every reconciler action is
    # journaled so the decision log is auditable from /v2/events too)
    "operator_scale_up", "operator_scale_down", "operator_isolate_tenant",
    "operator_rollout_wave", "operator_rollout_done",
    "operator_rollout_halted", "operator_rollback",
    "operator_gray_restart",
    # gray-failure resilience (repro.core.faults defenses): a shard tick
    # that outlived its deadline budget (Federation.tick records the
    # overrun on the shard's breaker and keeps the fleet ticking)
    "shard_tick_deadline",
    # declarative workloads (repro.workloads: plane apply/delete plus
    # every reconciler act — pipelines, recurring jobs, serving tier)
    "workload_applied", "workload_deleted",
    "workload_stage_submitted", "workload_stage_failed",
    "workload_pipeline_done", "workload_pipeline_degraded",
    "workload_recurring_run", "workload_recurring_skipped",
    "workload_service_scaled", "workload_service_ready",
    "workload_service_degraded",
    # pod/node lifecycle (repro.core.cluster): the Kubernetes-shaped
    # bind/run/delete trail every gang leaves behind
    "pod_bound", "pod_running", "pod_deleted", "pod_failed",
    "pod_restarted", "binding_rejected", "node_ready",
    # guardian recovery ladder (repro.core.guardian): per-job Helm-style
    # deployer decisions — restarts, rollbacks, gang requeues
    "guardian_crashed", "guardian_restarted", "guardian_created",
    "lcm_restarted", "rollback", "learner_restart", "learners_replaced",
    "straggler_restart", "gang_requeue", "bind_failed",
    "volume_provision_failed",
    # learner checkpointing (repro.core.executor)
    "checkpoint", "resume_from_checkpoint",
    # controller status relay (repro.core.controller)
    "status_relay_error",
)


@dataclass
class Event:
    ts: float
    component: str
    kind: str
    fields: dict
    # bus-assigned: position in the shard's stream (1-based, monotonic)
    seq: int = 0
    # owning tenant where resolvable; None = platform-internal (admin-only
    # visibility on the wire)
    tenant: Optional[str] = None


class EventBus:
    def __init__(self, clock, retention: int = DEFAULT_RETENTION,
                 shard_id: str = "shard-0"):
        if retention < 1:
            raise ValueError("retention must be >= 1")
        self.clock = clock
        self.retention = retention
        self.shard_id = shard_id
        self.dropped_total = 0
        # job_id -> tenant (or None); installed by the owning platform so
        # events carrying a job= field get stamped with their tenant
        self.tenant_resolver: Optional[Callable[[str], Optional[str]]] = None
        self._events: list[Event] = []  # retained window, oldest..newest
        self._first_seq = 1             # seq of _events[0]
        self._next_seq = 1
        # Drop in batches: del list[:k] is O(window), so a batch of
        # retention/16 keeps compaction amortised O(1) per emit. The
        # window briefly holds up to retention+batch-1 events (never
        # fewer than retention — the ring over-delivers, never under).
        self._batch = max(1, retention // 16)
        self._kind_counts: Counter = Counter()  # exact for all time
        self._subs: list[Callable[[Event], None]] = []
        self._lock = threading.Lock()

    # -- write side --------------------------------------------------------
    def emit(self, component: str, kind: str, **fields) -> Event:
        tenant = fields.get("tenant")
        if tenant is None and self.tenant_resolver is not None:
            job = fields.get("job")
            if job is not None:
                try:
                    tenant = self.tenant_resolver(job)
                except Exception:
                    tenant = None  # metastore down mid-emit: stay unstamped
        with self._lock:
            e = Event(self.clock.now(), component, kind, fields,
                      seq=self._next_seq, tenant=tenant)
            self._next_seq += 1
            self._events.append(e)
            self._kind_counts[kind] += 1
            if len(self._events) >= self.retention + self._batch:
                n = len(self._events) - self.retention
                del self._events[:n]
                self._first_seq += n
                self.dropped_total += n
            subs = list(self._subs)
        for fn in subs:  # outside the lock: a tap must not block emitters
            try:
                fn(e)
            except Exception:
                pass  # a broken tap must never take the platform down
        return e

    def subscribe(self, fn: Callable[[Event], None]):
        with self._lock:
            self._subs.append(fn)

    # -- read side ---------------------------------------------------------
    @property
    def events(self) -> list:
        """The retained window (oldest..newest). Compatibility surface —
        prefer ``since``/``read_since`` for anything cursor-shaped."""
        return self._events

    @property
    def seq(self) -> int:
        """High-water mark: seq of the newest event (0 when none yet)."""
        return self._next_seq - 1

    @property
    def first_seq(self) -> int:
        """Seq of the oldest retained event (``dropped_total + 1``)."""
        return self._first_seq

    def of_kind(self, kind: str) -> list:
        with self._lock:
            return [e for e in self._events if e.kind == kind]

    def count(self, kind: str) -> int:
        """Exact all-time count — survives ring compaction."""
        with self._lock:
            return self._kind_counts[kind]

    def since(self, seq: int) -> list:
        """Retained events with ``seq > seq`` (benchmark capture marks)."""
        with self._lock:
            idx = max(0, seq + 1 - self._first_seq)
            return self._events[idx:]

    def read_since(self, cursor: int, limit: int,
                   visible: Optional[Callable[[Event], bool]] = None,
                   kind: Optional[str] = None
                   ) -> tuple[list, int, int]:
        """One cursor page: up to ``limit`` events with ``seq > cursor``
        that pass the ``visible``/``kind`` filters.

        Returns ``(events, next_cursor, missed)``. ``next_cursor`` is the
        seq of the last event *scanned* (not just served): filtered-out
        events are consumed by the walk, and a scan that drains the bus
        jumps to the high-water mark so the next poll starts fresh. A
        served seq is therefore never served again on the same cursor
        chain — the exactly-once half of the contract; ``missed`` is the
        explicit other half: how many events in ``(cursor, first_seq)``
        retention already dropped before this read."""
        with self._lock:
            start = cursor + 1
            missed = max(0, min(self._first_seq, self._next_seq) - start)
            idx = max(0, start - self._first_seq)
            out: list[Event] = []
            last = max(cursor, self._first_seq - 1)
            for e in self._events[idx:]:
                last = e.seq
                if kind is not None and e.kind != kind:
                    continue
                if visible is not None and not visible(e):
                    continue
                out.append(e)
                if len(out) >= limit:
                    break
            return out, max(cursor, last), missed


def event_to_wire(e: Event, shard_id: str) -> dict:
    """The pinned /v2/events item shape."""
    return {"seq": e.seq, "ts": e.ts, "shard": shard_id,
            "component": e.component, "kind": e.kind,
            "tenant": e.tenant, "fields": dict(e.fields)}
