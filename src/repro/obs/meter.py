"""Per-tenant usage metering (FfDL §4: the billing/diagnosis requirement).

One :class:`UsageMeter` per shard, owned by its platform. Sources:

  * **chip-seconds** — accrued by ``FfDLPlatform.tick()`` for every job
    holding chips that round (``gang_chips × tick_period`` while the job
    is in a chip-holding status), so a federation aggregates usage one
    tick at a time — exactly the cadence the paper bills at;
  * **job outcomes** — ``jobs_submitted`` / ``jobs_completed`` /
    ``jobs_failed``, tapped off the shard's event bus (:func:`install_meter`
    subscribes to the lifecycle kinds; the bus stamps each event with its
    tenant via the platform's resolver);
  * **log bytes** — the ``LogIndex`` append hook (bytes of every line a
    tenant's learners emit through the collector; migrated lines are NOT
    re-billed on import);
  * **429s** — ``throttled_429s``, tapped off the ``rate_limited`` events
    the rate limiter emits (satellite: throttling is operator-visible).

The meter is wire-addressable as ``GET /v1/usage`` (a tenant sees its own
row, an admin sees all, summed across every shard) and feeds the
per-tenant families of ``GET /metrics``.
"""

from __future__ import annotations

import threading
from typing import Dict, Iterable, Optional

# The pinned usage-resource field vocabulary (docs/api.md).
# serving_replica_seconds: ready inference replicas × tick_period,
# accrued by the workloads reconciler at the same cadence chip_seconds
# accrue (a replica that is up but not yet ready bills chips, not this).
USAGE_FIELDS = ("chip_seconds", "jobs_submitted", "jobs_completed",
                "jobs_failed", "log_bytes", "throttled_429s",
                "serving_replica_seconds")

# event kind → usage field, for the bus tap
_KIND_FIELD = {
    "job_submitted": "jobs_submitted",
    "job_completed": "jobs_completed",
    "job_failed": "jobs_failed",
    "rate_limited": "throttled_429s",
}


class UsageMeter:
    """Thread-safe per-tenant counters; ``chip_seconds`` is a float,
    everything else integers."""

    def __init__(self):
        self._lock = threading.Lock()
        self._by_tenant: Dict[str, dict] = {}

    def _row(self, tenant: str) -> dict:
        row = self._by_tenant.get(tenant)
        if row is None:
            row = self._by_tenant[tenant] = dict.fromkeys(USAGE_FIELDS, 0)
            row["chip_seconds"] = 0.0
        return row

    def bump(self, tenant: str, field: str, n=1):
        if field not in USAGE_FIELDS:
            raise ValueError(f"unknown usage field {field!r}")
        with self._lock:
            self._row(tenant)[field] += n

    def get(self, tenant: str) -> dict:
        with self._lock:
            return dict(self._by_tenant.get(tenant) or
                        dict.fromkeys(USAGE_FIELDS, 0))

    def snapshot(self) -> Dict[str, dict]:
        """``{tenant: {field: value}}`` — a consistent copy."""
        with self._lock:
            return {t: dict(row) for t, row in self._by_tenant.items()}

    @staticmethod
    def merge(snapshots: Iterable[Dict[str, dict]],
              tenant: Optional[str] = None) -> Dict[str, dict]:
        """Sum per-shard snapshots into one usage view (optionally for a
        single tenant) — a migrated tenant's history stays whole because
        both shards' meters contribute."""
        merged: Dict[str, dict] = {}
        for snap in snapshots:
            for t, row in snap.items():
                if tenant is not None and t != tenant:
                    continue
                agg = merged.setdefault(t, dict.fromkeys(USAGE_FIELDS, 0))
                for f in USAGE_FIELDS:
                    agg[f] += row.get(f, 0)
        return merged


def install_meter(bus, meter: UsageMeter):
    """Subscribe ``meter`` to the lifecycle/backpressure kinds on ``bus``.
    Events without a resolved tenant are not billed (there is nobody to
    bill them to); they stay visible to admins on /v2/events."""
    def tap(e):
        field = _KIND_FIELD.get(e.kind)
        if field is not None and e.tenant is not None:
            meter.bump(e.tenant, field)
    bus.subscribe(tap)
