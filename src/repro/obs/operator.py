"""Autonomous operator loop (ROADMAP: the reconciler that drives the v2 plane).

FfDL's retrospective (§6) and Boag et al. 2018 both land on the same
conclusion: a multi-tenant platform's reaction to load and faults must be
automated.  PRs 5–6 built every primitive — per-shard occupancy, cordon /
drain, WAL-consistent migrations, the event bus, per-tenant metering — and
this module closes the loop with a watch → decide → act reconciler that
runs once per :meth:`Federation.tick`:

  * **shard autoscaling** — when fleet chip occupancy stays above
    ``high_water`` for ``streak_ticks`` consecutive ticks, spawn a fresh
    shard and drain the hottest tenant of the most-occupied shard into
    it; when occupancy stays below ``low_water``, drain the emptiest
    shard and retire it once its last resident has moved;
  * **hot-tenant isolation** — when one tenant accounts for more than
    ``hot_share`` of a shard's windowed heat (chip-seconds plus weighted
    429s), migrate it to the quietest shard;
  * **rolling shard upgrades** — GUARD-style progressive waves (drain →
    restart at the target version → uncordon, one shard per wave) with
    pre/post health validation; any shard death or post-restart failure
    regression halts the rollout and rolls the current wave back
    (uncordon + migrate the drained tenants home).

The split below is deliberate: :class:`OperatorPolicy` is a *pure* state
machine — ``decide(obs)`` maps an observation dict to a list of decision
dicts with no I/O, no clock and no RNG, and sorts every candidate list
internally so the decisions are a deterministic function of the observed
stats regardless of how the observation was enumerated (the property test
replays one trace under shuffled shard orders and asserts identical
logs).  :class:`Operator` wraps it with sensing (reads shard stats under
the plane mutex, exactly like ``shard_view``) and acting (the same
``/v2/admin`` verbs a human admin would call), journaling every action as
an ``operator_*`` platform event.
"""

from __future__ import annotations

import collections
import copy
import threading
from dataclasses import asdict, dataclass
from typing import Deque, Dict, List, Optional

# Decision/event vocabulary.  Every act the operator takes is journaled
# on the event bus under one of these kinds (docs/api.md pins them; they
# are part of PLATFORM_EVENT_KINDS in repro.obs.bus).
OPERATOR_EVENT_KINDS = (
    "operator_scale_up",
    "operator_scale_down",
    "operator_isolate_tenant",
    "operator_rollout_wave",
    "operator_rollout_done",
    "operator_rollout_halted",
    "operator_rollback",
    "operator_gray_restart",
)

_NEVER = -(10 ** 9)


@dataclass(frozen=True)
class OperatorConfig:
    """Thresholds for the reconciler (docs/architecture.md documents each)."""

    high_water: float = 0.85        # fleet occupancy that triggers scale-up
    low_water: float = 0.20         # fleet occupancy that triggers scale-down
    streak_ticks: int = 3           # consecutive ticks past a mark to act
    cooldown_ticks: int = 20        # min ticks between scaling actions
    min_shards: int = 2             # never scale below this many active shards
    max_shards: int = 8             # never scale above this many open shards
    hot_share: float = 0.60         # tenant share of shard heat to isolate
    min_heat: float = 1.0           # ignore shards cooler than this
    heat_window: int = 8            # ticks of usage deltas summed into heat
    heat_429_weight: float = 1.0    # 429s count this many chip-seconds each
    isolate_cooldown_ticks: int = 50  # per-tenant gap between isolations
    validate_ticks: int = 3         # post-restart health-watch ticks per wave
    allowed_failures: int = 0       # job_failed regressions tolerated per wave
    gray_cooldown_ticks: int = 30   # min ticks between restarts of one shard
    max_decisions: int = 200        # decision-log ring size


class OperatorPolicy:
    """Pure decision core: ``decide(obs)`` -> list of decision dicts.

    Holds only counters and the rollout state machine; never touches the
    federation.  All candidate selections sort by (metric, id) so ties —
    and therefore whole decision logs — are deterministic.
    """

    def __init__(self, config: OperatorConfig):
        self.config = config
        self.tick = 0
        self.high_streak = 0
        self.low_streak = 0
        self.last_scale_tick = _NEVER
        self.retiring: Optional[str] = None   # shard draining toward retirement
        self.rollout: Optional[dict] = None
        self.last_occupancy = 0.0
        self._isolated_at: Dict[str, int] = {}
        self._gray_at: Dict[str, int] = {}
        self.decisions: Deque[dict] = collections.deque(
            maxlen=config.max_decisions)

    # -- rollout requests (called via the admin plane) ---------------------
    def rollout_live(self) -> bool:
        return (self.rollout is not None
                and self.rollout["state"] not in ("done", "halted"))

    def request_rollout(self, version: str):
        """Record a rollout request; waves start on the next decide()."""
        from repro.api.types import ApiError, ErrorCode
        if self.rollout_live():
            raise ApiError(
                ErrorCode.CONFLICT,
                f"rollout to {self.rollout['version']!r} is already "
                f"{self.rollout['state']}", version=self.rollout["version"])
        self.rollout = {"version": version, "state": "starting",
                        "wave": 0, "shard": None, "pending": None,
                        "upgraded": [], "drained": [], "validate_left": 0,
                        "fail_base": 0, "error": ""}

    # -- the decision function ---------------------------------------------
    def _log(self, decision: dict) -> dict:
        decision = {"tick": self.tick, **decision}
        self.decisions.append(decision)
        return decision

    def decide(self, obs: dict) -> List[dict]:
        cfg = self.config
        self.tick = obs["tick"]
        out: List[dict] = []
        shards = sorted((dict(s) for s in obs["shards"]),
                        key=lambda s: s["shard_id"])
        for s in shards:
            # canonical resident order: float sums and max() tie-breaks
            # below must not depend on how the observation enumerated them
            s["tenants"] = sorted(s["tenants"])
        heat = obs["tenant_heat"]
        open_ = [s for s in shards if s["alive"] and not s["retired"]]
        active = [s for s in open_ if not s["cordoned"]]
        down = [s["shard_id"] for s in shards
                if not s["alive"] and not s["retired"]]
        live_migs = obs["live_migrations"]

        total = sum(s["chips_total"] for s in open_)
        used = sum(s["chips_used"] for s in open_)
        occ = (used / total) if total else 0.0
        self.last_occupancy = occ

        # 0. finish a pending retirement: the drain we started earlier has
        # moved the last resident off — fence the shard out of the fleet.
        if self.retiring is not None:
            s = next((x for x in shards if x["shard_id"] == self.retiring),
                     None)
            if s is None or s["retired"]:
                self.retiring = None
            elif s["alive"] and not s["tenants"] and not live_migs:
                out.append(self._log({
                    "action": "retire_shard", "shard": self.retiring,
                    "reason": "drain complete; no residents remain"}))
                self.retiring = None

        # 0b. gray-failure response: a shard that is ALIVE but whose
        # circuit breaker is open is wedged, not dead — liveness checks
        # miss it (that is what makes the failure gray). A restart clears
        # the wedge (WAL recovery; the breaker resets closed); if the
        # shard is still sick the breaker re-opens and, after the
        # cooldown, we try again rather than flap every tick.
        for s in shards:
            if (s["alive"] and not s["retired"]
                    and s.get("breaker", "closed") == "open"
                    and self.tick - self._gray_at.get(
                        s["shard_id"], _NEVER) >= cfg.gray_cooldown_ticks):
                out.append(self._log({
                    "action": "gray_restart", "shard": s["shard_id"],
                    "reason": (f"breaker open on alive shard "
                               f"{s['shard_id']}: gray failure — restart "
                               f"to clear the wedge")}))
                self._gray_at[s["shard_id"]] = self.tick

        # 1. a live rollout owns the fleet: no autoscaling or isolation
        # runs underneath it (scaling mid-wave would fight the drain).
        if self.rollout_live():
            out.extend(self._decide_rollout(shards, active, down, live_migs))
            return out

        # 2. autoscaling streaks (fleet-wide occupancy).
        self.high_streak = self.high_streak + 1 if occ >= cfg.high_water else 0
        self.low_streak = self.low_streak + 1 if occ <= cfg.low_water else 0
        cooled = self.tick - self.last_scale_tick >= cfg.cooldown_ticks
        if (self.high_streak >= cfg.streak_ticks and cooled and active
                and not live_migs and not down
                and len(open_) < cfg.max_shards):
            donor = max(active, key=lambda s: (
                (s["chips_used"] / s["chips_total"]) if s["chips_total"]
                else 0.0, s["shard_id"]))
            d = {"action": "scale_up", "to_shard": obs["next_shard_id"],
                 "occupancy": round(occ, 4),
                 "reason": (f"fleet occupancy {occ:.2f} >= "
                            f"{cfg.high_water} for {self.high_streak} "
                            f"ticks")}
            hot = max(donor["tenants"],
                      key=lambda t: (heat.get(t, 0.0), t), default=None)
            if hot is not None:
                d["migrate_tenant"] = hot
                d["from_shard"] = donor["shard_id"]
            out.append(self._log(d))
            self.last_scale_tick = self.tick
            self.high_streak = 0
        elif (self.low_streak >= cfg.streak_ticks and cooled
                and not live_migs and not down and self.retiring is None
                and len(active) > cfg.min_shards):
            victim = min(active, key=lambda s: (
                s["active_jobs"], s["jobs"], s["shard_id"]))
            out.append(self._log({
                "action": "scale_down", "shard": victim["shard_id"],
                "occupancy": round(occ, 4),
                "reason": (f"fleet occupancy {occ:.2f} <= {cfg.low_water} "
                           f"for {self.low_streak} ticks; "
                           f"{victim['shard_id']} is emptiest")}))
            self.retiring = victim["shard_id"]
            self.last_scale_tick = self.tick
            self.low_streak = 0

        # 3. hot-tenant isolation (at most one migration kicked per tick,
        # and never while other migrations are in flight).
        if not live_migs and not down and len(active) >= 2:
            for s in active:
                residents = s["tenants"]
                if len(residents) < 2:
                    continue
                shard_heat = sum(heat.get(t, 0.0) for t in residents)
                if shard_heat < cfg.min_heat:
                    continue
                top = max(residents, key=lambda t: (heat.get(t, 0.0), t))
                share = heat.get(top, 0.0) / shard_heat
                if share < cfg.hot_share:
                    continue
                if (self.tick - self._isolated_at.get(top, _NEVER)
                        < cfg.isolate_cooldown_ticks):
                    continue
                others = [x for x in active
                          if x["shard_id"] != s["shard_id"]]
                quiet = min(others, key=lambda x: (
                    sum(heat.get(t, 0.0) for t in x["tenants"]),
                    x["chips_used"], x["shard_id"]))
                out.append(self._log({
                    "action": "isolate_tenant", "tenant": top,
                    "from_shard": s["shard_id"],
                    "to_shard": quiet["shard_id"],
                    "share": round(share, 3),
                    "reason": (f"tenant {top!r} holds {share:.0%} of "
                               f"{s['shard_id']} heat; moving to quietest "
                               f"shard {quiet['shard_id']}")}))
                self._isolated_at[top] = self.tick
                break
        return out

    # -- rollout state machine ---------------------------------------------
    def _halt(self, out: List[dict], reason: str):
        r = self.rollout
        r["state"] = "halted"
        r["error"] = reason
        out.append(self._log({
            "action": "rollout_halt", "shard": r["shard"],
            "wave": r["wave"], "version": r["version"], "reason": reason}))

    def _rollback(self, out: List[dict]):
        r = self.rollout
        out.append(self._log({
            "action": "rollback", "shard": r["shard"],
            "tenants": [t for t, _ in r["drained"]],
            "version": r["version"],
            "reason": "uncordon the wave shard and migrate its drained "
                      "tenants home"}))

    def _next_wave(self, out: List[dict]):
        r = self.rollout
        r["shard"] = r["pending"].pop(0)
        r["wave"] += 1
        r["drained"] = []
        r["state"] = "draining"
        out.append(self._log({
            "action": "rollout_wave", "shard": r["shard"],
            "wave": r["wave"], "version": r["version"],
            "reason": (f"wave {r['wave']}: drain -> restart at "
                       f"{r['version']!r} -> uncordon -> validate")}))

    def _decide_rollout(self, shards, active, down, live_migs) -> List[dict]:
        cfg = self.config
        r = self.rollout
        out: List[dict] = []
        # Health gate shared by every state: ANY open shard down mid-rollout
        # halts the whole rollout — upgrading into a degraded fleet is how
        # rollouts cascade (the ROADMAP chaos ask pins exactly this).
        if down:
            self._halt(out, f"shard {down[0]} went down during wave "
                            f"{r['wave']}")
            if r["shard"] is not None and r["shard"] not in down:
                self._rollback(out)
            return out
        if r["state"] == "starting":
            if live_migs:
                return out  # pre-validation: let the fleet settle first
            r["pending"] = [s["shard_id"] for s in active
                            if s["version"] != r["version"]]
            if not r["pending"]:
                r["state"] = "done"
                out.append(self._log({
                    "action": "rollout_done", "version": r["version"],
                    "waves": 0,
                    "reason": "every shard already runs the target version"}))
                return out
            self._next_wave(out)
            return out
        s = next((x for x in shards if x["shard_id"] == r["shard"]), None)
        if s is None:
            self._halt(out, f"wave shard {r['shard']} vanished")
            return out
        if r["state"] == "draining":
            if not live_migs and s["active_jobs"] == 0 and not s["tenants"]:
                out.append(self._log({
                    "action": "rollout_restart", "shard": r["shard"],
                    "version": r["version"],
                    "reason": "drain complete; restart at target version "
                              "and uncordon"}))
                r["state"] = "validating"
                r["validate_left"] = cfg.validate_ticks
                r["fail_base"] = s["failed_total"]
            return out
        if r["state"] == "validating":
            regressions = s["failed_total"] - r["fail_base"]
            if regressions > cfg.allowed_failures:
                self._halt(out, f"post-restart regression on {r['shard']}: "
                                f"{regressions} job failure(s)")
                self._rollback(out)
                return out
            r["validate_left"] -= 1
            if r["validate_left"] > 0:
                return out
            r["upgraded"].append(r["shard"])
            r["shard"] = None
            r["drained"] = []
            if r["pending"]:
                self._next_wave(out)
            else:
                r["state"] = "done"
                out.append(self._log({
                    "action": "rollout_done", "version": r["version"],
                    "waves": r["wave"],
                    "reason": f"all {r['wave']} wave(s) validated healthy"}))
            return out
        return out


class Operator:
    """Sense → decide → act against a :class:`~repro.api.federation.Federation`.

    ``step()`` runs on the tick thread under the admin-plane mutex (plane
    mutex → shard lock, the same ordering every admin verb uses), so its
    actions serialize with concurrent admin verbs and its observations are
    as consistent as ``shard_view``'s.
    """

    def __init__(self, federation, config: Optional[OperatorConfig] = None):
        self.fed = federation
        self.config = config or OperatorConfig()
        self.policy = OperatorPolicy(self.config)
        self._mutex = threading.RLock()
        self._ticks = 0
        self._usage_prev: Dict[str, List[float]] = {}
        self._heat_win: Dict[str, Deque[float]] = {}

    # -- wire surface -------------------------------------------------------
    def status_view(self) -> dict:
        from repro.api.types import ADMIN_API_VERSION
        with self._mutex:
            p = self.policy
            return {"api_version": ADMIN_API_VERSION, "enabled": True,
                    "tick": p.tick,
                    "occupancy": round(p.last_occupancy, 4),
                    "retiring": p.retiring,
                    "config": asdict(self.config),
                    "rollout": copy.deepcopy(p.rollout),
                    "decisions": [dict(d) for d in p.decisions]}

    def request_rollout(self, version: str) -> dict:
        from repro.api.types import ApiError, ErrorCode
        with self._mutex:
            if not isinstance(version, str) or not version:
                raise ApiError(ErrorCode.INVALID_ARGUMENT,
                               "version must be a non-empty string")
            self.policy.request_rollout(version)
        return self.status_view()

    # -- the loop -----------------------------------------------------------
    def step(self) -> List[dict]:
        """One reconcile pass; called from Federation.tick after advance()."""
        with self.fed.admin._mutex:
            with self._mutex:
                obs = self._sense()
                decisions = self.policy.decide(obs)
                for d in decisions:
                    self._act(d)
                return decisions

    # -- sensing ------------------------------------------------------------
    def _sense(self) -> dict:
        from repro.api.admin import LIVE_PHASES
        from repro.core.types import TERMINAL, JobStatus
        cfg = self.config
        fed = self.fed
        self._ticks += 1
        usage_tot: Dict[str, List[float]] = {}
        shards = []
        for b in fed.router.backends:
            entry = {"shard_id": b.shard_id, "alive": b.alive,
                     "cordoned": b.cordoned,
                     "breaker": (b.breaker.state
                                 if getattr(b, "breaker", None) is not None
                                 else "closed"),
                     "retired": getattr(b, "retired", False),
                     "version": getattr(b, "version", "v0"),
                     "chips_total": 0, "chips_used": 0, "jobs": 0,
                     "active_jobs": 0, "queue_depth": 0, "tenants": [],
                     "failed_total": 0}
            if b.alive:
                with b.read_locked():
                    p = b.platform
                    meta = p.meta
                    active = 0
                    for st, ids in meta._by_status.items():
                        if st not in TERMINAL and st != JobStatus.HALTED:
                            active += len(ids)
                    entry.update({
                        "chips_total": p.cluster.total_chips,
                        "chips_used": p.cluster.used_chips,
                        "jobs": len(meta._order),
                        "active_jobs": active,
                        "queue_depth": p.scheduler.queue_depth(),
                        "tenants": sorted(
                            t for t, ids in meta._by_tenant.items() if ids),
                        "failed_total": p.events.count("job_failed")})
                    for tenant, row in p.meter.snapshot().items():
                        agg = usage_tot.setdefault(tenant, [0.0, 0.0])
                        agg[0] += row.get("chip_seconds", 0.0)
                        agg[1] += row.get("throttled_429s", 0)
            shards.append(entry)
        # Windowed heat: per-step usage deltas summed over heat_window
        # ticks, so a tenant that WAS hot cools off instead of dominating
        # forever on cumulative counters.
        heat: Dict[str, float] = {}
        for tenant in sorted(set(usage_tot) | set(self._heat_win)):
            cur = usage_tot.get(tenant, [0.0, 0.0])
            prev = self._usage_prev.get(tenant, [0.0, 0.0])
            step = (max(0.0, cur[0] - prev[0])
                    + cfg.heat_429_weight * max(0.0, cur[1] - prev[1]))
            win = self._heat_win.setdefault(
                tenant, collections.deque(maxlen=cfg.heat_window))
            win.append(step)
            heat[tenant] = sum(win)
        self._usage_prev = {t: list(v) for t, v in usage_tot.items()}
        live = sum(1 for m in fed.admin.migrations.values()
                   if m.phase in LIVE_PHASES)
        return {"tick": self._ticks, "shards": shards,
                "live_migrations": live, "tenant_heat": heat,
                "next_shard_id": f"shard-{fed._next_shard_idx}"}

    # -- acting -------------------------------------------------------------
    def _emit(self, kind: str, **fields):
        """Journal an operator event into the first alive, unretired
        shard's bus (deterministic pick; best-effort like _emit_phase)."""
        for b in sorted(self.fed.router.backends, key=lambda b: b.shard_id):
            if b.alive and not getattr(b, "retired", False):
                try:
                    b.platform.events.emit("operator", kind, **fields)
                except Exception:
                    pass
                return

    def _act(self, d: dict):
        from repro.api.types import ApiError
        try:
            self._dispatch(d)
        except ApiError as exc:
            # An admin verb refused the action (e.g. the migration target
            # got cordoned between sense and act). Journal it and, for a
            # rollout wave, halt: a wave whose drain failed must not sit
            # in "draining" forever.
            self.policy._log({"action": "act_failed", "attempted": d["action"],
                              "error": str(exc),
                              "reason": "admin verb rejected the action"})
            if d["action"] == "rollout_wave" and self.policy.rollout:
                self.policy.rollout["state"] = "halted"
                self.policy.rollout["error"] = f"wave drain failed: {exc}"
                self._emit("operator_rollout_halted",
                           shard=d.get("shard"), wave=d.get("wave"),
                           version=d.get("version"),
                           reason=self.policy.rollout["error"])

    def _dispatch(self, d: dict):
        fed = self.fed
        admin = fed.admin
        action = d["action"]
        if action == "scale_up":
            sid = fed.add_shard()
            self._emit("operator_scale_up", shard=sid,
                       occupancy=d["occupancy"], reason=d["reason"])
            if "migrate_tenant" in d:
                admin.start_migration(d["migrate_tenant"], sid)
        elif action == "scale_down":
            admin.drain(d["shard"])
            self._emit("operator_scale_down", shard=d["shard"],
                       occupancy=d["occupancy"], reason=d["reason"])
        elif action == "retire_shard":
            fed.retire_shard(d["shard"])
        elif action == "isolate_tenant":
            admin.start_migration(d["tenant"], d["to_shard"])
            self._emit("operator_isolate_tenant", tenant=d["tenant"],
                       from_shard=d["from_shard"], to_shard=d["to_shard"],
                       share=d["share"], reason=d["reason"])
        elif action == "rollout_wave":
            self._emit("operator_rollout_wave", shard=d["shard"],
                       wave=d["wave"], version=d["version"])
            result = admin.drain(d["shard"])
            drained = [(admin.migrations[mid].tenant, d["shard"])
                       for mid in result["migrations"]]
            self.policy.rollout["drained"] = drained
        elif action == "gray_restart":
            b = fed.router.backend(d["shard"])
            version = getattr(b, "version", "v0")
            b.crash()
            b.restart(version=version)
            self._emit("operator_gray_restart", shard=d["shard"],
                       reason=d["reason"])
        elif action == "rollout_restart":
            b = fed.router.backend(d["shard"])
            b.crash()
            b.restart(version=d["version"])
            b.uncordon()
        elif action == "rollout_done":
            self._emit("operator_rollout_done", version=d["version"],
                       waves=d["waves"])
        elif action == "rollout_halt":
            self._emit("operator_rollout_halted", shard=d.get("shard"),
                       wave=d["wave"], version=d["version"],
                       reason=d["reason"])
        elif action == "rollback":
            try:
                b = fed.router.backend(d["shard"])
                if b.alive and b.cordoned:
                    b.uncordon()
            except KeyError:
                pass
            from repro.api.types import ApiError
            for tenant in d["tenants"]:
                try:
                    admin.start_migration(tenant, d["shard"])
                except ApiError:
                    pass  # tenant's current shard may be down; best effort
            self._emit("operator_rollback", shard=d["shard"],
                       tenants=d["tenants"], version=d["version"])
