"""Dependency-free Prometheus text exposition (format v0.0.4).

``GET /metrics`` renders whatever the HTTP server scrapes out of its
platform at request time — no background collector thread, no external
client library. Three instrument shapes:

  * counters/gauges are plain numbers read off live objects (scrapes are
    monitoring reads: they tolerate torn values across families rather
    than taking every shard lock);
  * :class:`Histogram` is the one stateful instrument — cumulative
    buckets + sum + count, used for per-route request latency.

``METRIC_NAMES`` pins the family names as wire contract (docs/api.md and
docs/architecture.md map each to its source; tests/test_docs_api.py
enforces the mapping). Renaming one is a breaking change for operator
dashboards — add, don't rename.
"""

from __future__ import annotations

import threading
from typing import Iterable, Optional

# Latency buckets in seconds, tuned for an in-process API: sub-ms for
# indexed reads through to the 10 s long-poll ceiling.
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

# The pinned family vocabulary (see docs/architecture.md for emit sites).
METRIC_NAMES = (
    "ffdl_uptime_ticks",
    "ffdl_shard_up",
    "ffdl_shard_chips_total",
    "ffdl_shard_occupancy_chips",
    "ffdl_scheduler_queue_depth",
    "ffdl_wal_flushes_total",
    "ffdl_breaker_state",
    "ffdl_deadline_exceeded_total",
    "ffdl_events_seq",
    "ffdl_events_dropped_total",
    "ffdl_migrations",
    "ffdl_http_requests_total",
    "ffdl_http_request_latency_seconds",
    "ffdl_http_streams_active",
    "ffdl_http_streams_opened_total",
    "ffdl_http_heartbeats_total",
    "ffdl_rate_limited_total",
    "ffdl_tenant_chip_seconds_total",
    "ffdl_tenant_jobs_total",
    "ffdl_tenant_log_bytes_total",
    "ffdl_tenant_serving_replica_seconds_total",
)


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics): ``observe``
    is O(buckets); ``snapshot`` returns ``(bucket_counts, sum, count)``
    where ``bucket_counts[i]`` counts observations ≤ ``buckets[i]``."""

    def __init__(self, buckets: Iterable[float] = DEFAULT_BUCKETS):
        self.buckets = tuple(sorted(buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket")
        self._counts = [0] * len(self.buckets)
        self._sum = 0.0
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, value: float):
        with self._lock:
            self._sum += value
            self._count += 1
            for i, le in enumerate(self.buckets):
                if value <= le:
                    self._counts[i] += 1

    def snapshot(self):
        with self._lock:
            return list(self._counts), self._sum, self._count


def _escape(value: str) -> str:
    """Label-value escaping per the exposition format."""
    return (str(value).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _labels(labels: Optional[dict]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in labels.items())
    return "{" + inner + "}"


def _num(v) -> str:
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v) if isinstance(v, float) else str(v)


def render_metrics(families: list) -> str:
    """Render ``[(name, type, help, samples)]`` to exposition text.

    ``type`` is ``counter`` / ``gauge`` / ``histogram``. For scalar types
    each sample is ``(labels_dict_or_None, value)``; for histograms each
    sample is ``(labels_dict_or_None, Histogram)`` and expands to the
    ``_bucket``/``_sum``/``_count`` series with ``le`` labels.
    """
    out: list[str] = []
    for name, mtype, help_text, samples in families:
        out.append(f"# HELP {name} {help_text}")
        out.append(f"# TYPE {name} {mtype}")
        for labels, value in samples:
            if mtype == "histogram":
                counts, total, count = value.snapshot()
                base = dict(labels or {})
                for le, c in zip(value.buckets, counts):
                    out.append(f"{name}_bucket"
                               f"{_labels({**base, 'le': _num(float(le))})}"
                               f" {c}")
                out.append(f"{name}_bucket{_labels({**base, 'le': '+Inf'})}"
                           f" {count}")
                out.append(f"{name}_sum{_labels(base or None)} {_num(total)}")
                out.append(f"{name}_count{_labels(base or None)} {count}")
            else:
                out.append(f"{name}{_labels(labels)} {_num(value)}")
    return "\n".join(out) + "\n"
