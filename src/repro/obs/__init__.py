# The observability plane (FfDL §4): the sensor layer the platform's
# operators — human and autonomous — read. Five parts:
#   * bus:     per-shard, sequence-numbered, retention-bounded event bus
#              (promoted from core.types.EventLog) with tenant-scoped
#              visibility, served as GET /v2/events with cursor replay;
#   * meter:   per-tenant usage metering (chip-seconds, job outcomes, log
#              bytes, 429s), served as GET /v1/usage and via /metrics;
#   * metrics: a dependency-free Prometheus text exposition (counters,
#              gauges, histograms) behind GET /metrics;
#   * sse:     Server-Sent-Events framing for the true-streaming transport
#              behind `ffdl logs --follow` / `status --watch` / `events
#              --follow` (long-poll remains the fallback contract);
#   * operator: the autonomous reconciler (shard autoscaling, hot-tenant
#              isolation, health-gated rolling upgrades) closing the loop
#              over the sensors above via the /v2/admin verbs.
from repro.obs.bus import (
    DEFAULT_RETENTION,
    Event,
    EventBus,
    PLATFORM_EVENT_KINDS,
    event_to_wire,
)
from repro.obs.meter import USAGE_FIELDS, UsageMeter, install_meter
from repro.obs.operator import (
    OPERATOR_EVENT_KINDS,
    Operator,
    OperatorConfig,
    OperatorPolicy,
)
from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    Histogram,
    METRIC_NAMES,
    render_metrics,
)
from repro.obs.sse import (
    SSE_CONTENT_TYPE,
    SseMessage,
    format_comment,
    format_event,
    iter_sse,
)

__all__ = [
    "DEFAULT_BUCKETS",
    "DEFAULT_RETENTION",
    "Event",
    "EventBus",
    "Histogram",
    "METRIC_NAMES",
    "OPERATOR_EVENT_KINDS",
    "Operator",
    "OperatorConfig",
    "OperatorPolicy",
    "PLATFORM_EVENT_KINDS",
    "SSE_CONTENT_TYPE",
    "SseMessage",
    "USAGE_FIELDS",
    "UsageMeter",
    "event_to_wire",
    "format_comment",
    "format_event",
    "install_meter",
    "iter_sse",
    "render_metrics",
]
