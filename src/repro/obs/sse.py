"""Server-Sent-Events framing (the true-streaming transport).

Both ends of the wire live here so the framing can't drift: the server
side of ``GET /v1/jobs/{id}/logs``, ``.../status`` and ``GET /v2/events``
emits frames with :func:`format_event` / :func:`format_comment`, and the
client side (``HttpTransport.stream_*``) parses the byte stream back with
:func:`iter_sse`.

Dialect (the standard text/event-stream subset we pin in docs/api.md):

  * ``data:`` lines carry one JSON document per frame (multi-line data is
    rejoined with ``\\n`` by the parser);
  * ``id:`` carries the resume cursor — a client reconnecting sends it
    back as the ``Last-Event-ID`` header and the stream picks up exactly
    after it (the exactly-once contract across disconnects);
  * ``event:`` names the frame: default ``message`` (a payload),
    ``status`` (a status change), ``end`` (terminal — the server is done
    and will close), ``error`` (a mid-stream failure carrying the
    standard error envelope as data);
  * ``: hb`` comment frames are heartbeats — they keep idle connections
    demonstrably alive and carry no data. The parser yields them with
    ``comment`` set so callers (and the benchmark) can count cadence.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Iterator, Optional

SSE_CONTENT_TYPE = "text/event-stream"


@dataclass
class SseMessage:
    data: Optional[str] = None
    event: str = "message"
    id: Optional[str] = None
    comment: Optional[str] = None

    def json(self):
        """Decode the data payload (frames carry one JSON doc)."""
        return json.loads(self.data) if self.data is not None else None


def format_event(data, event: Optional[str] = None,
                 id: Optional[str] = None) -> bytes:
    """One wire frame. ``data`` may be a str (pre-encoded JSON) or any
    JSON-serialisable object."""
    if not isinstance(data, str):
        data = json.dumps(data)
    lines = []
    if event is not None and event != "message":
        lines.append(f"event: {event}")
    if id is not None:
        lines.append(f"id: {id}")
    for part in data.split("\n"):  # payload newlines become data: lines
        lines.append(f"data: {part}")
    return ("\n".join(lines) + "\n\n").encode("utf-8")


def format_comment(text: str = "hb") -> bytes:
    return f": {text}\n\n".encode("utf-8")


def iter_sse(fp) -> Iterator[SseMessage]:
    """Parse an SSE byte stream from a file-like object (``readline`` is
    enough — http.client responses decode chunked transfer transparently).
    Yields one :class:`SseMessage` per blank-line-terminated frame, comment
    frames included; returns on EOF."""
    data_lines: list[str] = []
    event: str = "message"
    id_: Optional[str] = None
    comment: Optional[str] = None
    while True:
        raw = fp.readline()
        if not raw:  # EOF: server closed (clean close or cut)
            return
        line = raw.decode("utf-8", errors="replace").rstrip("\r\n")
        if line == "":
            if data_lines or comment is not None or id_ is not None:
                yield SseMessage(
                    data="\n".join(data_lines) if data_lines else None,
                    event=event, id=id_, comment=comment)
            data_lines, event, id_, comment = [], "message", None, None
            continue
        if line.startswith(":"):
            comment = line[1:].lstrip(" ")
            continue
        field, _, value = line.partition(":")
        value = value[1:] if value.startswith(" ") else value
        if field == "data":
            data_lines.append(value)
        elif field == "event":
            event = value
        elif field == "id":
            id_ = value
