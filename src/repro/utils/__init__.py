from repro.utils.trees import (
    tree_bytes,
    tree_count,
    tree_flatten_with_paths,
    tree_map_with_path,
    path_str,
)

__all__ = [
    "tree_bytes",
    "tree_count",
    "tree_flatten_with_paths",
    "tree_map_with_path",
    "path_str",
]
