"""Small pytree utilities shared across the framework."""

from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np


def path_str(path) -> str:
    """Render a jax key-path as a '/'-joined string, e.g. 'blocks/attn/wq'."""
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_flatten_with_paths(tree) -> list[tuple[str, Any]]:
    """Flatten a pytree into [(path_string, leaf), ...]."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(path_str(path), leaf) for path, leaf in leaves]


def tree_map_with_path(fn: Callable[[str, Any], Any], tree):
    """tree_map where fn receives (path_string, leaf)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(path_str(path), leaf), tree
    )


def _leaf_count(x) -> int:
    if hasattr(x, "shape"):
        return int(np.prod(x.shape)) if x.shape else 1
    return 1


def _leaf_bytes(x) -> int:
    if hasattr(x, "dtype") and hasattr(x, "shape"):
        return _leaf_count(x) * np.dtype(x.dtype).itemsize
    return 0


def tree_count(tree) -> int:
    """Total number of elements across all array leaves."""
    return sum(_leaf_count(l) for l in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes across all array leaves (works on ShapeDtypeStructs too)."""
    return sum(_leaf_bytes(l) for l in jax.tree_util.tree_leaves(tree))
