"""Mesh construction for the production pods.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init, and only
dryrun.py is allowed to force 512 host devices).
"""

from __future__ import annotations

import jax

from repro.parallel.sharding import (
    MULTI_POD_RULES,
    SINGLE_POD_RULES,
    MeshEnv,
    zero1_rules,
)

# v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU integration tests (requires >= data*model devices)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def make_env(mesh, overrides: dict | None = None) -> MeshEnv:
    """MeshEnv with the right rules for this mesh (+ hillclimb overrides)."""
    rules = MULTI_POD_RULES if "pod" in mesh.shape else SINGLE_POD_RULES
    rules = zero1_rules(rules)
    if overrides:
        rules = dict(rules, **overrides)
    return MeshEnv(mesh=mesh, rules=rules)
