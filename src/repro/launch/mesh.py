"""Mesh construction for the production pods.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init, and only
dryrun.py is allowed to force 512 host devices).
"""

from __future__ import annotations

import jax

from repro.parallel.sharding import (
    MULTI_POD_RULES,
    SINGLE_POD_RULES,
    MeshEnv,
    zero1_rules,
)

# v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link


def compat_make_mesh(shape, axes, **kwargs):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (and the
    AxisType enum itself) only exist in newer jax; older ones default to
    Auto semantics anyway, so omit the argument there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs.setdefault("axis_types", (axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, **kwargs)


def compat_cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` across jax versions: older jax returns
    a per-computation list, newer a flat dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU integration tests (requires >= data*model devices)."""
    return compat_make_mesh((data, model), ("data", "model"))


def make_env(mesh, overrides: dict | None = None) -> MeshEnv:
    """MeshEnv with the right rules for this mesh (+ hillclimb overrides)."""
    rules = MULTI_POD_RULES if "pod" in mesh.shape else SINGLE_POD_RULES
    rules = zero1_rules(rules)
    if overrides:
        rules = dict(rules, **overrides)
    return MeshEnv(mesh=mesh, rules=rules)
