"""Loop-aware cost analysis of optimized HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body
exactly ONCE, but our models deliberately use ``lax.scan`` over layers (a
94-layer MoE would be uncompilable unrolled) and scan-blocked flash
attention — so XLA's numbers under-report FLOPs/bytes/collective-bytes by
the trip counts. This module re-derives the three roofline inputs from the
post-SPMD HLO text with loop multipliers applied:

  * **flops** — every ``dot`` (2 * prod(result_dims) * prod(contracted)),
    anywhere in the module (including inside fusions), times the product of
    enclosing while-loop trip counts;
  * **bytes** — per *top-level* instruction of executed computations
    (fusion internals excluded: only a fusion's external operands/results
    touch HBM): result bytes + operand bytes, times loop multiplier;
  * **collective bytes** — per collective instruction,
    max(result, operands) bytes, times loop multiplier.

Trip counts are extracted from each while's condition computation (largest
integer constant — exact for lax.scan's canonical ``iter < N`` condition).
Operand types are resolved through a per-computation symbol table (the
optimized HLO printer references operands by name only).

Validated against cost_analysis() on loop-free modules in
tests/test_hlo_cost.py (exact agreement on dots).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_TYPE_RE = re.compile(
    r"(bf16|f64|f32|f16|f8e4m3|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|u4|s4"
    r"|pred|c64|c128)\[([0-9,]*)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_COMP_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"\b([a-z][\w\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _dims(dims_str: str) -> list:
    return [int(d) for d in dims_str.split(",")] if dims_str else []


@dataclass
class Instr:
    name: str
    opcode: str
    rhs: str
    result_types: list  # [(dtype, [dims]), ...]
    operands: list      # instruction names referenced in the call parens
    attrs: str          # text after the closing operand paren


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    by_name: dict = field(default_factory=dict)


def _type_list(text: str) -> list:
    return [(m.group(1), _dims(m.group(2))) for m in _TYPE_RE.finditer(text)]


def _types_bytes(types: list) -> int:
    total = 0
    for dt, dims in types:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_instr(name: str, rhs: str) -> Instr:
    om = _OPCODE_RE.search(rhs)
    if om is None:
        return Instr(name, "", rhs, _type_list(rhs), [], "")
    opcode = om.group(1)
    result_types = _type_list(rhs[:om.start()])
    # operand section: balanced paren scan from the opcode's '('
    depth = 0
    start = om.end() - 1
    end = len(rhs)
    for i in range(start, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    opnd_text = rhs[start + 1:end]
    attrs = rhs[end + 1:]
    operands = [m.group(1) for m in _OPERAND_RE.finditer(opnd_text)]
    return Instr(name, opcode, rhs, result_types, operands, attrs)


def parse_module(hlo: str) -> dict:
    comps: dict[str, Computation] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if cur is None:
            m = _COMP_RE.match(stripped)
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if stripped.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(stripped)
        if not m:
            continue
        ins = _parse_instr(m.group(1), m.group(2))
        cur.instrs.append(ins)
        cur.by_name[ins.name] = ins
    return {"comps": comps, "entry": entry}


def _max_int_constant(comp: Computation) -> int:
    best = 1
    for ins in comp.instrs:
        for m in re.finditer(r"constant\((\d+)\)", ins.rhs):
            best = max(best, int(m.group(1)))
    return best


def _operand_bytes(comp: Computation, ins: Instr) -> int:
    total = 0
    for op in ins.operands:
        ref = comp.by_name.get(op)
        if ref is not None:
            total += _types_bytes(ref.result_types)
    return total


def _dot_flops(comp: Computation, ins: Instr) -> float:
    if not ins.result_types:
        return 0.0
    res_elems = 1
    for d in ins.result_types[0][1]:
        res_elems *= d
    lhs = comp.by_name.get(ins.operands[0]) if ins.operands else None
    if lhs is None or not lhs.result_types:
        return 0.0
    lhs_dims = lhs.result_types[0][1]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    contracted = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contracted *= lhs_dims[i]
    return 2.0 * res_elems * contracted


_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id",
               "iota", ""}


def _fusion_bytes(comp: Computation, ins: Instr, comps: dict) -> int:
    """Traffic of a fusion instruction, slice-aware.

    Inside a scan body, fusions commonly (a) dynamic-slice one layer's
    activations out of the full (L, ...) stacked array, or (b) dynamic-
    update-slice one layer's result into it. Charging the full stacked
    operand/result per iteration overstates bytes by ~L; the actual HBM
    traffic is the slice. So: an operand whose only uses inside the fused
    computation are dynamic-slice ops is charged at the slice size; a root
    dynamic-update-slice is charged at its update size (in-place aliasing).
    """
    mf = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
    fused = comps.get(mf.group(1)) if mf else None
    if fused is None:
        return _types_bytes(ins.result_types) + _operand_bytes(comp, ins)

    params = [i for i in fused.instrs if i.opcode == "parameter"]
    # order of parameters matches operand order; map param name → op bytes
    total = 0
    for idx, op_name in enumerate(ins.operands):
        ref = comp.by_name.get(op_name)
        full = _types_bytes(ref.result_types) if ref else 0
        if idx >= len(params) or full == 0:
            total += full
            continue
        pname = params[idx].name
        consumers = [i for i in fused.instrs if pname in i.operands]
        if consumers and all(c.opcode == "dynamic-slice" for c in consumers):
            total += sum(_types_bytes(c.result_types) for c in consumers)
        elif consumers and all(c.opcode == "dynamic-update-slice" and
                               c.operands and c.operands[0] == pname
                               for c in consumers):
            # in-place DUS target: charge the update size (read-modify-write)
            upd = 0
            for c in consumers:
                if len(c.operands) > 1:
                    u = fused.by_name.get(c.operands[1])
                    upd += _types_bytes(u.result_types) if u else 0
            total += upd
        else:
            total += full
    # result side: root DUS → update bytes, not the full aliased array
    root = fused.instrs[-1] if fused.instrs else None
    if root is not None and root.opcode == "dynamic-update-slice" and \
            len(root.operands) > 1:
        u = fused.by_name.get(root.operands[1])
        total += _types_bytes(u.result_types) if u else \
            _types_bytes(ins.result_types)
    else:
        total += _types_bytes(ins.result_types)
    return total


def analyze(hlo: str) -> dict:
    """Loop-aware {flops, bytes, collective_bytes, collectives{...}}."""
    mod = parse_module(hlo)
    comps = mod["comps"]
    entry = mod["entry"]
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                "collectives": {}, "collective_counts": {}}

    mult: dict[str, float] = {}
    fused: set[str] = set()
    stack = [(entry, 1.0, False)]
    visited = set()
    while stack:
        cname, m, in_fusion = stack.pop()
        if cname not in comps:
            continue
        key = (cname, round(m, 6), in_fusion)
        if key in visited:
            continue
        visited.add(key)
        mult[cname] = mult.get(cname, 0.0) + m
        if in_fusion:
            fused.add(cname)
        for ins in comps[cname].instrs:
            if ins.opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                trip = 1
                if mc and mc.group(1) in comps:
                    trip = _max_int_constant(comps[mc.group(1)])
                if mb:
                    stack.append((mb.group(1), m * trip, in_fusion))
            elif ins.opcode == "fusion":
                mf = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                if mf:
                    stack.append((mf.group(1), m, True))
            elif ins.opcode in ("call", "async-start"):
                mf = re.search(r"to_apply=%?([\w.\-]+)", ins.attrs)
                if mf:
                    stack.append((mf.group(1), m, in_fusion))
            elif ins.opcode == "conditional":
                mb = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
                if mb:
                    for b in mb.group(1).split(","):
                        stack.append((b.strip().lstrip("%"), m, in_fusion))
            # reduce/map/scatter/sort/custom-call bodies: scalar — skipped.

    flops = 0.0
    bytes_ = 0.0
    coll_bytes = 0.0
    coll_detail = {k: 0.0 for k in _COLLECTIVES}
    coll_counts = {k: 0 for k in _COLLECTIVES}
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        top_level = cname not in fused
        for ins in comp.instrs:
            if ins.opcode == "dot":
                flops += m * _dot_flops(comp, ins)
            base = ins.opcode.replace("-start", "")
            if base in _COLLECTIVES:
                b = max(_types_bytes(ins.result_types),
                        _operand_bytes(comp, ins))
                coll_bytes += m * b
                coll_detail[base] += m * b
                coll_counts[base] += 1
            if top_level and ins.opcode not in _NO_TRAFFIC and \
                    not ins.opcode.endswith("-done"):
                if ins.opcode == "fusion":
                    bytes_ += m * _fusion_bytes(comp, ins, comps)
                elif ins.opcode == "dynamic-slice":
                    bytes_ += m * 2 * _types_bytes(ins.result_types)
                elif ins.opcode == "dynamic-update-slice":
                    upd = (comp.by_name.get(ins.operands[1])
                           if len(ins.operands) > 1 else None)
                    ub = _types_bytes(upd.result_types) if upd else \
                        _types_bytes(ins.result_types)
                    bytes_ += m * 2 * ub
                else:
                    bytes_ += m * (_types_bytes(ins.result_types) +
                                   _operand_bytes(comp, ins))
    return {
        "flops": flops,
        "bytes": bytes_,
        "collective_bytes": coll_bytes,
        "collectives": coll_detail,
        "collective_counts": coll_counts,
    }
