"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --tiny \\
        --steps 100 --batch 8 --seq 128 --mesh 2x2 --ckpt-dir /tmp/run1

Builds the mesh (+ logical rules), shards the train state (params by
TP/DP rules, optimizer by ZeRO-1), restores from the newest valid
checkpoint if one exists, then runs the step loop with async checkpointing
and metrics logging. The same code path the platform executor uses, exposed
as a standalone CLI for single-job runs (and the template for a real
multi-host deployment: swap `make_mesh` for `jax.distributed`-initialized
devices).

Optimized-rules flags expose the EXPERIMENTS.md §Perf winners:
  --sp           sequence-parallel residuals (seq → model)
  --batch-tp     batch-TP attention (for TP-indivisible head counts)
"""

from __future__ import annotations

import argparse
import time


def parse_mesh(spec: str):
    parts = [int(x) for x in spec.split("x")]
    if len(parts) == 1:
        return None  # single device
    return tuple(parts)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true",
                    help="use the reduced smoke config of the family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--mesh", default="1", help="e.g. 2x2 = data x model")
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--batch-tp", action="store_true")
    ap.add_argument("--remat", default="full", choices=["none", "dots", "full"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.ckpt import checkpoint as ckpt
    from repro.ckpt.checkpoint import AsyncCheckpointer
    from repro.configs import get_config, get_tiny_config
    from repro.data.objectstore import DirBucket
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.launch.mesh import compat_make_mesh, make_env
    from repro.models import steps
    from repro.models.steps import TrainState
    from repro.optim import adamw
    from repro.parallel import logical_to_spec, param_shardings, use_env
    from repro.parallel.zero import opt_state_shardings

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    cfg = cfg.replace(remat=args.remat)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                                total_steps=args.steps)

    mesh_shape = parse_mesh(args.mesh)
    if mesh_shape is not None:
        if len(mesh_shape) != 2:
            raise SystemExit("--mesh must be DxM (e.g. 2x2)")
        need = mesh_shape[0] * mesh_shape[1]
        if jax.device_count() < need:
            raise SystemExit(
                f"mesh {args.mesh} needs {need} devices, have "
                f"{jax.device_count()} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={need} for CPU)")
        mesh = compat_make_mesh(mesh_shape, ("data", "model"))
        overrides = {}
        if args.sp:
            overrides["seq"] = "model"
        if args.batch_tp:
            overrides["batch_attn"] = ("data", "model")
        env = make_env(mesh, overrides=overrides)
    else:
        from repro.parallel import null_env
        env = null_env()
        mesh = None

    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch,
                                  seed=args.seed))
    bucket = DirBucket(args.ckpt_dir) if args.ckpt_dir else None
    acp = AsyncCheckpointer(bucket, "ckpt") if bucket else None

    with use_env(env):
        train_step = steps.make_train_step(cfg, opt_cfg)
        if mesh is not None:
            aparams = steps.abstract_params(cfg)
            axes = steps.param_axes(cfg)
            st_sh = TrainState(
                step=NamedSharding(mesh, P()),
                params=param_shardings(axes, aparams, env),
                opt=opt_state_shardings(axes, aparams, env))
            b_sh = {
                "tokens": NamedSharding(mesh, logical_to_spec(
                    ("batch", None), env, (args.batch, args.seq))),
                "labels": NamedSharding(mesh, logical_to_spec(
                    ("batch", None), env, (args.batch, args.seq))),
            }
            train_step = jax.jit(train_step, in_shardings=(st_sh, b_sh),
                                 out_shardings=(st_sh, None),
                                 donate_argnums=(0,))
        else:
            st_sh = None
            train_step = jax.jit(train_step, donate_argnums=(0,))

        # resume from the newest valid checkpoint (same contract the
        # platform's RealLearner uses)
        start = 0
        if bucket is not None:
            latest = ckpt.latest_step(bucket, "ckpt")
            if latest is not None:
                abstract = steps.abstract_train_state(cfg)
                state, _ = ckpt.restore(bucket, "ckpt", latest,
                                        like=abstract, shardings=st_sh)
                state = jax.tree.map(jax.numpy.asarray, state) \
                    if mesh is None else state
                start = latest
                print(f"resumed from checkpoint step {latest}")
        if start == 0:
            state = steps.init_train_state(cfg, jax.random.key(args.seed))
            if mesh is not None:
                state = jax.device_put(state, st_sh)

        from repro.utils import tree_count
        print(f"arch={cfg.name} params={tree_count(state.params)/1e6:.1f}M "
              f"mesh={args.mesh} devices={jax.device_count()}")

        t0 = time.perf_counter()
        tokens_done = 0
        for step in range(start, args.steps):
            batch = data.batch_at(step)
            if mesh is not None:
                batch = jax.device_put(batch, b_sh)
            state, metrics = train_step(state, batch)
            tokens_done += args.batch * args.seq
            if (step + 1) % args.log_every == 0:
                dt = time.perf_counter() - t0
                print(f"step {step+1:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"{tokens_done/dt:,.0f} tok/s")
            if acp is not None and (step + 1) % args.ckpt_every == 0:
                acp.save(step + 1, state,
                         {"loss": float(metrics["loss"])})
        if acp is not None:
            acp.save(args.steps, state, {"final": True})
            acp.wait()
            print(f"checkpoints: {ckpt.steps_available(bucket, 'ckpt')}")


if __name__ == "__main__":
    main()
