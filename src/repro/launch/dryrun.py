"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: every cell must
``.lower().compile()`` on the 16x16 single-pod mesh AND the 2x16x16
multi-pod mesh, and the compiled artifact yields the roofline terms
(cost_analysis + HLO collective parse) recorded in EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""

# The VERY FIRST lines, before any other import: jax locks the device count
# at first init, and the dry-run (and ONLY the dry-run) needs 512 host
# devices for the production meshes.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, all_cells, get_config
from repro.launch import hlo_cost
from repro.launch.mesh import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    compat_cost_analysis,
    make_env,
    make_production_mesh,
)
from repro.models import encdec, steps
from repro.models.steps import TrainState
from repro.nn import params as prm
from repro.nn.blocks import stack_state_axes
from repro.optim import adamw
from repro.parallel import logical_to_spec, param_shardings, use_env
from repro.parallel.zero import opt_state_shardings
from repro.utils.trees import tree_bytes

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3|f8e5m2|s64|s32|s16|s8|u64"
                      r"|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective byte totals parsed from post-SPMD HLO (per device).

    For each collective instruction, counts max(result bytes, operand bytes)
    — all-gather moves ~result bytes, reduce-scatter ~operand bytes.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", line)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b(all-reduce|all-gather|reduce-scatter|all-to-all"
                        r"|collective-permute)(?:-start)?\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        result_part = rhs[:opm.start()]
        operand_part = rhs[opm.start():]
        res_b = sum(_shape_bytes(t) for t in _TYPE_RE.finditer(result_part))
        opd_b = sum(_shape_bytes(t) for t in _TYPE_RE.finditer(operand_part))
        out[op] += max(res_b, opd_b)
        counts[op] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


# --------------------------------------------------------------------------
# cell construction: step fn + abstract inputs + shardings
# --------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, env, remat=None, overrides=None):
    """Returns (fn, example_kwargs, in_shardings, out_shardings)."""
    cfg = get_config(arch)
    if remat:
        cfg = cfg.replace(remat=remat)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    mesh = env.mesh

    ns = lambda spec: NamedSharding(mesh, spec)
    aparams = steps.abstract_params(cfg)
    paxes = steps.param_axes(cfg)
    pshard = param_shardings(paxes, aparams, env)

    def batch_shardings(batch):
        out = {}
        for k, v in batch.items():
            if k in ("tokens", "labels"):
                out[k] = ns(logical_to_spec(("batch", None), env, v.shape))
            elif k == "frames":
                out[k] = ns(logical_to_spec(("batch", None, None), env,
                                            v.shape))
        return out

    specs = steps.input_specs(cfg, shape)

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig(total_steps=10000)
        fn = steps.make_train_step(cfg, opt_cfg)
        astate = steps.abstract_train_state(cfg)
        oshard = opt_state_shardings(paxes, aparams, env)
        st_shard = TrainState(step=ns(P()), params=pshard, opt=oshard)
        in_sh = (st_shard, batch_shardings(specs["batch"]))
        out_sh = (st_shard, None)
        return fn, (astate, specs["batch"]), in_sh, out_sh, cfg

    if shape.kind == "prefill":
        fn = steps.make_prefill_step(cfg)
        in_sh = (pshard, batch_shardings(specs["batch"]))
        return fn, (aparams, specs["batch"]), in_sh, None, cfg

    # decode
    fn = steps.make_decode_step(cfg)
    if cfg.is_encoder_decoder:
        saxes = encdec.decode_state_axes(cfg)
    else:
        saxes = stack_state_axes(cfg)
    sshard = jax.tree.map(
        lambda axes, arr: ns(logical_to_spec(axes, env, arr.shape)),
        saxes, specs["states"],
        is_leaf=lambda l: isinstance(l, tuple) and
        all(isinstance(x, (str, type(None))) for x in l))
    tok_sh = ns(logical_to_spec(("batch", None), env, (shape.global_batch, 1)))
    in_sh = (pshard, tok_sh, sshard, ns(P()))
    out_sh = (None, sshard)
    return fn, (aparams, specs["token"], specs["states"],
                specs["cache_len"]), in_sh, out_sh, cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             remat=None, overrides=None, rule_overrides=None,
             bf16_interior: bool = False, keep_hlo: bool = False) -> dict:
    from repro.nn import policy

    mesh = make_production_mesh(multi_pod=multi_pod)
    env = make_env(mesh, overrides=rule_overrides)
    n_chips = mesh.size
    shape = SHAPES[shape_name]
    t0 = time.time()
    with use_env(env), policy.bf16_interior(bf16_interior):
        fn, args, in_sh, out_sh, cfg = build_cell(arch, shape_name, env,
                                                  remat=remat,
                                                  overrides=overrides)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compat_cost_analysis(compiled)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # Loop-aware accounting (XLA's cost_analysis counts while bodies once —
    # see hlo_cost.py). Raw XLA numbers kept alongside for reference.
    la = hlo_cost.analyze(hlo)

    flops_pd = float(la["flops"])
    bytes_pd = float(la["bytes"])
    coll_pd = float(la["collective_bytes"])

    # model "useful" flops: 6ND train / 2ND per generated token (global)
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.global_batch  # one token per sequence
        model_flops = 2 * n_active * tokens

    compute_s = flops_pd / PEAK_FLOPS_BF16
    memory_s = bytes_pd / HBM_BW
    collective_s = coll_pd / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "compile_s": round(t_compile, 1),
        "flops_per_device": flops_pd,
        "bytes_per_device": bytes_pd,
        "collective_bytes_per_device": coll_pd,
        "collectives": la["collectives"],
        "collective_counts": la["collective_counts"],
        "xla_flops_raw": float(cost.get("flops", 0.0)),
        "xla_bytes_raw": float(cost.get("bytes accessed", 0.0)),
        "param_bytes_global": tree_bytes(steps.abstract_params(cfg)),
        "n_params": cfg.param_count(),
        "n_active_params": n_active,
        "model_flops_global": model_flops,
        "useful_flops_ratio": model_flops / max(flops_pd * n_chips, 1),
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck.replace("_s", ""),
        "arg_bytes": mem.argument_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
    }
    if keep_hlo:
        result["hlo"] = hlo
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--bf16-interior", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
            if args.tag:
                tag += f"__{args.tag}"
            try:
                res = run_cell(arch, shape, multi_pod=mp, remat=args.remat,
                               bf16_interior=args.bf16_interior)
                with open(f"{args.out}/{tag}.json", "w") as f:
                    json.dump(res, f, indent=1)
                print(f"OK   {tag:60s} compile={res['compile_s']:6.1f}s "
                      f"bottleneck={res['bottleneck']:10s} "
                      f"compute={res['compute_s']*1e3:9.2f}ms "
                      f"mem={res['memory_s']*1e3:9.2f}ms "
                      f"coll={res['collective_s']*1e3:9.2f}ms", flush=True)
            except Exception as e:
                failures.append(tag)
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc(limit=4)
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("\nall cells passed")


if __name__ == "__main__":
    main()
