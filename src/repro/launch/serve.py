"""Serving engine + launcher: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --tiny \\
        --requests 16 --prompt-len 64 --gen 32

:class:`ServeEngine` is the importable core — one constructed engine is a
serving session (config resolved, sharding env built, params initialized,
prefill/decode steps jitted once) that :meth:`generate`\\ s batches on
demand. The workloads serving tier drives it in-process: attach one to a
``Service`` resource via ``WorkloadPlane.attach_engine`` and each
``…/invoke`` request lands in :meth:`infer`. ``main()`` is a thin argv
wrapper over the same object.

Drives the same prefill/decode step functions the dry-run lowers at
production shapes: a batch of synthetic prompts is prefilled (KV caches /
recurrent states built), then tokens are generated step by step. Reports
prefill and decode throughput. With ``--mesh``, runs sharded (incl. the
§Perf context-parallel cache via ``--ctx-parallel``).
"""

from __future__ import annotations

import argparse
import time
from typing import Optional


class ServeEngine:
    """One in-process serving session for an arch.

    Construction is the expensive part (params + jit); ``generate`` is
    the per-batch hot path, handling both LM (prefill → KV-cache decode)
    and encoder-decoder (encode → decode-state) branches.
    """

    def __init__(self, arch: str, tiny: bool = True,
                 mesh: Optional[str] = None, ctx_parallel: bool = False,
                 seed: int = 0):
        import jax

        from repro.configs import get_config, get_tiny_config
        from repro.launch.mesh import compat_make_mesh, make_env
        from repro.launch.train import parse_mesh
        from repro.models import steps
        from repro.parallel import null_env, use_env

        self.arch = arch
        self.cfg = get_tiny_config(arch) if tiny else get_config(arch)
        mesh_shape = parse_mesh(mesh) if mesh is not None else None
        if mesh_shape is not None:
            m = compat_make_mesh(mesh_shape, ("data", "model"))
            overrides = {"kv_seq": "model"} if ctx_parallel else {}
            self.env = make_env(m, overrides=overrides)
        else:
            self.env = null_env()
        self._use_env = use_env
        self._key = jax.random.key(seed)
        with use_env(self.env):
            self.params = steps.init_params(self.cfg, self._key)
            if not self.cfg.is_encoder_decoder:
                self._prefill = jax.jit(steps.make_prefill_step(self.cfg))
            self._decode = jax.jit(steps.make_decode_step(self.cfg))

    # -- the per-batch hot path -------------------------------------------
    def generate(self, prompts, gen: int) -> dict:
        """Prefill ``prompts`` (B, S) and decode ``gen`` tokens. Returns
        ``{"tokens": (B, gen) array, "prefill_s": float, "decode_s":
        float}`` — throughput is the caller's division to do."""
        import jax
        import jax.numpy as jnp

        from repro.models import encdec, steps

        B, S = prompts.shape
        s_max = S + gen
        with self._use_env(self.env):
            if self.cfg.is_encoder_decoder:
                frames = jax.random.normal(
                    self._key, (B, self.cfg.enc_seq, self.cfg.d_model),
                    jnp.bfloat16)
                memory = jax.jit(
                    lambda p, f: encdec.encode(p, f, self.cfg))(
                        self.params, frames)
                states = encdec.init_decode_state(
                    self.params, memory, self.cfg, B, s_max)
                tok = jnp.zeros((B, 1), jnp.int32)
                cache_len, t_pf = 0, 0.0
            else:
                t0 = time.perf_counter()
                tok, pf_states, _ = self._prefill(
                    self.params, {"tokens": prompts})
                jax.block_until_ready(tok)
                t_pf = time.perf_counter() - t0
                # move prefill KV into the fixed-capacity decode cache
                states = steps.decode_state(self.cfg, B, s_max)
                states = _install_prefill(states, pf_states, self.cfg, S)
                cache_len = S

            generated = [tok]
            t0 = time.perf_counter()
            for i in range(gen - 1):
                tok, states = self._decode(self.params, tok, states,
                                           jnp.int32(cache_len + i))
                generated.append(tok)
            jax.block_until_ready(tok)
            t_dec = time.perf_counter() - t0
        return {"tokens": jnp.concatenate(generated, axis=1),
                "prefill_s": t_pf, "decode_s": t_dec}

    # -- serving-tier adapter ---------------------------------------------
    def infer(self, payload=None) -> dict:
        """One inference request, as the workloads serving tier calls it
        (``POST /v2/workloads/{name}/invoke`` → attached engine). The
        payload is a dict of knobs: ``prompt_len`` (default 16),
        ``gen`` (default 8), ``batch`` (default 1); prompts are
        synthetic, like the launcher's."""
        import jax

        p = payload or {}
        B = int(p.get("batch", 1))
        S = int(p.get("prompt_len", 16))
        gen = max(2, int(p.get("gen", 8)))
        prompts = jax.random.randint(self._key, (B, S), 0,
                                     self.cfg.vocab_size)
        out = self.generate(prompts, gen)
        toks = out["tokens"]
        return {"arch": self.arch, "tokens": toks[0].tolist(),
                "batch": B, "prompt_len": S,
                "decode_ms_per_token":
                    out["decode_s"] / max(gen - 1, 1) * 1e3}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=8, help="batch size")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="1")
    ap.add_argument("--ctx-parallel", action="store_true",
                    help="shard the KV cache over the model axis (§Perf it.9)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    engine = ServeEngine(args.arch, tiny=args.tiny, mesh=args.mesh,
                         ctx_parallel=args.ctx_parallel, seed=args.seed)
    B, S = args.requests, args.prompt_len
    prompts = jax.random.randint(engine._key, (B, S), 0,
                                 engine.cfg.vocab_size)
    out = engine.generate(prompts, args.gen)
    toks, t_pf, t_dec = out["tokens"], out["prefill_s"], out["decode_s"]

    print(f"arch={engine.cfg.name} requests={B} prompt={S} "
          f"generated={toks.shape[1]}")
    if t_pf:
        print(f"prefill: {B * S / t_pf:,.0f} tok/s ({t_pf*1e3:.1f} ms)")
    print(f"decode:  {B * (args.gen - 1) / max(t_dec, 1e-9):,.0f} tok/s "
          f"({t_dec / max(args.gen - 1, 1) * 1e3:.2f} ms/token)")
    print(f"sample continuation (req 0): {toks[0, :12].tolist()}")


def _install_prefill(states, pf_states, cfg, prompt_len):
    """Write prefill-produced K/V into the decode cache at positions [0, S)."""
    import jax
    import jax.numpy as jnp
    from repro.nn.attention import KVCache

    def merge(slot, new):
        if isinstance(slot, jax.Array) and slot.ndim >= 3 and \
                new is not None and isinstance(new, jax.Array):
            return jax.lax.dynamic_update_slice_in_dim(
                slot, new.astype(slot.dtype), 0,
                axis=slot.ndim - 2)
        return slot

    # pf_states mirrors the decode-state structure (KVCache per attn layer,
    # recurrent state dicts pass through unchanged)
    def combine(s, p):
        if isinstance(s, KVCache) and isinstance(p, KVCache):
            return KVCache(k=merge(s.k, p.k), v=merge(s.v, p.v))
        return p if p is not None else s

    if isinstance(states, list):
        return [combine(s, p) for s, p in zip(states, pf_states)]
    # stacked scan layout: pytrees align leaf-wise
    return jax.tree.map(
        lambda s, p: merge(s, p) if hasattr(s, "ndim") else s,
        states, pf_states,
        is_leaf=lambda l: hasattr(l, "ndim"))


if __name__ == "__main__":
    main()
