"""Serving launcher: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --tiny \\
        --requests 16 --prompt-len 64 --gen 32

Drives the same prefill/decode step functions the dry-run lowers at
production shapes: a batch of synthetic prompts is prefilled (KV caches /
recurrent states built), then tokens are generated step by step. Reports
prefill and decode throughput. With --mesh, runs sharded (incl. the
§Perf context-parallel cache via --ctx-parallel).
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=8, help="batch size")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="1")
    ap.add_argument("--ctx-parallel", action="store_true",
                    help="shard the KV cache over the model axis (§Perf it.9)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_tiny_config
    from repro.launch.mesh import compat_make_mesh, make_env
    from repro.launch.train import parse_mesh
    from repro.models import encdec, steps
    from repro.parallel import null_env, use_env

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    mesh_shape = parse_mesh(args.mesh)
    if mesh_shape is not None:
        mesh = compat_make_mesh(mesh_shape, ("data", "model"))
        overrides = {"kv_seq": "model"} if args.ctx_parallel else {}
        env = make_env(mesh, overrides=overrides)
    else:
        env = null_env()

    key = jax.random.key(args.seed)
    B, S = args.requests, args.prompt_len
    s_max = S + args.gen

    with use_env(env):
        params = steps.init_params(cfg, key)
        prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

        if cfg.is_encoder_decoder:
            frames = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model),
                                       jnp.bfloat16)
            memory = jax.jit(lambda p, f: encdec.encode(p, f, cfg))(
                params, frames)
            states = encdec.init_decode_state(params, memory, cfg, B, s_max)
            tok = jnp.zeros((B, 1), jnp.int32)
            cache_len = 0
            t_pf = 0.0
        else:
            prefill = jax.jit(steps.make_prefill_step(cfg))
            t0 = time.perf_counter()
            tok, pf_states, _ = prefill(params, {"tokens": prompts})
            jax.block_until_ready(tok)
            t_pf = time.perf_counter() - t0
            # move prefill KV into the fixed-capacity decode cache
            states = steps.decode_state(cfg, B, s_max)
            states = _install_prefill(states, pf_states, cfg, S)
            cache_len = S

        decode = jax.jit(steps.make_decode_step(cfg))
        generated = [tok]
        t0 = time.perf_counter()
        for i in range(args.gen - 1):
            tok, states = decode(params, tok, states, jnp.int32(cache_len + i))
            generated.append(tok)
        jax.block_until_ready(tok)
        t_dec = time.perf_counter() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name} requests={B} prompt={S} generated={out.shape[1]}")
    if t_pf:
        print(f"prefill: {B * S / t_pf:,.0f} tok/s ({t_pf*1e3:.1f} ms)")
    print(f"decode:  {B * (args.gen - 1) / max(t_dec, 1e-9):,.0f} tok/s "
          f"({t_dec / max(args.gen - 1, 1) * 1e3:.2f} ms/token)")
    print(f"sample continuation (req 0): {out[0, :12].tolist()}")


def _install_prefill(states, pf_states, cfg, prompt_len):
    """Write prefill-produced K/V into the decode cache at positions [0, S)."""
    import jax
    import jax.numpy as jnp
    from repro.nn.attention import KVCache

    def merge(slot, new):
        if isinstance(slot, jax.Array) and slot.ndim >= 3 and \
                new is not None and isinstance(new, jax.Array):
            return jax.lax.dynamic_update_slice_in_dim(
                slot, new.astype(slot.dtype), 0,
                axis=slot.ndim - 2)
        return slot

    # pf_states mirrors the decode-state structure (KVCache per attn layer,
    # recurrent state dicts pass through unchanged)
    def combine(s, p):
        if isinstance(s, KVCache) and isinstance(p, KVCache):
            return KVCache(k=merge(s.k, p.k), v=merge(s.v, p.v))
        return p if p is not None else s

    if isinstance(states, list):
        return [combine(s, p) for s, p in zip(states, pf_states)]
    # stacked scan layout: pytrees align leaf-wise
    return jax.tree.map(
        lambda s, p: merge(s, p) if hasattr(s, "ndim") else s,
        states, pf_states,
        is_leaf=lambda l: hasattr(l, "ndim"))


if __name__ == "__main__":
    main()
