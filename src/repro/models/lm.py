"""Decoder-only language model assembly (all non-enc-dec archs) + losses."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn import params as prm
from repro.nn.blocks import def_stack, init_stack_state, stack_apply
from repro.nn.layers import def_norm, embed_lookup, norm, unembed
from repro.parallel import shard


def def_lm(cfg: ModelConfig):
    d = {
        "embed": prm.embedding(cfg.vocab_size, cfg.d_model),
        "blocks": def_stack(cfg),
        "final_norm": def_norm(cfg.d_model, cfg.rms_norm),
    }
    if not cfg.tie_embeddings:
        d["unembed"] = prm.ParamDef((cfg.vocab_size, cfg.d_model),
                                    ("vocab", "embed"), init="normal", scale=0.02)
    return d


def lm_apply(p, tokens, cfg: ModelConfig, *, mode="train", states=None,
             cache_len=None, positions=None):
    """tokens: (B, S) int32 → (logits (B, S, V) fp32, new_states, aux)."""
    if positions is None:
        if mode == "decode":
            positions = jnp.broadcast_to(cache_len, tokens.shape).astype(jnp.int32)
        else:
            positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    x = embed_lookup(p["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x = shard(x, "batch", "seq", "embed")
    x, new_states, aux = stack_apply(p["blocks"], x, cfg, positions=positions,
                                     mode=mode, states=states,
                                     cache_len=cache_len)
    x = norm(p["final_norm"], x, cfg.rms_norm)
    table = p["embed"] if cfg.tie_embeddings else p["unembed"]
    logits = unembed(table, x)
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, new_states, aux


def init_lm_state(cfg: ModelConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    return init_stack_state(cfg, batch, s_max, dtype)


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

def cross_entropy(logits, labels, z_loss: float = 1e-4):
    """Mean token cross-entropy in fp32 with optional z-loss regularizer.

    logits: (B, S, V) fp32; labels: (B, S) int32 (-1 = masked out).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom
