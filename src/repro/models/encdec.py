"""Encoder-decoder model (whisper-tiny backbone).

The audio frontend (log-mel + conv downsampling) is a STUB per the
assignment: ``input_specs()`` feeds precomputed frame embeddings
(B, enc_seq, d_model). Positions are sinusoidal (whisper-style absolute),
which keeps any decode length shape-valid (noted in DESIGN.md §7).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn import params as prm
from repro.nn.attention import (
    KVCache,
    cross_attention,
    def_cross_attention,
    def_gqa,
    gqa_attention,
)
from repro.nn.layers import (
    def_norm,
    embed_lookup,
    norm,
    sinusoidal_positions,
    unembed,
)
from repro.nn.mlp import def_mlp, mlp
from repro.parallel import shard


def _def_enc_block(cfg: ModelConfig):
    return {
        "norm1": def_norm(cfg.d_model, cfg.rms_norm),
        "attn": def_gqa(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
        "norm2": def_norm(cfg.d_model, cfg.rms_norm),
        "mlp": def_mlp(cfg.d_model, cfg.d_ff, cfg.act),
    }


def def_encdec(cfg: ModelConfig):
    dec_block = {
        "norm1": def_norm(cfg.d_model, cfg.rms_norm),
        "attn": def_gqa(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
        "norm_cross": def_norm(cfg.d_model, cfg.rms_norm),
        "cross": def_cross_attention(cfg.d_model, cfg.n_heads, cfg.hd),
        "norm2": def_norm(cfg.d_model, cfg.rms_norm),
        "mlp": def_mlp(cfg.d_model, cfg.d_ff, cfg.act),
    }
    return {
        "embed": prm.embedding(cfg.vocab_size, cfg.d_model),
        "enc": [_def_enc_block(cfg) for _ in range(cfg.n_enc_layers)],
        "enc_norm": def_norm(cfg.d_model, cfg.rms_norm),
        "dec": [dict(dec_block) for _ in range(cfg.n_layers)],
        "dec_norm": def_norm(cfg.d_model, cfg.rms_norm),
    }


def encode(p, frames, cfg: ModelConfig):
    """frames: (B, enc_seq, d) stub frontend output → encoder memory."""
    s = frames.shape[1]
    x = frames + sinusoidal_positions(s, cfg.d_model).astype(frames.dtype)
    x = shard(x, "batch", "enc_seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(s), frames.shape[:2])
    for blk in p["enc"]:
        h = norm(blk["norm1"], x, cfg.rms_norm)
        o, _ = gqa_attention(
            blk["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, positions=positions, use_rope=False,
            causal=False, chunk=cfg.attn_chunk, mode="train")
        x = x + o
        x = x + mlp(blk["mlp"], norm(blk["norm2"], x, cfg.rms_norm), cfg.act)
        x = shard(x, "batch", "enc_seq", "embed")
    return norm(p["enc_norm"], x, cfg.rms_norm)


def decode_train(p, tokens, memory, cfg: ModelConfig):
    """Teacher-forced decoder pass. tokens: (B, S); memory: (B, S_enc, d)."""
    b, s = tokens.shape
    x = embed_lookup(p["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    for blk in p["dec"]:
        h = norm(blk["norm1"], x, cfg.rms_norm)
        o, _ = gqa_attention(
            blk["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, positions=positions, use_rope=False,
            causal=True, chunk=cfg.attn_chunk, mode="train")
        x = x + o
        h = norm(blk["norm_cross"], x, cfg.rms_norm)
        o, _ = cross_attention(blk["cross"], h, memory=memory)
        x = x + o
        x = x + mlp(blk["mlp"], norm(blk["norm2"], x, cfg.rms_norm), cfg.act)
        x = shard(x, "batch", "seq", "embed")
    x = norm(p["dec_norm"], x, cfg.rms_norm)
    return unembed(p["embed"], x)


def init_decode_state(p, memory, cfg: ModelConfig, batch: int, s_max: int,
                      dtype=jnp.bfloat16):
    """Self-attn KV caches + precomputed cross-attn K/V per decoder layer."""
    states = []
    for blk in p["dec"]:
        k = jnp.einsum("bsd,dhk->bhsk", memory, blk["cross"]["wk"],
                       preferred_element_type=jnp.float32).astype(dtype)
        v = jnp.einsum("bsd,dhk->bhsk", memory, blk["cross"]["wv"],
                       preferred_element_type=jnp.float32).astype(dtype)
        states.append({
            "self": KVCache(
                jnp.zeros((batch, cfg.n_kv_heads, s_max, cfg.hd), dtype),
                jnp.zeros((batch, cfg.n_kv_heads, s_max, cfg.hd), dtype)),
            "cross_kv": (k, v),
        })
    return states


def abstract_decode_state(cfg: ModelConfig, batch: int, s_max: int,
                          dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the decode state (dry-run, no allocation)."""
    def sd(shape):
        return jax.ShapeDtypeStruct(shape, dtype)
    return [
        {
            "self": KVCache(sd((batch, cfg.n_kv_heads, s_max, cfg.hd)),
                            sd((batch, cfg.n_kv_heads, s_max, cfg.hd))),
            "cross_kv": (sd((batch, cfg.n_heads, cfg.enc_seq, cfg.hd)),
                         sd((batch, cfg.n_heads, cfg.enc_seq, cfg.hd))),
        }
        for _ in range(cfg.n_layers)
    ]


def decode_state_axes(cfg: ModelConfig):
    """Logical axes of the decode state (dry-run sharding)."""
    kv = ("batch", "kv_heads", "kv_seq", "head_dim")
    cross = ("batch", "heads", "enc_seq", "head_dim")
    return [
        {"self": KVCache(k=kv, v=kv), "cross_kv": (cross, cross)}
        for _ in range(cfg.n_layers)
    ]


def decode_step(p, token, states, cache_len, cfg: ModelConfig):
    """One decode step. token: (B, 1); returns (logits (B,1,V), new states)."""
    b = token.shape[0]
    x = embed_lookup(p["embed"], token).astype(jnp.dtype(cfg.dtype))
    # absolute sinusoidal position at cache_len (traced) — computed directly
    pos = jnp.broadcast_to(cache_len, (b, 1)).astype(jnp.int32)
    half_angles = pos[..., None].astype(jnp.float32) / jnp.power(
        10000.0, jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32) / cfg.d_model)
    pe = jnp.zeros((b, 1, cfg.d_model), jnp.float32)
    pe = pe.at[..., 0::2].set(jnp.sin(half_angles))
    pe = pe.at[..., 1::2].set(jnp.cos(half_angles))
    x = x + pe.astype(x.dtype)
    new_states = []
    for blk, st in zip(p["dec"], states):
        h = norm(blk["norm1"], x, cfg.rms_norm)
        o, new_cache = gqa_attention(
            blk["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, positions=pos, use_rope=False, causal=True,
            cache=st["self"], cache_len=cache_len, mode="decode")
        x = x + o
        h = norm(blk["norm_cross"], x, cfg.rms_norm)
        o, _ = cross_attention(blk["cross"], h, mem_kv=st["cross_kv"])
        x = x + o
        x = x + mlp(blk["mlp"], norm(blk["norm2"], x, cfg.rms_norm), cfg.act)
        new_states.append({"self": new_cache, "cross_kv": st["cross_kv"]})
    x = norm(p["dec_norm"], x, cfg.rms_norm)
    return unembed(p["embed"], x), new_states
