"""Step factories: the jit-able train / prefill / decode functions that the
executor, dry-run, benchmarks and examples all share.

``abstract_*`` helpers produce ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) so the multi-pod dry-run can lower 235B-param
models on a CPU container.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, lm
from repro.nn import params as prm
from repro.nn.blocks import init_stack_state
from repro.optim import adamw


class TrainState(NamedTuple):
    step: jax.Array  # () int32
    params: dict
    opt: adamw.OptState


AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def model_defs(cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return encdec.def_encdec(cfg)
    return lm.def_lm(cfg)


def init_params(cfg: ModelConfig, key):
    return prm.materialize(key, model_defs(cfg), jnp.dtype(cfg.dtype))


def abstract_params(cfg: ModelConfig):
    return prm.abstract(model_defs(cfg), jnp.dtype(cfg.dtype))


def param_axes(cfg: ModelConfig):
    return prm.axes_of(model_defs(cfg))


def init_train_state(cfg: ModelConfig, key) -> TrainState:
    params = init_params(cfg, key)
    return TrainState(jnp.zeros((), jnp.int32), params, adamw.init(params))


def abstract_train_state(cfg: ModelConfig) -> TrainState:
    params = abstract_params(cfg)
    return TrainState(jax.ShapeDtypeStruct((), jnp.int32), params,
                      adamw.abstract_state(params))


# --------------------------------------------------------------------------
# Train step
# --------------------------------------------------------------------------

def loss_fn(params, batch, cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        memory = encdec.encode(params, batch["frames"], cfg)
        logits = encdec.decode_train(params, batch["tokens"], memory, cfg)
        aux = jnp.zeros((), jnp.float32)
    else:
        logits, _, aux = lm.lm_apply(params, batch["tokens"], cfg, mode="train")
    ce = lm.cross_entropy(logits, batch["labels"])
    return ce + AUX_WEIGHT * aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig):
    def train_step(state: TrainState, batch):
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch, cfg)
        new_params, new_opt, om = adamw.update(
            opt_cfg, grads, state.opt, state.step, jnp.dtype(cfg.dtype))
        metrics = {"loss": loss, **parts, **om, "step": state.step}
        return TrainState(state.step + 1, new_params, new_opt), metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, parts = loss_fn(params, batch, cfg)
        return {"loss": loss, **parts}

    return eval_step


# --------------------------------------------------------------------------
# Serve steps
# --------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig):
    """Returns fn(params, batch) → (next_token (B,1), states, last_logits)."""

    if cfg.is_encoder_decoder:
        def prefill(params, batch):
            memory = encdec.encode(params, batch["frames"], cfg)
            logits = encdec.decode_train(params, batch["tokens"], memory, cfg)
            # Serving would keep decoding against `memory`; return it as state.
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            return nxt, memory, logits[:, -1]
        return prefill

    def prefill(params, batch):
        logits, states, _ = lm.lm_apply(params, batch["tokens"], cfg,
                                        mode="prefill")
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, states, logits[:, -1]

    return prefill


def make_decode_step(cfg: ModelConfig):
    """Returns fn(params, token (B,1), states, cache_len ()) →
    (next_token (B,1), new_states)."""

    if cfg.is_encoder_decoder:
        def decode(params, token, states, cache_len):
            logits, new_states = encdec.decode_step(params, token, states,
                                                    cache_len, cfg)
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            return nxt, new_states
        return decode

    def decode(params, token, states, cache_len):
        logits, new_states, _ = lm.lm_apply(params, token, cfg, mode="decode",
                                            states=states, cache_len=cache_len)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, new_states

    return decode


def decode_state(cfg: ModelConfig, batch: int, s_max: int):
    """Concrete decode-time state (tests / examples)."""
    dtype = jnp.dtype(cfg.dtype)
    if cfg.is_encoder_decoder:
        raise ValueError("enc-dec decode state needs params+memory; "
                         "use encdec.init_decode_state")
    return init_stack_state(cfg, batch, s_max, dtype)  # full alloc


def abstract_decode_state(cfg: ModelConfig, batch: int, s_max: int):
    """ShapeDtypeStruct decode state (dry-run)."""
    dtype = jnp.dtype(cfg.dtype)
    if cfg.is_encoder_decoder:
        return encdec.abstract_decode_state(cfg, batch, s_max, dtype)
    # compact: local-attention caches sized at the window (dry-run honesty
    # for long_500k — a full 500k cache would misstate the arch's memory)
    return jax.eval_shape(
        lambda: init_stack_state(cfg, batch, s_max, dtype, compact=True))


# --------------------------------------------------------------------------
# Input specs per shape cell (dry-run and smoke tests)
# --------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs for every model input of this (arch, shape) cell.

    train/prefill: token batch (+ frames for enc-dec).
    decode: single-token batch + full KV/recurrent state + cache_len.
    """
    b, s = shape.global_batch, shape.seq_len
    tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)
    if shape.kind == "train":
        batch = {"tokens": tok(b, s), "labels": tok(b, s)}
        if cfg.is_encoder_decoder:
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": tok(b, s)}
        if cfg.is_encoder_decoder:
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        return {"batch": batch}
    # decode: one new token against a cache of size seq_len
    return {
        "token": tok(b, 1),
        "states": abstract_decode_state(cfg, b, s),
        "cache_len": jax.ShapeDtypeStruct((), jnp.int32),
    }
