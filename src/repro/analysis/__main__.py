"""CLI: ``python -m repro.analysis`` (also ``make lint`` and a CI step).

Exit status 0 only when every finding is covered by ``baseline.json``,
every baseline entry carries a reason, and no entry is stale (matching
nothing — a fixed exception must be deleted, not carried forward).
``--write-baseline`` seeds the file from current findings with TODO
reasons for a human to justify.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from repro.analysis import run_analysis
from repro.analysis.base import BASELINE_PATH, Baseline


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Run the invariant analyzer suite over src/repro.",
    )
    parser.add_argument("--root", default=None,
                        help="repo root to analyze (default: this repo)")
    parser.add_argument("--baseline", default=None,
                        help="baseline JSON path (default: the committed one)")
    parser.add_argument("--write-baseline", action="store_true",
                        help="write current findings as the new baseline "
                             "(reasons left as TODO for human review)")
    parser.add_argument("-q", "--quiet", action="store_true",
                        help="suppress per-finding output, print summary only")
    args = parser.parse_args(argv)

    t0 = time.perf_counter()
    result = run_analysis(args.root)
    elapsed = time.perf_counter() - t0

    baseline_path = Path(args.baseline) if args.baseline else BASELINE_PATH
    if args.write_baseline:
        entries = [
            {"key": f.key, "reason": "TODO: justify this exception",
             "note": f.render()}
            for f in result.findings
        ]
        baseline_path.write_text(json.dumps(entries, indent=2) + "\n")
        print(f"wrote {len(entries)} baseline entries to {baseline_path}")
        return 0

    baseline = Baseline.load(baseline_path)
    new, old = result.split(baseline)
    unjustified = baseline.unjustified()
    stale = baseline.stale()

    if not args.quiet:
        for f in new:
            print(f.render())
        for entry in unjustified:
            print(f"baseline entry without a reason: {entry.get('key')}")
        for entry in stale:
            print(f"stale baseline entry (matches nothing): {entry.get('key')}")

    status = "FAIL" if (new or unjustified or stale) else "ok"
    print(
        f"repro.analysis: {status} — {result.files} files, "
        f"{len(new)} new finding(s), {len(old)} baselined, "
        f"{len(stale)} stale, {elapsed:.2f}s"
    )
    return 1 if (new or unjustified or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
