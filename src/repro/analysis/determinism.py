"""DET-AMBIENT: no ambient clock or unseeded RNG in platform code.

The simulated platform is deterministic by construction: every tick,
breaker, fault plan, and chaos schedule takes an explicit clock hook or
a seeded RNG. One stray ``time.time()`` or ``random.random()`` makes a
failing chaos campaign unreproducible — the worst possible property for
a platform whose whole test strategy is replaying seeds.

Checked subtree: ``core``, ``api``, ``obs``, ``workloads`` (analysis
tooling and the storage/cluster simulation layers below ``core`` keep
their own rules). Banned on sight:

* ambient clock reads: ``time.time``/``monotonic``/``perf_counter``
  (and ``_ns`` variants), ``time.sleep``, ``datetime.now``/``utcnow``
* module-level RNG: any ``random.*`` call except a *seeded*
  ``random.Random(seed)`` construction
* numpy global RNG: any ``np.random.*`` except a seeded
  ``np.random.default_rng(seed)`` / ``np.random.SeedSequence(...)``

``DET_ALLOWLIST`` exempts whole files that *are* the clock/timing plane,
each with a reason (rendered in docs/architecture.md). Everything else
must thread ``now``/``clock``/seeds explicitly.
"""

from __future__ import annotations

import ast

from repro.analysis.base import Finding, dotted_name, scope_of

#: Only these subpackages of src/repro are in scope.
_SCOPE_PREFIXES = (
    "src/repro/core/",
    "src/repro/api/",
    "src/repro/obs/",
    "src/repro/workloads/",
)

#: Whole-file exemptions: path -> reason (docs/architecture.md lists
#: these; a file that stops existing should be pruned here).
DET_ALLOWLIST = {
    "src/repro/core/faults.py":
        "IS the clock/deadline plane: deadline_scope and ShardBreaker "
        "own the monotonic-clock hooks everything else injects",
    "src/repro/api/http.py":
        "wall-clock edge: SSE heartbeat pacing and per-request latency "
        "timing are real-time observability, not simulated state",
    "src/repro/api/gateway.py":
        "wall-clock edge: long-poll parking (time.sleep) happens outside "
        "shard locks and never influences simulated state",
    "src/repro/api/client.py":
        "client-side retry backoff sleeps; RetryPolicy jitter is a "
        "seeded random.Random(seed) and stays reproducible",
    "src/repro/api/cli.py":
        "operator-facing CLI: startup polling and timeouts are real "
        "time by definition",
}

_CLOCK_CALLS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.sleep",
    "datetime.now", "datetime.utcnow",
    "datetime.datetime.now", "datetime.datetime.utcnow",
}

#: Seeded constructions allowed even under the RNG prefixes, provided
#: they carry at least one argument (the seed).
_SEEDED_CTORS = {
    "random.Random",
    "np.random.default_rng", "numpy.random.default_rng",
    "np.random.SeedSequence", "numpy.random.SeedSequence",
    "np.random.Generator", "numpy.random.Generator",
}

_RNG_PREFIXES = ("random.", "np.random.", "numpy.random.")


def _in_scope(path: str) -> bool:
    if path.startswith("src/repro/"):
        return path.startswith(_SCOPE_PREFIXES)
    return True  # fixture trees: analyze everything handed to us


def _violation(call: ast.Call):
    """Return (label, why) if this call is ambient, else None."""
    dn = dotted_name(call.func)
    if not dn:
        return None
    if dn in _CLOCK_CALLS:
        return dn, "ambient clock — inject a clock hook or `now` param"
    if dn in _SEEDED_CTORS:
        if call.args or call.keywords:
            return None
        return dn, "unseeded RNG construction — pass an explicit seed"
    if dn.startswith(_RNG_PREFIXES):
        return dn, "module-level RNG — construct a seeded generator"
    return None


def check_determinism(sources) -> list:
    findings = []
    for src in sources:
        if not _in_scope(src.path):
            continue
        if src.path in DET_ALLOWLIST:
            continue
        for call in ast.walk(src.tree):
            if not isinstance(call, ast.Call):
                continue
            hit = _violation(call)
            if hit is None:
                continue
            label, why = hit
            findings.append(Finding(
                check="DET-AMBIENT",
                path=src.path,
                line=call.lineno,
                scope=scope_of(call),
                message=f"`{label}`: {why}",
                detail=label,
            ))
    return findings
