"""Runtime lock-order witness: acquisition-graph cycle detection.

The static LOCK-ORDER check sees lexical nesting; it cannot see a
cross-thread ABBA hazard assembled at runtime (thread 1 holds shard:0
and waits on shard:1 while thread 2 does the reverse — each acquisition
is lexically innocent). The witness closes that gap dynamically:

* :meth:`LockOrderWitness.install` monkeypatches
  ``RWLock.read_locked``/``write_locked`` (every shard lock in the
  platform, including each one ``AllShardsLock`` takes through its
  ``ExitStack``) to record, per thread, an edge ``held -> attempting``
  at acquisition-**attempt** time — before blocking, so an acquisition
  that later fails with ``DeadlineExceeded`` still contributes its
  hazard edge — and to push onto the thread's held-stack only after
  the acquisition *succeeds* (a failed wait must not corrupt the
  stack).
* After a workload runs (a test module, a chaos benchmark), the
  accumulated directed graph over lock names (``shard:0``, ``shard:1``,
  ...) must be **acyclic**: a cycle is a witnessed deadlock hazard even
  if the schedule that would actually deadlock never fired.

tests/conftest.py installs the module-level :data:`witness` for the
whole pytest run and asserts acyclicity after the concurrency-heavy
modules; ``benchmarks/faults.py`` does the same around its chaos
campaign. Unit tests exercise private instances so a seeded cycle
never leaks into the global graph.
"""

from __future__ import annotations

import contextlib
import threading


class LockOrderWitness:
    """Records the cross-thread lock-acquisition graph; see module doc."""

    def __init__(self):
        self._tls = threading.local()
        self._mu = threading.Lock()
        self.edges = {}          # name -> set of names acquired while held
        self.acquisitions = 0    # total successful acquisitions observed
        self._installed = None   # (cls, orig_read, orig_write) when active

    # -- recording ---------------------------------------------------------

    def _stack(self):
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def record_attempt(self, name: str) -> None:
        """Edge from the innermost held lock to ``name`` (attempt time)."""
        st = self._stack()
        if st and st[-1] != name:
            with self._mu:
                self.edges.setdefault(st[-1], set()).add(name)

    def push(self, name: str) -> None:
        self._stack().append(name)
        with self._mu:
            self.acquisitions += 1

    def pop(self, name: str) -> None:
        st = self._stack()
        # remove the innermost matching entry (reentrant read locks may
        # stack the same name twice)
        for i in range(len(st) - 1, -1, -1):
            if st[i] == name:
                del st[i]
                return

    def reset(self) -> None:
        with self._mu:
            self.edges = {}
            self.acquisitions = 0

    # -- instrumentation ---------------------------------------------------

    @staticmethod
    def _lock_name(lock) -> str:
        return getattr(lock, "name", None) or f"lock@{id(lock):x}"

    def install(self, lock_cls=None) -> None:
        """Wrap ``RWLock.read_locked``/``write_locked`` on ``lock_cls``
        (default: the platform's ``repro.api.backend.RWLock``)."""
        if self._installed is not None:
            return
        if lock_cls is None:
            from repro.api.backend import RWLock as lock_cls
        orig_read = lock_cls.read_locked
        orig_write = lock_cls.write_locked
        witness = self

        def _wrap(orig):
            @contextlib.contextmanager
            def wrapped(lock, *args, **kwargs):
                name = witness._lock_name(lock)
                witness.record_attempt(name)
                with orig(lock, *args, **kwargs):
                    witness.push(name)
                    try:
                        yield
                    finally:
                        witness.pop(name)
            return wrapped

        lock_cls.read_locked = _wrap(orig_read)
        lock_cls.write_locked = _wrap(orig_write)
        self._installed = (lock_cls, orig_read, orig_write)

    def uninstall(self) -> None:
        if self._installed is None:
            return
        lock_cls, orig_read, orig_write = self._installed
        lock_cls.read_locked = orig_read
        lock_cls.write_locked = orig_write
        self._installed = None

    # -- analysis ----------------------------------------------------------

    def snapshot(self):
        with self._mu:
            return {k: set(v) for k, v in self.edges.items()}

    def find_cycle(self):
        """A list of lock names forming a cycle, or None if acyclic."""
        graph = self.snapshot()
        WHITE, GRAY, BLACK = 0, 1, 2
        color = {}
        parent = {}

        def dfs(start):
            stack = [(start, iter(sorted(graph.get(start, ()))))]
            color[start] = GRAY
            while stack:
                node, it = stack[-1]
                advanced = False
                for nxt in it:
                    c = color.get(nxt, WHITE)
                    if c == GRAY:
                        # unwind the gray chain into an explicit cycle
                        cycle = [nxt, node]
                        cur = node
                        while cur != nxt:
                            cur = parent[cur]
                            cycle.append(cur)
                        cycle.reverse()
                        return cycle
                    if c == WHITE:
                        color[nxt] = GRAY
                        parent[nxt] = node
                        stack.append((nxt, iter(sorted(graph.get(nxt, ())))))
                        advanced = True
                        break
                if not advanced:
                    color[node] = BLACK
                    stack.pop()
            return None

        for start in sorted(graph):
            if color.get(start, WHITE) == WHITE:
                cycle = dfs(start)
                if cycle:
                    return cycle
        return None

    def assert_acyclic(self, context: str = "") -> None:
        cycle = self.find_cycle()
        if cycle:
            where = f" after {context}" if context else ""
            raise AssertionError(
                f"lock-order witness found an acquisition cycle{where}: "
                + " -> ".join(cycle)
                + f" (graph: { {k: sorted(v) for k, v in sorted(self.snapshot().items())} })"
            )


#: Process-wide witness; tests/conftest.py installs it for the run.
witness = LockOrderWitness()
