"""REG-EVENT / REG-METRIC / REG-ROUTE: wire registries vs. reality.

The wire contract lives in five hand-pinned tables — ``ROUTES`` /
``ADMIN_ROUTES`` / ``WORKLOAD_ROUTES`` / ``OBS_ROUTES`` (plus the
``ROUTE_HANDLERS`` dispatch table), ``PLATFORM_EVENT_KINDS``, and
``METRIC_NAMES``. docs/api.md is already pinned against the tables;
this checker pins the tables against the *code*:

* **REG-EVENT** — every literal kind passed to an ``emit()`` site must
  be in ``PLATFORM_EVENT_KINDS`` (an operator keying automation on
  /v2/events must be able to trust the vocabulary is complete), and
  every registered kind must still be mentioned by some emit site or
  kind table (no zombie vocabulary). Kinds emitted through variables
  are out of static reach — the vocabulary tuples those variables draw
  from are literals, so the reverse direction still covers them.
* **REG-METRIC** — the family names rendered by
  ``collect_metric_families`` and the ``METRIC_NAMES`` registry must
  match exactly, both directions.
* **REG-ROUTE** — ``ROUTE_HANDLERS`` keys must equal the union of the
  ``*_ROUTES`` tables; every handler it names must exist; every
  ``_h_*`` handler defined must be routed. A route table without a
  ``ROUTE_HANDLERS`` dispatch table at all is itself a finding: routes
  reachable only through an if-chain are exactly the drift this check
  exists to prevent.

Each sub-check only runs when its registry is present in the analyzed
tree, so fixture snippets can exercise one invariant in isolation.
"""

from __future__ import annotations

import ast

from repro.analysis.base import Finding, scope_of

_METRIC_TYPES = {"counter", "gauge", "histogram"}


def _find_assign(sources, name):
    """Locate ``name = <literal>`` at module level. Returns
    (source, assign_node, value_node) or None."""
    for src in sources:
        for node in src.tree.body:
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and tgt.id == name:
                        return src, node, node.value
            elif isinstance(node, ast.AnnAssign):
                if (isinstance(node.target, ast.Name)
                        and node.target.id == name and node.value):
                    return src, node, node.value
    return None


def _str_elts(value_node):
    out = []
    for elt in getattr(value_node, "elts", []):
        if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
            out.append((elt.value, elt.lineno))
    return out


def _emit_kind(call: ast.Call):
    """Literal kind of an emit site, or None if dynamic/not an emit.

    ``bus.emit(component, kind, **fields)`` — kind is the second
    positional or the ``kind=`` keyword. Plane-level ``self._emit``
    helpers take the kind first.
    """
    fn = call.func
    attr = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else "")
    idx = {"emit": 1, "_emit": 0}.get(attr)
    if idx is None:
        return None
    for kw in call.keywords:
        if kw.arg == "kind":
            if isinstance(kw.value, ast.Constant) and isinstance(kw.value.value, str):
                return kw.value.value
            return None
    if len(call.args) > idx:
        arg = call.args[idx]
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            return arg.value
    return None


def _check_events(sources, findings):
    found = _find_assign(sources, "PLATFORM_EVENT_KINDS")
    if not found:
        return
    reg_src, reg_node, reg_value = found
    kinds = {v for v, _ in _str_elts(reg_value)}
    registry_literals = set()
    for n in ast.walk(reg_node):
        registry_literals.add(id(n))

    mentioned = set()
    for src in sources:
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call):
                kind = _emit_kind(node)
                if kind is not None and kind not in kinds:
                    findings.append(Finding(
                        check="REG-EVENT",
                        path=src.path,
                        line=node.lineno,
                        scope=scope_of(node),
                        message=(
                            f"emit kind `{kind}` is not in "
                            f"PLATFORM_EVENT_KINDS — register it (the "
                            f"/v2/events vocabulary is a wire contract)"
                        ),
                        detail=kind,
                    ))
            if (isinstance(node, ast.Constant)
                    and isinstance(node.value, str)
                    and id(node) not in registry_literals):
                mentioned.add(node.value)

    for kind, lineno in _str_elts(reg_value):
        if kind not in mentioned:
            findings.append(Finding(
                check="REG-EVENT",
                path=reg_src.path,
                line=lineno,
                scope="PLATFORM_EVENT_KINDS",
                message=(
                    f"registered kind `{kind}` is emitted nowhere in "
                    f"the tree — zombie vocabulary, delete or emit it"
                ),
                detail=kind,
            ))


def _check_metrics(sources, findings):
    found = _find_assign(sources, "METRIC_NAMES")
    if not found:
        return
    reg_src, _, reg_value = found
    registered = dict(_str_elts(reg_value))  # name -> line

    rendered = {}  # name -> (path, line)
    for src in sources:
        for node in ast.walk(src.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if node.name != "collect_metric_families":
                continue
            for tup in ast.walk(node):
                if not isinstance(tup, ast.Tuple) or len(tup.elts) < 3:
                    continue
                head, kind = tup.elts[0], tup.elts[1]
                if (isinstance(head, ast.Constant) and isinstance(head.value, str)
                        and isinstance(kind, ast.Constant)
                        and kind.value in _METRIC_TYPES):
                    rendered.setdefault(head.value, (src.path, tup.lineno))

    for name, (path, line) in sorted(rendered.items()):
        if name not in registered:
            findings.append(Finding(
                check="REG-METRIC",
                path=path,
                line=line,
                scope="collect_metric_families",
                message=(
                    f"rendered family `{name}` is not in METRIC_NAMES — "
                    f"register it (family names are a wire contract)"
                ),
                detail=name,
            ))
    for name, line in sorted(registered.items()):
        if name not in rendered:
            findings.append(Finding(
                check="REG-METRIC",
                path=reg_src.path,
                line=line,
                scope="METRIC_NAMES",
                message=(
                    f"registered family `{name}` is rendered nowhere — "
                    f"zombie metric, delete or render it"
                ),
                detail=name,
            ))


def _route_pairs(value_node):
    out = []
    for elt in getattr(value_node, "elts", []):
        if isinstance(elt, ast.Tuple) and len(elt.elts) == 2:
            m, t = elt.elts
            if (isinstance(m, ast.Constant) and isinstance(t, ast.Constant)):
                out.append((f"{m.value} {t.value}", elt.lineno))
    return out


def _check_routes(sources, findings):
    tables = {}
    for src in sources:
        for node in src.tree.body:
            if not isinstance(node, ast.Assign):
                continue
            for tgt in node.targets:
                if (isinstance(tgt, ast.Name)
                        and (tgt.id == "ROUTES" or tgt.id.endswith("_ROUTES"))
                        and tgt.id != "UNAUTHENTICATED_ROUTES"):
                    pairs = _route_pairs(node.value)
                    if pairs:
                        tables[tgt.id] = (src, node, pairs)
    if not tables:
        return

    routed = {}  # "METHOD /tpl" -> (path, line)
    table_file = None
    for tname, (src, node, pairs) in sorted(tables.items()):
        table_file = src
        for key, line in pairs:
            routed.setdefault(key, (src.path, line))

    handlers = _find_assign(sources, "ROUTE_HANDLERS")
    if handlers is None:
        src, node, _ = next(iter(tables.values()))
        findings.append(Finding(
            check="REG-ROUTE",
            path=src.path,
            line=node.lineno,
            scope="<module>",
            message=(
                "route tables exist but no ROUTE_HANDLERS dispatch "
                "table — routes must resolve to handlers declaratively, "
                "not through an if-chain"
            ),
            detail="ROUTE_HANDLERS-missing",
        ))
        return

    h_src, h_node, h_value = handlers
    mapping = {}  # "METHOD /tpl" -> (handler_name, line)
    for k, v in zip(getattr(h_value, "keys", []), getattr(h_value, "values", [])):
        if (isinstance(k, ast.Constant) and isinstance(k.value, str)
                and isinstance(v, ast.Constant) and isinstance(v.value, str)):
            mapping[k.value] = (v.value, k.lineno)

    defined = {}
    for node in ast.walk(h_src.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defined[node.name] = node

    for key, (path, line) in sorted(routed.items()):
        if key not in mapping:
            findings.append(Finding(
                check="REG-ROUTE",
                path=path,
                line=line,
                scope="ROUTE_HANDLERS",
                message=f"route `{key}` has no ROUTE_HANDLERS entry",
                detail=key,
            ))
    for key, (handler, line) in sorted(mapping.items()):
        if key not in routed:
            findings.append(Finding(
                check="REG-ROUTE",
                path=h_src.path,
                line=line,
                scope="ROUTE_HANDLERS",
                message=(
                    f"ROUTE_HANDLERS entry `{key}` is in no *_ROUTES "
                    f"table — the pinned tables are the contract"
                ),
                detail=key,
            ))
        if handler not in defined:
            findings.append(Finding(
                check="REG-ROUTE",
                path=h_src.path,
                line=line,
                scope="ROUTE_HANDLERS",
                message=(
                    f"route `{key}` names handler `{handler}` which is "
                    f"not defined in {h_src.name}"
                ),
                detail=handler,
            ))
    wired = {handler for handler, _ in mapping.values()}
    for name, node in sorted(defined.items()):
        if name.startswith("_h_") and name not in wired:
            findings.append(Finding(
                check="REG-ROUTE",
                path=h_src.path,
                line=node.lineno,
                scope=scope_of(node),
                message=(
                    f"handler `{name}` is defined but routed nowhere — "
                    f"dead endpoint or missing ROUTE_HANDLERS entry"
                ),
                detail=name,
            ))


def check_registries(sources) -> list:
    findings = []
    _check_events(sources, findings)
    _check_metrics(sources, findings)
    _check_routes(sources, findings)
    return findings
