"""Invariant analyzer suite: machine-checked concurrency + contract rules.

Nine PRs of growth left this platform with a set of load-bearing
conventions — per-shard RW locks acquired in a canonical order, pure
``decide()`` policy cores, seeded RNG everywhere determinism matters,
and five hand-pinned wire registries — that were enforced only by
docstrings and scattered tests. FfDL's dependability lessons (§5.6 and
the Boag et al. companion study) say exactly these conventions are where
multi-tenant platforms rot: concurrency discipline and contract drift,
not model code. This package turns the conventions into *invariants*:

  * **static checkers** (stdlib ``ast`` only, no third-party deps) run
    over ``src/repro`` by ``python -m repro.analysis`` / ``make lint``:

      - ``LOCK-BLOCKING`` / ``LOCK-ORDER``  (:mod:`repro.analysis.locks`)
      - ``PURITY-CALL`` / ``PURITY-MUTATION`` (:mod:`repro.analysis.purity`)
      - ``DET-AMBIENT``  (:mod:`repro.analysis.determinism`)
      - ``REG-EVENT`` / ``REG-METRIC`` / ``REG-ROUTE``
        (:mod:`repro.analysis.registry`)
      - ``DEADLINE-VERB``  (:mod:`repro.analysis.deadlines`)

  * a **runtime lock-order witness** (:mod:`repro.analysis.witness`)
    that instruments ``RWLock`` acquisition under pytest and the chaos
    benchmarks and asserts the observed acquisition graph is acyclic —
    catching dynamic ordering hazards the AST cannot see.

Intentional exceptions live in ``baseline.json`` next to this file;
every entry carries a ``reason`` and the CLI fails on any finding not
baselined. docs/architecture.md ("Invariants & static analysis")
documents the check table and the lock lattice; tests/test_docs_api.py
pins that section, and tests/test_analysis.py proves each check fires
on a seeded violation.
"""

from repro.analysis.base import (
    AnalysisResult,
    Baseline,
    Finding,
    SourceFile,
    load_sources,
)
from repro.analysis.deadlines import check_deadlines
from repro.analysis.determinism import check_determinism
from repro.analysis.locks import LOCK_LATTICE, check_locks
from repro.analysis.purity import PURE_REGISTRY, check_purity
from repro.analysis.registry import check_registries

# The pinned check-id vocabulary (docs/architecture.md tables these; a
# new checker must add its ids here so the docs pin catches it).
CHECK_IDS = (
    "LOCK-BLOCKING",   # blocking call while holding a shard/plane lock
    "LOCK-ORDER",      # lock acquired against the declared lattice order
    "PURITY-CALL",     # registered-pure function reaches an impure call
    "PURITY-MUTATION",  # registered-pure function mutates an input
    "DET-AMBIENT",     # ambient clock / unseeded RNG outside the allowlist
    "REG-EVENT",       # emitted event kind missing from PLATFORM_EVENT_KINDS
    "REG-METRIC",      # rendered metric family <-> METRIC_NAMES drift
    "REG-ROUTE",       # route table <-> handler table drift
    "DEADLINE-VERB",   # v1/v2 verb dispatched outside a deadline_scope
)

CHECKERS = (
    check_locks,
    check_purity,
    check_determinism,
    check_registries,
    check_deadlines,
)


def run_analysis(root=None) -> AnalysisResult:
    """Run every checker over ``src/repro`` (or ``root``); returns the
    raw findings (baseline NOT yet applied — the CLI does that)."""
    sources = load_sources(root)
    findings = []
    for checker in CHECKERS:
        findings.extend(checker(sources))
    findings.sort(key=lambda f: (f.path, f.line, f.check))
    return AnalysisResult(findings=findings, files=len(sources))


__all__ = [
    "AnalysisResult",
    "Baseline",
    "CHECK_IDS",
    "CHECKERS",
    "Finding",
    "LOCK_LATTICE",
    "PURE_REGISTRY",
    "SourceFile",
    "check_deadlines",
    "check_determinism",
    "check_locks",
    "check_purity",
    "check_registries",
    "load_sources",
    "run_analysis",
]
