"""Shared plumbing for the invariant analyzers.

Everything here is stdlib-only (``ast``, ``json``, ``pathlib``): the
analyzers must run in CI and in the bare container with no third-party
installs. A checker is a function ``(sources) -> list[Finding]`` over
pre-parsed :class:`SourceFile` objects; the CLI subtracts the committed
``baseline.json`` and exits non-zero on anything left.

Baseline keys deliberately omit line numbers — ``CHECK:path:scope:detail``
— so unrelated edits above a justified exception don't invalidate it,
while moving the offending code to a *different* function does.
"""

from __future__ import annotations

import ast
import dataclasses
import json
from pathlib import Path

#: Repo root, derived from this file's location (src/repro/analysis/base.py).
REPO_ROOT = Path(__file__).resolve().parents[3]

#: Default analysis target.
DEFAULT_TARGET = REPO_ROOT / "src" / "repro"

#: Committed exceptions file (JSON list of {"key":..., "reason":...}).
BASELINE_PATH = Path(__file__).resolve().parent / "baseline.json"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer hit: stable ``key`` for baselining, ``line`` for humans."""

    check: str    # e.g. "LOCK-ORDER" — must be one of repro.analysis.CHECK_IDS
    path: str     # repo-relative posix path, e.g. "src/repro/api/admin.py"
    line: int     # 1-based line of the offending node
    scope: str    # dotted qualname of the enclosing def/class, or "<module>"
    message: str  # human-readable explanation

    #: Short stable token distinguishing findings within one scope
    #: (e.g. the blocked call name, the event kind, the metric family).
    detail: str = ""

    @property
    def key(self) -> str:
        return f"{self.check}:{self.path}:{self.scope}:{self.detail}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.check} [{self.scope}] {self.message}"


@dataclasses.dataclass
class SourceFile:
    """A parsed module: ``tree`` has ``.parent`` links on every node."""

    path: str          # repo-relative posix path
    text: str
    tree: ast.Module

    @property
    def name(self) -> str:
        return Path(self.path).name


@dataclasses.dataclass
class AnalysisResult:
    findings: list
    files: int

    def split(self, baseline: "Baseline"):
        """Partition into (new, baselined) against the committed baseline."""
        new, old = [], []
        for f in self.findings:
            (old if baseline.covers(f) else new).append(f)
        return new, old


class Baseline:
    """The committed exception list. Every entry needs a ``reason`` —
    an entry without one is itself a failure (the CLI enforces this)."""

    def __init__(self, entries=None):
        self.entries = list(entries or [])
        self._keys = {e.get("key") for e in self.entries}
        self._hit = set()

    @classmethod
    def load(cls, path: Path = BASELINE_PATH) -> "Baseline":
        if not path.exists():
            return cls([])
        return cls(json.loads(path.read_text()))

    def covers(self, finding: Finding) -> bool:
        if finding.key in self._keys:
            self._hit.add(finding.key)
            return True
        return False

    def unjustified(self):
        return [e for e in self.entries if not str(e.get("reason", "")).strip()]

    def stale(self):
        """Entries that matched nothing — the exception no longer exists
        and should be deleted rather than silently carried forward."""
        return [e for e in self.entries if e.get("key") not in self._hit]


def annotate_parents(tree: ast.AST) -> ast.AST:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child.parent = node
    return tree


def scope_of(node: ast.AST) -> str:
    """Dotted qualname of the innermost enclosing def/class chain."""
    parts = []
    cur = getattr(node, "parent", None)
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
        parts.append(node.name)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            parts.append(cur.name)
        cur = getattr(cur, "parent", None)
    return ".".join(reversed(parts)) or "<module>"


def dotted_name(node: ast.AST) -> str:
    """Render Name/Attribute chains as 'a.b.c' ('' for anything dynamic)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else ""
    return ""


def parse_source(path: Path, root: Path) -> SourceFile:
    text = path.read_text()
    tree = ast.parse(text, filename=str(path))
    annotate_parents(tree)
    rel = path.resolve().relative_to(root.resolve()).as_posix()
    return SourceFile(path=rel, text=text, tree=tree)


def load_sources(root=None) -> list:
    """Parse every ``*.py`` under ``src/repro`` (or ``root``), returning
    :class:`SourceFile` objects with repo-relative paths. Skips caches."""
    root = Path(root) if root else REPO_ROOT
    target = root / "src" / "repro"
    if not target.exists():  # analyzing an arbitrary tree (tests do this)
        target = root
    sources = []
    for path in sorted(target.rglob("*.py")):
        if "__pycache__" in path.parts:
            continue
        sources.append(parse_source(path, root))
    return sources
