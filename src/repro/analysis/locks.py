"""LOCK-BLOCKING and LOCK-ORDER: static lock-discipline checks.

The platform's locks form a three-level lattice, acquired strictly
downward (a holder may only acquire lower levels):

    plane (2)   AdminPlane/WorkloadPlane ``_mutex`` RLocks, entered via
                the ``_serialized`` decorator
    shard (1)   per-shard ``RWLock`` (``read_locked``/``write_locked``,
                the gateway's ``_tenant_locked``/``_job_locked`` wrappers,
                and ``AllShardsLock`` which takes every shard lock in
                router order — the one sanctioned shard-while-shard site)
    leaf  (0)   internal mutexes/conditions (``self._lock``, ``_cond``,
                ``_metrics_lock``, ...) that never nest outward

Two rules, both intraprocedural (the runtime witness in
:mod:`repro.analysis.witness` covers what lexical analysis cannot):

* **LOCK-ORDER** — inside a region holding level L, acquiring level
  M >= L is a violation, except leaf-in-leaf (unordered internal
  mutexes never nest outward) and plane-in-plane (reentrant RLock).
  Shard-while-shard is flagged even when hand-sorted — such sites must
  carry a baseline justification tying them to AllShardsLock's total
  order (``AdminPlane._cutover`` is the one such site today).

* **LOCK-BLOCKING** — no sleeping, file/WAL flushing, or socket I/O
  while holding a shard or plane lock. Leaf locks are exempt: the
  MetaStore group-commit flushes its WAL under its own leaf mutex by
  design, and that's the level where it is safe.
"""

from __future__ import annotations

import ast

from repro.analysis.base import Finding, dotted_name, scope_of

#: The declared lattice (documented in docs/architecture.md; higher
#: acquires lower, never the reverse).
LOCK_LATTICE = {"plane": 2, "shard": 1, "leaf": 0}

#: Attribute-call names that acquire a shard-level lock.
_SHARD_CALLS = {"read_locked", "write_locked", "_tenant_locked", "_job_locked"}

#: Constructors treated as shard-level acquisitions (sanctioned total
#: order internally, but still a shard hold for what runs under them).
_SHARD_CTORS = {"AllShardsLock"}

#: Bare context-manager attributes that are plane mutexes.
_PLANE_ATTRS = {"_mutex"}

#: Blocking calls, as dotted names and bare attribute names.
_BLOCKING_DOTTED = {
    "time.sleep",
    "os.fsync",
    "socket.create_connection",
    "deadline_sleep",
    "urlopen",
    "open",
}
_BLOCKING_ATTRS = {
    "sleep",
    "fsync",
    "flush",
    "sendall",
    "recv",
    "sendfile",
    "getresponse",
    "urlopen",
    "deadline_sleep",
}


def _classify(expr: ast.AST):
    """Map a ``with`` item's context expression to a lattice level.

    Returns ``(level, label)`` or ``None`` for non-lock managers
    (``deadline_scope``, ``ExitStack``, files opened via with, ...).
    """
    if isinstance(expr, ast.Call):
        fn = expr.func
        if isinstance(fn, ast.Attribute):
            if fn.attr in _SHARD_CALLS:
                return "shard", dotted_name(fn)
        if isinstance(fn, ast.Name):
            if fn.id in _SHARD_CALLS:
                return "shard", fn.id
            if fn.id in _SHARD_CTORS:
                return "shard", fn.id
        return None
    # Bare lock objects used directly as context managers.
    if isinstance(expr, ast.Attribute):
        attr = expr.attr
        if attr in _PLANE_ATTRS:
            return "plane", dotted_name(expr)
        if attr.startswith("_") and any(t in attr for t in ("lock", "mutex", "cond")):
            return "leaf", dotted_name(expr)
    if isinstance(expr, ast.Name):
        nid = expr.id
        if nid.startswith("_") and any(t in nid for t in ("lock", "mutex", "cond")):
            return "leaf", nid
    return None


def _is_serialized(func: ast.AST) -> bool:
    for dec in func.decorator_list:
        name = dec.func if isinstance(dec, ast.Call) else dec
        if dotted_name(name).split(".")[-1] == "_serialized":
            return True
    return False


def _blocking_label(call: ast.Call):
    """Return a label if ``call`` is a known blocking primitive."""
    fn = call.func
    dn = dotted_name(fn)
    if dn in _BLOCKING_DOTTED:
        return dn
    if isinstance(fn, ast.Attribute) and fn.attr in _BLOCKING_ATTRS:
        return dn or fn.attr
    return None


class _FunctionLockWalker:
    """Walk one function's statements with a held-lock stack, emitting
    LOCK-ORDER on upward acquisitions and LOCK-BLOCKING on blocking
    calls under shard/plane holds. Nested defs are skipped here (they
    execute later; each gets its own top-level pass)."""

    def __init__(self, src, func, findings):
        self.src = src
        self.func = func
        self.findings = findings
        self.held = ["plane"] if _is_serialized(func) else []

    def run(self):
        for stmt in self.func.body:
            self._visit(stmt)

    def _visit(self, node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return  # analyzed separately with its own (empty) stack
        if isinstance(node, ast.With):
            self._visit_with(node)
            return
        if isinstance(node, ast.Call):
            self._check_blocking(node)
        for child in ast.iter_child_nodes(node):
            self._visit(child)

    def _pop(self, n):
        if n:
            del self.held[-n:]

    def _visit_with(self, node: ast.With):
        pushed = 0
        for item in node.items:
            # expressions inside the item may themselves contain calls
            self._visit(item.context_expr)
            cls = _classify(item.context_expr)
            if cls is None:
                continue
            level, label = cls
            self._check_order(node, level, label)
            self.held.append(level)
            pushed += 1
        for stmt in node.body:
            self._visit(stmt)
        self._pop(pushed)

    def _check_order(self, node, level, label):
        for held in self.held:
            ok = LOCK_LATTICE[level] < LOCK_LATTICE[held]
            # Sanctioned same-level reentry: leaf-in-leaf (unordered
            # internal mutexes) and plane-in-plane (reentrant RLock).
            if level == held and level in ("leaf", "plane"):
                ok = True
            if not ok:
                self.findings.append(Finding(
                    check="LOCK-ORDER",
                    path=self.src.path,
                    line=node.lineno,
                    scope=scope_of(self.func),
                    message=(
                        f"acquires {level} lock `{label}` while already "
                        f"holding a {held} lock — violates the "
                        f"plane->shard->leaf lattice"
                    ),
                    detail=label,
                ))
                return

    def _check_blocking(self, call: ast.Call):
        # children are visited by the caller's generic loop
        if not any(h in ("shard", "plane") for h in self.held):
            return
        label = _blocking_label(call)
        if label:
            outer = "plane" if "plane" in self.held else "shard"
            self.findings.append(Finding(
                check="LOCK-BLOCKING",
                path=self.src.path,
                line=call.lineno,
                scope=scope_of(self.func),
                message=(
                    f"blocking call `{label}` while holding a {outer} "
                    f"lock — sleeps/flushes/socket I/O must happen "
                    f"outside shard and plane critical sections"
                ),
                detail=label,
            ))


def check_locks(sources) -> list:
    findings = []
    for src in sources:
        for node in ast.walk(src.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            # AllShardsLock's internals ARE the sanctioned total order;
            # RWLock's internals only touch its own leaf condition.
            if scope_of(node).split(".")[0] in ("AllShardsLock",):
                continue
            _FunctionLockWalker(src, node, findings).run()
    return findings
