"""PURITY-CALL and PURITY-MUTATION: registered-pure policy cores.

The platform's control loops all follow the same shape: an impure shell
gathers an observation snapshot, a **pure** ``decide()`` turns it into a
list of action dicts, and the shell applies them. That purity is what
makes operator/reconciler/breaker decisions replayable and unit-testable
without a live platform — and it is exactly the property a refactor
silently breaks by reaching for ``time.time()`` or mutating the
observation in place.

``PURE_REGISTRY`` names the functions the platform promises are pure.
For each, the checker:

* **PURITY-CALL** — transitively follows same-file calls
  (``self.helper(...)``, bare module functions, ``Class.helper``) and
  flags any reachable I/O, ambient clock, or RNG use. Cross-module
  calls are not followed (the registry lists entry points whose helper
  graphs are file-local by construction).
* **PURITY-MUTATION** — flags statements in the *entry* function that
  mutate a parameter: subscript/attribute stores rooted at a parameter,
  or mutating method calls (``append``/``update``/``sort``/...) on one.
  Rebinding a parameter name (``outcomes = list(outcomes)``) untracks
  it — that's the sanctioned defensive-copy idiom. Helpers may mutate
  their own parameters (e.g. an ``out`` accumulator passed by the entry
  function); only the entry function's inputs are protected.
"""

from __future__ import annotations

import ast

from repro.analysis.base import Finding, dotted_name, scope_of

#: (repo-relative path, dotted qualname) of every function the platform
#: declares pure. docs/architecture.md tables this list.
PURE_REGISTRY = (
    ("src/repro/obs/operator.py", "OperatorPolicy.decide"),
    ("src/repro/workloads/reconciler.py", "ReconcilerPolicy.decide"),
    ("src/repro/core/faults.py", "BreakerPolicy.step"),
    ("src/repro/core/faults.py", "BreakerPolicy.observe"),
    ("src/repro/core/faults.py", "BreakerPolicy.allow_request"),
    ("src/repro/api/router.py", "encode_composite_cursor"),
    ("src/repro/api/router.py", "parse_composite_cursor"),
    ("src/repro/obs/bus.py", "event_to_wire"),
)

#: Calls that are impure on sight inside a pure function.
_IMPURE_EXACT = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.sleep",
    "datetime.now", "datetime.utcnow",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "open", "print", "input", "deadline_sleep",
}
_IMPURE_PREFIXES = (
    "random.", "np.random.", "numpy.random.",
    "os.", "socket.", "urllib.", "subprocess.", "sys.",
    "logging.",
)

#: Method names that mutate their receiver in place.
_MUTATING_METHODS = {
    "append", "extend", "insert", "remove", "pop", "clear", "sort",
    "reverse", "update", "setdefault", "popitem", "add", "discard",
    "appendleft", "popleft",
}


def _index_file(src):
    """Map dotted qualnames -> function nodes for one module."""
    table = {}
    for node in ast.walk(src.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            table[scope_of(node)] = node
    return table


def _resolve_callee(call: ast.Call, entry_scope: str, table):
    """Resolve a call to a same-file function node, or None.

    ``self.helper(...)`` -> method of the entry's class; bare names ->
    module-level function; ``Class.helper(...)`` -> that method.
    """
    fn = call.func
    if isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name):
        if fn.value.id == "self" and "." in entry_scope:
            cls = entry_scope.rsplit(".", 1)[0]
            return table.get(f"{cls}.{fn.attr}")
        return table.get(f"{fn.value.id}.{fn.attr}")
    if isinstance(fn, ast.Name):
        return table.get(fn.id)
    return None


def _impure_label(call: ast.Call):
    dn = dotted_name(call.func)
    if dn in _IMPURE_EXACT:
        return dn
    if dn and dn.startswith(_IMPURE_PREFIXES):
        return dn
    return None


def _check_calls(src, entry_name, qualname, node, table, visited, findings):
    if qualname in visited:
        return
    visited.add(qualname)
    for call in ast.walk(node):
        if not isinstance(call, ast.Call):
            continue
        label = _impure_label(call)
        if label:
            via = "" if qualname == entry_name else f" (via `{qualname}`)"
            findings.append(Finding(
                check="PURITY-CALL",
                path=src.path,
                line=call.lineno,
                scope=entry_name,
                message=(
                    f"registered-pure `{entry_name}` reaches impure call "
                    f"`{label}`{via}"
                ),
                detail=label,
            ))
            continue
        callee = _resolve_callee(call, qualname, table)
        if callee is not None:
            _check_calls(src, entry_name, scope_of(callee), callee,
                         table, visited, findings)


def _param_names(func):
    a = func.args
    names = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return [n for n in names if n not in ("self", "cls")]


def _root_name(node):
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _check_mutation(src, entry_name, func, findings):
    params = set(_param_names(func))
    # A parameter rebound to a fresh object anywhere in the body is the
    # defensive-copy idiom; stop tracking it entirely (flow-insensitive
    # but safe: the copy shadows the caller's object).
    for node in ast.walk(func):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                for name in ast.walk(tgt):
                    if isinstance(name, ast.Name) and isinstance(
                            name.ctx, ast.Store) and name.id in params:
                        params.discard(name.id)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            if isinstance(node.target, ast.Name):
                params.discard(node.target.id)
    if not params:
        return

    def flag(node, root, what):
        findings.append(Finding(
            check="PURITY-MUTATION",
            path=src.path,
            line=node.lineno,
            scope=entry_name,
            message=(
                f"registered-pure `{entry_name}` mutates its input "
                f"`{root}` ({what}) — copy before editing"
            ),
            detail=root,
        ))

    for node in ast.walk(func):
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            for tgt in targets:
                if isinstance(tgt, (ast.Subscript, ast.Attribute)):
                    root = _root_name(tgt)
                    if root in params:
                        flag(node, root, "item/attribute store")
        elif isinstance(node, ast.Delete):
            for tgt in node.targets:
                if isinstance(tgt, (ast.Subscript, ast.Attribute)):
                    root = _root_name(tgt)
                    if root in params:
                        flag(node, root, "del")
        elif isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Attribute) and fn.attr in _MUTATING_METHODS:
                root = _root_name(fn.value)
                if root in params:
                    flag(node, root, f".{fn.attr}() call")


def check_purity(sources, registry=PURE_REGISTRY) -> list:
    findings = []
    by_path = {s.path: s for s in sources}
    for path, qualname in registry:
        src = by_path.get(path)
        if src is None:
            # Fixture trees won't contain the real registry paths;
            # missing *files* are skipped, missing *functions* are not.
            continue
        table = _index_file(src)
        func = table.get(qualname)
        if func is None:
            findings.append(Finding(
                check="PURITY-CALL",
                path=path,
                line=1,
                scope=qualname,
                message=(
                    f"purity registry names `{qualname}` but no such "
                    f"function exists in {path} — fix the registry"
                ),
                detail="missing",
            ))
            continue
        _check_calls(src, qualname, qualname, func, table, set(), findings)
        _check_mutation(src, qualname, func, findings)
    return findings
