"""DEADLINE-VERB: every gateway verb runs under a deadline scope.

PR 9 gave the platform thread-local deadlines (``deadline_scope``) so a
gray-failing shard turns into a bounded 504 instead of a wedged caller,
and wrapped every v1 verb in the ``_deadlined`` decorator. The check
generalizes the rule: **any public method of a ``*Gateway`` class whose
first parameter is ``api_key`` is a wire verb**, and a wire verb must
either carry a deadline decorator (``_deadlined`` / anything built from
``deadline_guarded``) or open ``with deadline_scope(...)`` itself.

This is the check that would have flagged the v2 planes: AdminGateway
and WorkloadGateway shipped without budgets, so a cutover stuck behind
a slow shard held the caller forever (fixed in this PR via
``repro.api.types.deadline_guarded``).
"""

from __future__ import annotations

import ast

from repro.analysis.base import Finding, dotted_name

#: Decorator names that satisfy the requirement.
_DEADLINE_DECORATORS = {"_deadlined", "deadline_guarded", "deadlined"}


def _has_deadline_decorator(func) -> bool:
    for dec in func.decorator_list:
        name = dec.func if isinstance(dec, ast.Call) else dec
        if dotted_name(name).split(".")[-1] in _DEADLINE_DECORATORS:
            return True
    return False


def _opens_deadline_scope(func) -> bool:
    for node in ast.walk(func):
        if isinstance(node, ast.With):
            for item in node.items:
                expr = item.context_expr
                if isinstance(expr, ast.Call):
                    if dotted_name(expr.func).split(".")[-1] == "deadline_scope":
                        return True
    return False


def _is_verb(func) -> bool:
    if func.name.startswith("_"):
        return False
    args = func.args.posonlyargs + func.args.args
    names = [a.arg for a in args]
    return len(names) >= 2 and names[0] == "self" and names[1] == "api_key"


def check_deadlines(sources) -> list:
    findings = []
    for src in sources:
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if not node.name.endswith("Gateway"):
                continue
            for func in node.body:
                if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if not _is_verb(func):
                    continue
                if _has_deadline_decorator(func) or _opens_deadline_scope(func):
                    continue
                findings.append(Finding(
                    check="DEADLINE-VERB",
                    path=src.path,
                    line=func.lineno,
                    scope=f"{node.name}.{func.name}",
                    message=(
                        f"wire verb `{node.name}.{func.name}` runs "
                        f"without a deadline_scope — a gray-failing "
                        f"shard wedges the caller forever; wrap it in "
                        f"`_deadlined`/`deadline_guarded`"
                    ),
                    detail=func.name,
                ))
    return findings
