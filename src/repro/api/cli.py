"""``ffdl`` — the thin CLI for the v1 API tier.

Speaks ONLY the wire protocol (JSON over HTTP via
:class:`~repro.api.http.HttpTransport`); it has no in-process shortcut to
the platform, so everything it can do, any HTTP client can do.

    python -m repro.api.cli serve --port 8084 --tenant demo --rate 200
    export FFDL_ENDPOINT=http://127.0.0.1:8084 FFDL_API_KEY=ffdl-...
    python -m repro.api.cli submit --name train1 --learners 2 --chips 2 \
        --sim-duration 120 --idempotency-key train1-try1
    python -m repro.api.cli list --limit 10
    python -m repro.api.cli status job-00001 --watch
    python -m repro.api.cli logs job-00001 --follow
    python -m repro.api.cli halt job-00001 && python -m repro.api.cli resume job-00001
    python -m repro.api.cli events --follow --kind job_completed
    python -m repro.api.cli usage
    # v2 admin plane (use the operator key `serve` prints):
    python -m repro.api.cli admin shards
    python -m repro.api.cli admin create-tenant team-a --quota 8 --shard shard-0
    python -m repro.api.cli admin migrate team-a shard-1 --wait
    python -m repro.api.cli admin drain shard-0
    # autonomous operator (requires `serve --operator`):
    python -m repro.api.cli admin operator
    python -m repro.api.cli admin rollout v1 --wait

``serve`` boots a local simulated platform — optionally federated over
``--shards`` independent backend shards — prints one API key per
``--tenant`` (with its shard placement), and ticks the simulation in the
foreground so submitted jobs actually run — the zero-to-aha path for
``make serve``. ``logs --follow``, ``status --watch`` and
``events --follow`` each hold ONE SSE connection (heartbeats, exact
resume via ``Last-Event-ID``); ``--long-poll`` forces the request-train
fallback.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.api.http import ApiHttpServer, HttpTransport
from repro.api.ratelimit import RateLimitConfig
from repro.api.types import ApiError
from repro.core.types import JobManifest

DEFAULT_ENDPOINT = "http://127.0.0.1:8084"


def _transport(args) -> HttpTransport:
    return HttpTransport(args.endpoint)


def _key(args) -> str:
    if not args.key:
        sys.exit("error: no API key (pass --key or set FFDL_API_KEY)")
    return args.key


def _print_json(obj):
    print(json.dumps(obj, indent=2, default=str))


def _view_row(v) -> str:
    return (f"{v.job_id:12s} {v.tenant:12s} {v.status:12s} "
            f"step={v.progress_step:<6d} {v.name}")


# --------------------------------------------------------------------------
# Subcommands
# --------------------------------------------------------------------------

def cmd_serve(args) -> int:
    from repro.api.federation import Federation
    fed = Federation(n_shards=args.shards, n_hosts=args.hosts,
                     chips_per_host=args.chips_per_host)
    if getattr(args, "operator", False):
        from repro.api.ops import install_operator
        install_operator(fed)
        print("autonomous operator: ON (autoscaling, hot-tenant isolation, "
              "rolling upgrades via /v2/admin/operator)")
    rate = None
    if args.rate:
        rate = RateLimitConfig(rate=args.rate, burst=args.burst,
                               max_inflight=args.max_inflight)
    server = ApiHttpServer(fed, host=args.host, port=args.port,
                           rate_limit=rate)
    print(f"ffdl API server listening on {server.base_url} "
          f"({args.shards} shard{'s' if args.shards != 1 else ''})")
    for tenant in args.tenant or ["demo"]:
        print(f"  tenant {tenant!r} -> {fed.shard_of(tenant)}: API key "
              f"{fed.auth.issue_key(tenant)}")
    print(f"  operator (v2 admin plane) API key "
          f"{fed.auth.issue_admin_key()}")
    limited = f"rate={args.rate}/s burst={args.burst}" if rate else "off"
    print(f"  rate limiting: {limited}")
    print("ticking simulation; Ctrl-C to stop")
    with server:
        try:
            while True:
                time.sleep(args.tick_period)
                # per-shard write locks: reads on other shards keep flowing
                fed.tick()
        except KeyboardInterrupt:
            print("\nbye")
    return 0


def cmd_health(args) -> int:
    out = _transport(args).health()
    _print_json(out)
    return 0 if out.get("status") == "ok" else 1


def cmd_submit(args) -> int:
    manifest = JobManifest(
        name=args.name, tenant=args.tenant, n_learners=args.learners,
        chips_per_learner=args.chips, sim_duration=args.sim_duration,
        **(json.loads(args.extra) if args.extra else {}))
    from repro.api.types import SubmitRequest
    resp = _transport(args).submit(
        _key(args), SubmitRequest(manifest=manifest,
                                  idempotency_key=args.idempotency_key))
    dedup = " (deduplicated)" if resp.deduplicated else ""
    print(f"{resp.job_id}{dedup}")
    return 0


def cmd_list(args) -> int:
    t = _transport(args)
    cursor = args.cursor
    while True:
        page = t.list_jobs(_key(args), tenant=args.tenant,
                           status=args.status, cursor=cursor,
                           limit=args.limit)
        for v in page.items:
            print(_view_row(v))
        cursor = page.next_cursor
        if cursor is None or not args.all:
            if cursor is not None:
                print(f"# next cursor: {cursor}  (pass --cursor or --all)")
            return 0


def cmd_status(args) -> int:
    if args.watch:
        from repro.api.client import ApiClient
        client = ApiClient(_transport(args), _key(args),
                           prefer_sse=not args.long_poll)
        for v in client.watch_status(args.job_id, wait_ms=args.wait_ms):
            print(f"{v.job_id} {v.status:12s} step={v.progress_step:<6d} "
                  f"{v.message}", flush=True)
        return 0
    v = _transport(args).status(_key(args), args.job_id)
    _print_json({"job_id": v.job_id, "name": v.name, "tenant": v.tenant,
                 "status": v.status, "progress_step": v.progress_step,
                 "submitted_at": v.submitted_at,
                 "finished_at": v.finished_at, "message": v.message})
    return 0


def cmd_history(args) -> int:
    for ts, status, msg in _transport(args).status_history(_key(args),
                                                           args.job_id):
        print(f"{ts:10.1f}  {status:12s} {msg}")
    return 0


def cmd_logs(args) -> int:
    if args.follow:
        from repro.api.client import ApiClient
        client = ApiClient(_transport(args), _key(args),
                           prefer_sse=not args.long_poll)
        for line in client.follow_logs(args.job_id, cursor=args.cursor,
                                       wait_ms=args.wait_ms):
            print(line, flush=True)
        return 0
    t = _transport(args)
    cursor = args.cursor
    while True:
        page = t.logs(_key(args), args.job_id, cursor=cursor,
                      limit=args.limit)
        for line in page.items:
            print(line)
        cursor = page.next_cursor
        if cursor is None:
            return 0
        if args.limit is not None:  # --limit means exactly one page
            print(f"# next cursor: {cursor}  (pass --cursor to continue)")
            return 0


def cmd_search(args) -> int:
    page = _transport(args).search_logs(_key(args), args.query,
                                        job_id=args.job, cursor=args.cursor,
                                        limit=args.limit)
    for rec in page.items:
        print(f"{rec.job_id} learner={rec.learner} {rec.line}")
    if page.next_cursor is not None:
        print(f"# next cursor: {page.next_cursor}  (pass --cursor)")
    return 0


def cmd_events(args) -> int:
    from repro.api.client import ApiClient
    client = ApiClient(_transport(args), _key(args),
                       prefer_sse=not args.long_poll)
    if args.follow:
        try:
            for e in client.follow_events(cursor=args.cursor,
                                          kind=args.kind,
                                          wait_ms=args.wait_ms):
                print(json.dumps(e), flush=True)
        except KeyboardInterrupt:
            pass
        return 0
    out = client.events(cursor=args.cursor, limit=args.limit,
                        kind=args.kind)
    for e in out["items"]:
        print(json.dumps(e))
    if out["missed"]:
        print(f"# {out['missed']} events aged out of retention before "
              f"this cursor", file=sys.stderr)
    print(f"# next cursor: {out['next_cursor']}", file=sys.stderr)
    return 0


def cmd_usage(args) -> int:
    from repro.api.client import ApiClient
    rows = ApiClient(_transport(args), _key(args)).usage(tenant=args.tenant)
    for u in rows:
        print(f"{u['tenant']:16s} chip_s={u['chip_seconds']:<10g} "
              f"jobs={u['jobs_submitted']}/{u['jobs_completed']}"
              f"/{u['jobs_failed']} (sub/done/fail) "
              f"log_bytes={u['log_bytes']} 429s={u['throttled_429s']}")
    return 0


def cmd_halt(args) -> int:
    _transport(args).halt(_key(args), args.job_id, requeue=args.requeue)
    print(f"{args.job_id} halted")
    return 0


def cmd_resume(args) -> int:
    _transport(args).resume(_key(args), args.job_id)
    print(f"{args.job_id} resumed")
    return 0


def cmd_cancel(args) -> int:
    _transport(args).cancel(_key(args), args.job_id)
    print(f"{args.job_id} cancelled")
    return 0


# -- v2 admin plane (operator key with the 'admin' scope) ------------------

def _admin(args):
    from repro.api.client import AdminClient
    return AdminClient(_transport(args), _key(args))


def cmd_admin_shards(args) -> int:
    for s in _admin(args).list_shards():
        flags = ("cordoned" if s["cordoned"] else "") or ""
        print(f"{s['shard_id']:10s} {s['status']:5s} "
              f"chips={s['chips_used']}/{s['chips_total']} "
              f"jobs={s['jobs']} active={s['active_jobs']} "
              f"queue={s['queue_depth']} "
              f"tenants={','.join(s['tenants']) or '-'} {flags}")
    return 0


def cmd_admin_tenants(args) -> int:
    for t in _admin(args).list_tenants():
        quota = t["quota_chips"] if t["quota_chips"] is not None else "-"
        rate = f"{t['rate']}/{t['burst']}" if t["rate"] is not None else "-"
        mig = " (migrating)" if t["migrating"] else ""
        print(f"{t['name']:16s} shard={t['shard']:10s} quota={quota} "
              f"tier={t['tier']} rate={rate}{mig}")
    return 0


def _tenant_fields(args) -> dict:
    fields = {}
    if args.quota is not None:
        fields["quota_chips"] = args.quota
    if args.tier is not None:
        fields["tier"] = args.tier
    if args.rate is not None:
        fields["rate"] = args.rate
    if args.burst is not None:
        fields["burst"] = args.burst
    return fields


def cmd_admin_create_tenant(args) -> int:
    fields = _tenant_fields(args)
    if args.shard is not None:
        fields["shard"] = args.shard
    _print_json(_admin(args).create_tenant(args.name, **fields))
    return 0


def cmd_admin_patch_tenant(args) -> int:
    _print_json(_admin(args).patch_tenant(args.name, **_tenant_fields(args)))
    return 0


def cmd_admin_delete_tenant(args) -> int:
    _print_json(_admin(args).delete_tenant(args.name))
    return 0


def cmd_admin_cordon(args) -> int:
    _print_json(_admin(args).cordon(args.shard_id))
    return 0


def cmd_admin_uncordon(args) -> int:
    _print_json(_admin(args).uncordon(args.shard_id))
    return 0


def cmd_admin_drain(args) -> int:
    _print_json(_admin(args).drain(args.shard_id))
    return 0


def _wait_migration(admin, migration_id: str, timeout_s: float) -> dict:
    deadline = time.monotonic() + timeout_s
    while True:
        m = admin.migration(migration_id)
        if m["phase"] in ("DONE", "FAILED") or time.monotonic() > deadline:
            return m
        time.sleep(0.2)


def cmd_admin_migrate(args) -> int:
    admin = _admin(args)
    m = admin.migrate(args.tenant, args.to_shard)
    if args.wait:
        m = _wait_migration(admin, m["migration_id"], args.timeout)
        _print_json(m)
        # a timed-out wait leaves the migration in-flight: that is NOT
        # success (scripts chain `--wait && decommission-source`)
        return 0 if m["phase"] == "DONE" else 1
    _print_json(m)
    return 0 if m["phase"] != "FAILED" else 1


def cmd_admin_operator(args) -> int:
    st = _admin(args).operator_status()
    ro = st.get("rollout")
    ro_line = "-"
    if ro is not None:
        ro_line = (f"{ro['version']} [{ro['state']}] wave {ro['wave']}"
                   + (f" on {ro['shard']}" if ro.get("shard") else ""))
    print(f"tick {st['tick']}  occupancy {st['occupancy']:.2f}  "
          f"rollout {ro_line}")
    for d in st["decisions"][-args.last:]:
        extra = {k: v for k, v in d.items()
                 if k not in ("tick", "action", "reason")}
        detail = " ".join(f"{k}={v}" for k, v in sorted(extra.items()))
        print(f"  t={d['tick']:<6d} {d['action']:<18s} {detail}")
        print(f"           {d['reason']}")
    return 0


def cmd_admin_rollout(args) -> int:
    admin = _admin(args)
    st = admin.rollout(args.version)
    if not args.wait:
        _print_json(st["rollout"])
        return 0
    deadline = time.monotonic() + args.timeout
    while time.monotonic() < deadline:
        ro = admin.operator_status().get("rollout") or {}
        if ro.get("state") in ("done", "halted"):
            _print_json(ro)
            return 0 if ro["state"] == "done" else 1
        time.sleep(0.2)
    print("timed out waiting for rollout", file=sys.stderr)
    return 1


def cmd_admin_migrations(args) -> int:
    for m in _admin(args).list_migrations():
        print(f"{m['migration_id']} {m['tenant']:16s} "
              f"{m['from_shard']} -> {m['to_shard']} {m['phase']:8s} "
              f"{m['error']}")
    return 0


def cmd_admin_migration(args) -> int:
    _print_json(_admin(args).migration(args.migration_id))
    return 0


def _workloads(args):
    from repro.api.client import WorkloadClient
    return WorkloadClient(_transport(args), _key(args))


def cmd_apply(args) -> int:
    text = (sys.stdin.read() if args.file == "-"
            else open(args.file, encoding="utf-8").read())
    view = _workloads(args).apply(text)
    verb = "created" if view.get("created") else "configured"
    print(f"{view['kind'].lower()}/{view['name']} {verb} "
          f"(generation {view['generation']})")
    return 0


def _workload_row(v) -> str:
    st = v["status"]
    detail = ""
    if v["kind"] == "Pipeline":
        done = sum(1 for s in st["stages"].values() if s["state"] == "DONE")
        detail = f"stages={done}/{len(st['stages'])}"
    elif v["kind"] == "RecurringJob":
        detail = f"runs={st['runs']} skipped={st['skipped']}"
    else:
        detail = (f"ready={len(st['ready_slots'])}/"
                  f"{v['spec']['replicas']}")
    return (f"{v['kind']:13s} {v['tenant']:12s} {v['name']:20s} "
            f"{st['phase']:10s} gen={v['generation']:<3d} {detail}")


def cmd_workloads_list(args) -> int:
    for v in _workloads(args).list(tenant=args.tenant):
        print(_workload_row(v))
    return 0


def cmd_workloads_get(args) -> int:
    _print_json(_workloads(args).get(args.name, tenant=args.tenant))
    return 0


def cmd_workloads_delete(args) -> int:
    view = _workloads(args).delete(args.name, tenant=args.tenant)
    print(f"{view['kind'].lower()}/{view['name']} deleted")
    return 0


def cmd_workloads_invoke(args) -> int:
    payload = json.loads(args.payload) if args.payload else None
    _print_json(_workloads(args).invoke(args.name, payload=payload,
                                        tenant=args.tenant))
    return 0


# --------------------------------------------------------------------------
# Parser
# --------------------------------------------------------------------------

def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="ffdl",
        description="CLI for the FfDL v1 HTTP API (see docs/api.md)")
    ap.add_argument("--endpoint",
                    default=os.environ.get("FFDL_ENDPOINT", DEFAULT_ENDPOINT),
                    help="API base URL (env FFDL_ENDPOINT)")
    ap.add_argument("--key", default=os.environ.get("FFDL_API_KEY"),
                    help="tenant API key (env FFDL_API_KEY)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("serve", help="run a local platform + HTTP server")
    s.add_argument("--host", default="127.0.0.1")
    s.add_argument("--port", type=int, default=8084)
    s.add_argument("--shards", type=int, default=1,
                   help="independent platform shards behind the gateway "
                        "(tenants are hash-routed; job ids stay unique)")
    s.add_argument("--hosts", type=int, default=8)
    s.add_argument("--chips-per-host", type=int, default=4)
    s.add_argument("--tenant", action="append",
                   help="issue a key for this tenant (repeatable)")
    s.add_argument("--rate", type=float, default=200.0,
                   help="per-tenant req/s (0 disables rate limiting)")
    s.add_argument("--burst", type=int, default=100)
    s.add_argument("--max-inflight", type=int, default=64)
    s.add_argument("--tick-period", type=float, default=0.05,
                   help="wall seconds between simulation ticks")
    s.add_argument("--operator", action="store_true",
                   help="install the autonomous operator (autoscaling, "
                        "hot-tenant isolation, rolling upgrades)")
    s.set_defaults(fn=cmd_serve)

    s = sub.add_parser("health", help="GET /v1/health")
    s.set_defaults(fn=cmd_health)

    s = sub.add_parser("submit", help="POST /v1/jobs")
    s.add_argument("--name", required=True)
    s.add_argument("--tenant", default="demo")
    s.add_argument("--learners", type=int, default=1)
    s.add_argument("--chips", type=int, default=1,
                   help="chips per learner")
    s.add_argument("--sim-duration", type=float, default=120.0)
    s.add_argument("--idempotency-key",
                   help="sent as the Idempotency-Key header")
    s.add_argument("--extra", help="extra manifest fields as a JSON object")
    s.set_defaults(fn=cmd_submit)

    s = sub.add_parser("list", help="GET /v1/jobs (cursor-paginated)")
    s.add_argument("--tenant")
    s.add_argument("--status")
    s.add_argument("--cursor")
    s.add_argument("--limit", type=int, default=20)
    s.add_argument("--all", action="store_true",
                   help="follow next_cursor to exhaustion")
    s.set_defaults(fn=cmd_list)

    s = sub.add_parser("status", help="GET /v1/jobs/{id}")
    s.add_argument("job_id")
    s.add_argument("--watch", "-w", action="store_true",
                   help="long-poll and print every status change until "
                        "the job reaches a terminal state")
    s.add_argument("--wait-ms", type=int, default=8000,
                   help="server-side park per --watch poll (capped 10s)")
    s.add_argument("--long-poll", action="store_true",
                   help="force long-poll for --watch instead of one SSE "
                        "stream")
    s.set_defaults(fn=cmd_status)

    s = sub.add_parser("history", help="GET /v1/jobs/{id}/history")
    s.add_argument("job_id")
    s.set_defaults(fn=cmd_history)

    s = sub.add_parser("logs", help="GET /v1/jobs/{id}/logs")
    s.add_argument("job_id")
    s.add_argument("--cursor")
    s.add_argument("--limit", type=int,
                   help="print at most this many lines (one page); "
                        "default: follow cursors to the end")
    s.add_argument("--follow", "-f", action="store_true",
                   help="long-poll for new lines until the job reaches a "
                        "terminal state")
    s.add_argument("--wait-ms", type=int, default=8000,
                   help="server-side park per --follow poll (capped 10s)")
    s.add_argument("--long-poll", action="store_true",
                   help="force long-poll for --follow instead of one SSE "
                        "stream")
    s.set_defaults(fn=cmd_logs)

    s = sub.add_parser("search", help="GET /v1/logs/search")
    s.add_argument("query")
    s.add_argument("--job", help="restrict to one job id")
    s.add_argument("--cursor")
    s.add_argument("--limit", type=int)
    s.set_defaults(fn=cmd_search)

    s = sub.add_parser("events", help="GET /v2/events (platform event "
                                      "stream; one JSON object per line)")
    s.add_argument("--cursor", help="resume from this event cursor")
    s.add_argument("--kind", help="only events of this kind")
    s.add_argument("--limit", type=int, help="page size (no --follow)")
    s.add_argument("--follow", "-f", action="store_true",
                   help="stream new events until interrupted")
    s.add_argument("--wait-ms", type=int, default=8000,
                   help="server-side park per --follow poll (capped 10s)")
    s.add_argument("--long-poll", action="store_true",
                   help="force long-poll instead of one SSE stream")
    s.set_defaults(fn=cmd_events)

    s = sub.add_parser("usage", help="GET /v1/usage (per-tenant metering)")
    s.add_argument("--tenant", help="one tenant's row (admin keys)")
    s.set_defaults(fn=cmd_usage)

    s = sub.add_parser("halt", help="POST /v1/jobs/{id}/halt")
    s.add_argument("job_id")
    s.add_argument("--requeue", action="store_true")
    s.set_defaults(fn=cmd_halt)

    s = sub.add_parser("resume", help="POST /v1/jobs/{id}/resume")
    s.add_argument("job_id")
    s.set_defaults(fn=cmd_resume)

    s = sub.add_parser("cancel", help="DELETE /v1/jobs/{id}")
    s.add_argument("job_id")
    s.set_defaults(fn=cmd_cancel)

    # -- v2 admin plane ----------------------------------------------------
    adm = sub.add_parser(
        "admin", help="v2 admin control plane (operator key with the "
                      "'admin' scope; see docs/api.md)")
    asub = adm.add_subparsers(dest="admin_cmd", required=True)

    s = asub.add_parser("shards", help="GET /v2/admin/shards")
    s.set_defaults(fn=cmd_admin_shards)
    for name, fn in (("cordon", cmd_admin_cordon),
                     ("uncordon", cmd_admin_uncordon),
                     ("drain", cmd_admin_drain)):
        s = asub.add_parser(name,
                            help=f"POST /v2/admin/shards/{{id}}/{name}")
        s.add_argument("shard_id")
        s.set_defaults(fn=fn)

    s = asub.add_parser("tenants", help="GET /v2/admin/tenants")
    s.set_defaults(fn=cmd_admin_tenants)
    for name, fn, with_shard in (
            ("create-tenant", cmd_admin_create_tenant, True),
            ("patch-tenant", cmd_admin_patch_tenant, False)):
        s = asub.add_parser(name)
        s.add_argument("name")
        s.add_argument("--quota", type=int, help="chip quota")
        s.add_argument("--tier", choices=("paid", "free"))
        s.add_argument("--rate", type=float, help="req/s rate limit")
        s.add_argument("--burst", type=int)
        if with_shard:
            s.add_argument("--shard", help="pin to a named shard")
        s.set_defaults(fn=fn)
    s = asub.add_parser("delete-tenant",
                        help="DELETE /v2/admin/tenants/{name}")
    s.add_argument("name")
    s.set_defaults(fn=cmd_admin_delete_tenant)

    s = asub.add_parser("migrate",
                        help="POST /v2/admin/migrations (tenant -> shard)")
    s.add_argument("tenant")
    s.add_argument("to_shard")
    s.add_argument("--wait", action="store_true",
                   help="poll until DONE/FAILED")
    s.add_argument("--timeout", type=float, default=60.0)
    s.set_defaults(fn=cmd_admin_migrate)
    s = asub.add_parser("migrations", help="GET /v2/admin/migrations")
    s.set_defaults(fn=cmd_admin_migrations)
    s = asub.add_parser("migration",
                        help="GET /v2/admin/migrations/{id}")
    s.add_argument("migration_id")
    s.set_defaults(fn=cmd_admin_migration)

    s = asub.add_parser("operator",
                        help="GET /v2/admin/operator (status + decisions)")
    s.add_argument("--last", type=int, default=20,
                   help="show only the last N decisions")
    s.set_defaults(fn=cmd_admin_operator)
    s = asub.add_parser("rollout",
                        help="POST /v2/admin/operator/rollout "
                             "(rolling shard upgrade)")
    s.add_argument("version")
    s.add_argument("--wait", action="store_true",
                   help="poll until done/halted")
    s.add_argument("--timeout", type=float, default=120.0)
    s.set_defaults(fn=cmd_admin_rollout)

    # v2 workloads plane (tenant- or admin-keyed)
    s = sub.add_parser("apply",
                       help="POST /v2/workloads (apply a Pipeline / "
                            "RecurringJob / Service manifest)")
    s.add_argument("-f", "--file", required=True,
                   help="manifest file (JSON or YAML subset); '-' = stdin")
    s.set_defaults(fn=cmd_apply)

    wl = sub.add_parser("workloads", help="v2 workloads plane resources")
    wsub = wl.add_subparsers(dest="workloads_cmd", required=True)
    s = wsub.add_parser("list", help="GET /v2/workloads")
    s.add_argument("--tenant", help="admin keys: which tenant "
                                    "(omit for all)")
    s.set_defaults(fn=cmd_workloads_list)
    s = wsub.add_parser("get", help="GET /v2/workloads/{name}")
    s.add_argument("name")
    s.add_argument("--tenant", help="admin keys must pass this")
    s.set_defaults(fn=cmd_workloads_get)
    s = wsub.add_parser("delete", help="DELETE /v2/workloads/{name} "
                                       "(cascades + cancels)")
    s.add_argument("name")
    s.add_argument("--tenant", help="admin keys must pass this")
    s.set_defaults(fn=cmd_workloads_delete)
    s = wsub.add_parser("invoke",
                        help="POST /v2/workloads/{name}/invoke (one "
                             "inference request against a Service)")
    s.add_argument("name")
    s.add_argument("--payload", help="JSON request payload")
    s.add_argument("--tenant", help="admin keys must pass this")
    s.set_defaults(fn=cmd_workloads_invoke)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except ApiError as e:
        msg = f"error [{e.code.value}]: {e.message}"
        if e.retry_after is not None:
            msg += f" (retry after {e.retry_after}s)"
        print(msg, file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
