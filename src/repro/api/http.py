"""JSON-over-HTTP transport for the v1 API tier (FfDL §3.2).

FfDL's user-facing surface is a replicated REST tier behind a load
balancer; this module serves our v1 envelope contract over a real wire
using only the stdlib (``http.server``, threaded — no new dependencies).
The full contract is written down in ``docs/api.md`` and pinned by
``tests/test_docs_api.py``.

Server side
    :class:`ApiHttpServer` mounts the routes below over a platform's (or
    :class:`~repro.api.federation.Federation`'s) ``LoadBalancer`` — so
    HTTP composes with replica crash-masking — with an optional
    :class:`~repro.api.ratelimit.RateLimitedApi` front (per-tenant token
    buckets + bounded in-flight gate → 429 with ``Retry-After``).
    Locking is per-shard inside the gateway (reads share a shard's RW
    lock, writes take it exclusively; see ``repro.api.backend``), so a
    read on one shard never queues behind a submit — or a simulation
    tick — on another. ``server.lock`` remains for code that ticks the
    sim from another thread (``with server.lock: platform.tick()``): it
    takes every shard's write lock in shard order. Throttled calls are
    rejected *before* any lock, which is what keeps a flooding tenant
    cheap.

Client side
    :class:`HttpTransport` speaks the wire protocol and re-raises wire
    errors as ``ApiError`` with the original stable code — the same
    contract as the in-process transports, so
    ``ApiClient(HttpTransport(url), key)`` behaves like
    ``ApiClient(platform.api, key)``.

Routes (``{job_id}`` is a path segment)::

    GET    /v1/health                   liveness + replica counts (no auth)
    POST   /v1/jobs                     submit        (201; 200 when deduped)
    GET    /v1/jobs                     list_jobs     (tenant,status,cursor,limit)
    GET    /v1/jobs/{job_id}            status → JobView (wait_ms,last_status
                                        = watch long-poll)
    GET    /v1/jobs/{job_id}/history    status_history
    GET    /v1/jobs/{job_id}/logs       logs          (cursor,limit)
    GET    /v1/logs/search              search_logs   (q,job_id,cursor,limit)
    POST   /v1/jobs/{job_id}/halt       halt          (body: {"requeue": bool})
    POST   /v1/jobs/{job_id}/resume     resume
    DELETE /v1/jobs/{job_id}            cancel

The **v2 admin control plane** (``repro.api.admin``; requires an operator
key carrying the ``admin`` scope, envelopes stamped ``"v2"``)::

    POST   /v2/admin/tenants                        create tenant
    GET    /v2/admin/tenants                        list tenants
    GET    /v2/admin/tenants/{tenant}               get tenant
    PATCH  /v2/admin/tenants/{tenant}               patch quota/tier/rate
    DELETE /v2/admin/tenants/{tenant}               delete tenant
    GET    /v2/admin/shards                         list shards + occupancy
    GET    /v2/admin/shards/{shard_id}              get shard
    POST   /v2/admin/shards/{shard_id}/cordon       cordon
    POST   /v2/admin/shards/{shard_id}/uncordon     uncordon
    POST   /v2/admin/shards/{shard_id}/drain        migrate all off + cordon
    POST   /v2/admin/migrations                     start tenant→shard move
    GET    /v2/admin/migrations                     list migrations
    GET    /v2/admin/migrations/{migration_id}      get migration phase

Operator-keyed admin calls bypass the per-tenant rate limiter (they are
the operator's backpressure controls, not tenant traffic); unknown or
tenant keys probing /v2 still spend tokens from their usual bucket. The
error envelope and ``STATUS_OF`` mapping are shared with v1.

The **observability plane** (``repro.obs``)::

    GET    /metrics       Prometheus text exposition (no auth, no envelope)
    GET    /v1/usage      per-tenant usage meter (tenant: own row; admin: all)
    GET    /v2/events     platform event stream, cursor replay (+ SSE)

``/v1/jobs/{id}/logs``, ``/v1/jobs/{id}`` (status) and ``/v2/events``
additionally speak **Server-Sent Events**: a request carrying
``Accept: text/event-stream`` (or ``?stream=sse``) gets one chunked
response that stays open — data frames with resume ids, ``: hb``
heartbeat comments while idle, an ``event: end`` frame when a followed
job goes terminal. A reconnecting client sends ``Last-Event-ID`` and the
stream resumes exactly after it. Long-poll (``wait_ms``) remains the
fallback contract on the same routes.

Headers: ``Authorization: Bearer <key>`` on every authenticated route;
``Idempotency-Key`` on submit; ``Retry-After`` on 429/503 responses;
``Accept: text/event-stream`` + ``Last-Event-ID`` for SSE.
"""

from __future__ import annotations

import dataclasses
import http.client
import json
import math
import socket
import sys
import threading
import time
from collections import Counter
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib import parse as urlparse

from repro.api.backend import AllShardsLock
from repro.api.ratelimit import RateLimitConfig, RateLimitedApi
from repro.api.router import (
    OFFSET_CURSOR_RE,
    encode_composite_cursor,
    parse_composite_cursor,
)
from repro.api.types import (
    ADMIN_API_VERSION,
    API_VERSION,
    ApiError,
    ErrorCode,
    JobView,
    Page,
    SubmitRequest,
    SubmitResponse,
)
from repro.core.faults import BREAKER_STATE_VALUE
from repro.core.helpers import LogRecord
from repro.core.types import JobManifest, JobStatus, TERMINAL
from repro.obs import (
    Histogram,
    SSE_CONTENT_TYPE,
    UsageMeter,
    format_comment,
    format_event,
    iter_sse,
    render_metrics,
)

# job statuses as they appear on the wire
_TERMINAL_WIRE = {s.value for s in TERMINAL}

# Stable ErrorCode → HTTP status mapping. docs/api.md documents exactly
# this table and tests/test_docs_api.py fails if they ever diverge (or if
# a new code is added without a mapping).
STATUS_OF = {
    ErrorCode.UNAUTHENTICATED: 401,
    ErrorCode.FORBIDDEN: 403,
    ErrorCode.NOT_FOUND: 404,
    ErrorCode.INVALID_ARGUMENT: 400,
    ErrorCode.QUOTA_EXCEEDED: 429,
    ErrorCode.FAILED_PRECONDITION: 409,
    ErrorCode.CONFLICT: 409,
    ErrorCode.UNAVAILABLE: 503,
    ErrorCode.UNSUPPORTED_VERSION: 400,
    ErrorCode.RATE_LIMITED: 429,
    ErrorCode.DEADLINE_EXCEEDED: 504,
}

# Canonical route table (docs/api.md is checked against this).
ROUTES = (
    ("GET", "/v1/health"),
    ("POST", "/v1/jobs"),
    ("GET", "/v1/jobs"),
    ("GET", "/v1/jobs/{job_id}"),
    ("GET", "/v1/jobs/{job_id}/history"),
    ("GET", "/v1/jobs/{job_id}/logs"),
    ("GET", "/v1/logs/search"),
    ("POST", "/v1/jobs/{job_id}/halt"),
    ("POST", "/v1/jobs/{job_id}/resume"),
    ("DELETE", "/v1/jobs/{job_id}"),
)

# The v2 admin control plane (docs/api.md is checked against this too).
ADMIN_ROUTES = (
    ("POST", "/v2/admin/tenants"),
    ("GET", "/v2/admin/tenants"),
    ("GET", "/v2/admin/tenants/{tenant}"),
    ("PATCH", "/v2/admin/tenants/{tenant}"),
    ("DELETE", "/v2/admin/tenants/{tenant}"),
    ("GET", "/v2/admin/shards"),
    ("GET", "/v2/admin/shards/{shard_id}"),
    ("POST", "/v2/admin/shards/{shard_id}/cordon"),
    ("POST", "/v2/admin/shards/{shard_id}/uncordon"),
    ("POST", "/v2/admin/shards/{shard_id}/drain"),
    ("POST", "/v2/admin/migrations"),
    ("GET", "/v2/admin/migrations"),
    ("GET", "/v2/admin/migrations/{migration_id}"),
    ("GET", "/v2/admin/operator"),
    ("POST", "/v2/admin/operator/rollout"),
    ("POST", "/v2/admin/faults"),
    ("GET", "/v2/admin/faults"),
    ("DELETE", "/v2/admin/faults"),
    ("DELETE", "/v2/admin/faults/{fault_id}"),
)

# The v2 workloads plane (docs/api.md is checked against this too).
# Tenant-scoped, unlike /v2/admin: a tenant key addresses its own
# workloads, an admin key anyone's (with ?tenant=).
WORKLOAD_ROUTES = (
    ("POST", "/v2/workloads"),
    ("GET", "/v2/workloads"),
    ("GET", "/v2/workloads/{name}"),
    ("DELETE", "/v2/workloads/{name}"),
    ("POST", "/v2/workloads/{name}/invoke"),
)

# The observability plane (docs/api.md is checked against this as well).
OBS_ROUTES = (
    ("GET", "/metrics"),
    ("GET", "/v1/usage"),
    ("GET", "/v2/events"),
)

# Declarative dispatch: every pinned route resolves to exactly one
# ``_h_*`` handler method, and every handler is routed. The REG-ROUTE
# analyzer (python -m repro.analysis) enforces both directions against
# the tables above, so a route can no longer exist only in an if-chain
# (or a handler only in dead code). Handlers share one signature:
# ``handler(key, qs, params)`` with ``params`` the template's ``{...}``
# segments already extracted.
ROUTE_HANDLERS = {
    "GET /v1/health": "_h_health",
    "GET /metrics": "_h_metrics",
    "POST /v1/jobs": "_h_submit",
    "GET /v1/jobs": "_h_list_jobs",
    "GET /v1/jobs/{job_id}": "_h_job_status",
    "GET /v1/jobs/{job_id}/history": "_h_job_history",
    "GET /v1/jobs/{job_id}/logs": "_h_job_logs",
    "GET /v1/logs/search": "_h_search_logs",
    "POST /v1/jobs/{job_id}/halt": "_h_job_halt",
    "POST /v1/jobs/{job_id}/resume": "_h_job_resume",
    "DELETE /v1/jobs/{job_id}": "_h_job_cancel",
    "GET /v1/usage": "_h_usage",
    "GET /v2/events": "_h_events",
    "POST /v2/admin/tenants": "_h_admin_create_tenant",
    "GET /v2/admin/tenants": "_h_admin_list_tenants",
    "GET /v2/admin/tenants/{tenant}": "_h_admin_get_tenant",
    "PATCH /v2/admin/tenants/{tenant}": "_h_admin_patch_tenant",
    "DELETE /v2/admin/tenants/{tenant}": "_h_admin_delete_tenant",
    "GET /v2/admin/shards": "_h_admin_list_shards",
    "GET /v2/admin/shards/{shard_id}": "_h_admin_get_shard",
    "POST /v2/admin/shards/{shard_id}/cordon": "_h_admin_cordon",
    "POST /v2/admin/shards/{shard_id}/uncordon": "_h_admin_uncordon",
    "POST /v2/admin/shards/{shard_id}/drain": "_h_admin_drain",
    "POST /v2/admin/migrations": "_h_admin_start_migration",
    "GET /v2/admin/migrations": "_h_admin_list_migrations",
    "GET /v2/admin/migrations/{migration_id}": "_h_admin_get_migration",
    "GET /v2/admin/operator": "_h_admin_operator_status",
    "POST /v2/admin/operator/rollout": "_h_admin_start_rollout",
    "POST /v2/admin/faults": "_h_admin_install_fault",
    "GET /v2/admin/faults": "_h_admin_list_faults",
    "DELETE /v2/admin/faults": "_h_admin_clear_faults",
    "DELETE /v2/admin/faults/{fault_id}": "_h_admin_clear_fault",
    "POST /v2/workloads": "_h_workload_apply",
    "GET /v2/workloads": "_h_workload_list",
    "GET /v2/workloads/{name}": "_h_workload_get",
    "DELETE /v2/workloads/{name}": "_h_workload_delete",
    "POST /v2/workloads/{name}/invoke": "_h_workload_invoke",
}

# Probe-able endpoints: served before (and without) credentials, like
# every liveness/scrape surface should be.
UNAUTHENTICATED_ROUTES = frozenset({"GET /v1/health", "GET /metrics"})

MAX_BODY_BYTES = 1 << 20  # a manifest is small; reject anything bigger
# An oversized-but-bounded body is still drained (so the 400 envelope is
# delivered cleanly and the keep-alive connection survives); beyond this
# cap we stop reading and close the connection instead.
MAX_DRAIN_BYTES = 4 * MAX_BODY_BYTES

_MANIFEST_FIELDS = {f.name for f in dataclasses.fields(JobManifest)}


# --------------------------------------------------------------------------
# Wire codecs
# --------------------------------------------------------------------------

def manifest_from_wire(d) -> JobManifest:
    if not isinstance(d, dict):
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       "manifest must be a JSON object")
    unknown = sorted(set(d) - _MANIFEST_FIELDS)
    if unknown:
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       f"unknown manifest fields: {unknown}")
    if "name" not in d:
        raise ApiError(ErrorCode.INVALID_ARGUMENT, "manifest.name is required")
    try:
        return JobManifest(**d)
    except TypeError as e:
        raise ApiError(ErrorCode.INVALID_ARGUMENT, f"bad manifest: {e}")


def error_to_wire(err: ApiError, version: str = API_VERSION) -> dict:
    return {"api_version": version,
            "error": {"code": err.code.value, "message": err.message,
                      "details": err.details}}


def _page_to_wire(page: Page, items) -> dict:
    return {"api_version": API_VERSION, "items": items,
            "next_cursor": page.next_cursor}


def _search_rec_to_wire(rec) -> dict:
    if isinstance(rec, LogRecord):
        return dataclasses.asdict(rec)
    return dict(rec)


# --------------------------------------------------------------------------
# Server
# --------------------------------------------------------------------------

class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    # One buffered write per response + no Nagle: without these, the
    # status line / headers / body go out as separate small segments and
    # loopback latency jumps to the delayed-ACK timer (~40ms tails).
    wbufsize = -1
    disable_nagle_algorithm = True
    timeout = 30  # bound stuck reads; a stalled client can't pin a thread
    ctx: "ApiHttpServer"  # bound per-server via a dynamic subclass

    # -- plumbing ---------------------------------------------------------
    def log_message(self, *_args):  # no stderr noise from the test suite
        pass

    def _send_json(self, status: int, payload: dict,
                   extra_headers: Optional[dict] = None):
        self._drain_unread_body()  # keep-alive: never leave request bytes
        self._status_sent = status
        body = json.dumps(payload).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (extra_headers or {}).items():
            self.send_header(k, str(v))
        if self.close_connection:  # e.g. an undrainable oversized body
            self.send_header("Connection", "close")
        self.end_headers()
        self.wfile.write(body)

    def _send_error_envelope(self, err: ApiError):
        headers = {}
        if err.code == ErrorCode.RATE_LIMITED:
            headers["Retry-After"] = max(1, math.ceil(err.retry_after or 0))
        elif err.code == ErrorCode.UNAVAILABLE:
            headers["Retry-After"] = 1
        version = getattr(self, "_envelope_version", API_VERSION)
        self._send_json(STATUS_OF[err.code],
                        error_to_wire(err, version), headers)

    def _api_key(self) -> str:
        auth = self.headers.get("Authorization")
        if auth is None:
            raise ApiError(ErrorCode.UNAUTHENTICATED,
                           "missing Authorization header")
        scheme, _, key = auth.partition(" ")
        if scheme.lower() != "bearer" or not key.strip():
            raise ApiError(ErrorCode.UNAUTHENTICATED,
                           "Authorization must be 'Bearer <api-key>'")
        return key.strip()

    def _content_length(self) -> int:
        """Never trust the header: a negative value would turn
        ``rfile.read`` into read-until-EOF (thread pinned until the client
        hangs up), a non-numeric one would escape as ValueError."""
        raw = self.headers.get("Content-Length") or "0"
        try:
            n = int(raw)
        except ValueError:
            n = -1
        if n < 0:
            self.close_connection = True  # can't know where the body ends
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           f"invalid Content-Length: {raw!r}")
        return n

    def _json_body(self) -> dict:
        length = self._content_length()
        if length > MAX_BODY_BYTES:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           f"request body exceeds {MAX_BODY_BYTES} bytes")
        raw = self.rfile.read(length) if length else b""
        self._body_read = True
        if not raw:
            return {}
        try:
            body = json.loads(raw)
        except ValueError:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           "request body is not valid JSON")
        if not isinstance(body, dict):
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           "request body must be a JSON object")
        return body

    @staticmethod
    def _int_param(qs: dict, name: str) -> Optional[int]:
        raw = qs.get(name, [None])[0]
        if raw is None:
            return None
        try:
            return int(raw)
        except ValueError:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           f"{name} must be an integer, got {raw!r}")

    # -- routing ----------------------------------------------------------
    @staticmethod
    def _match_route(method: str, parts: list):
        """ROUTES/ADMIN_ROUTES/WORKLOAD_ROUTES/OBS_ROUTES are the
        authoritative tables: anything they don't name is a 404 *before*
        auth, so probing the route space needs no credential and a typo'd
        URL isn't misreported as an auth failure. Returns the matched
        ``("METHOD /template", params)`` — the label request metrics
        aggregate under, plus the extracted ``{...}`` path params — or
        None."""
        for m, template in ROUTES + ADMIN_ROUTES + WORKLOAD_ROUTES \
                + OBS_ROUTES:
            t_parts = [p for p in template.split("/") if p]
            if m == method and len(t_parts) == len(parts) and all(
                    tp.startswith("{") or tp == pp
                    for tp, pp in zip(t_parts, parts)):
                params = {tp[1:-1]: pp for tp, pp in zip(t_parts, parts)
                          if tp.startswith("{")}
                return f"{m} {template}", params
        return None

    def _route(self, method: str):
        """Declarative dispatch: match against the pinned tables, look
        the template up in ``ROUTE_HANDLERS``, authenticate (except the
        probe-able ``UNAUTHENTICATED_ROUTES``), throttle v2 planes, and
        hand off. Operator-keyed v2 traffic bypasses the per-tenant
        rate limiter — those are the operator's backpressure controls,
        not tenant traffic — but unknown/tenant keys still spend a
        token, so credential-guessing floods against /v2 are
        429-throttled before auth exactly like against v1. Workload
        routes ARE tenant traffic (including the serving data path,
        ``…/invoke``) and ride the same buckets as v1: that is the
        serving tier's per-tenant QoS."""
        split = urlparse.urlsplit(self.path)
        qs = urlparse.parse_qs(split.query)
        parts = [p for p in split.path.split("/") if p]

        if parts[:1] == ["v2"]:
            self._envelope_version = ADMIN_API_VERSION
        matched = self._match_route(method, parts)
        if matched is None:
            self._route_template = None
            raise ApiError(ErrorCode.NOT_FOUND,
                           f"no route for {method} {split.path}")
        self._route_template, params = matched
        handler = getattr(self, ROUTE_HANDLERS[self._route_template])
        if self._route_template in UNAUTHENTICATED_ROUTES:
            return handler(None, qs, params)
        key = self._api_key()
        if parts[:2] in (["v2", "admin"], ["v2", "workloads"]) \
                and self.ctx.ratelimiter is not None:
            self.ctx.ratelimiter.throttle_non_admin(key)
        return handler(key, qs, params)

    # -- v1 data plane + observability handlers ---------------------------
    def _h_health(self, key, qs, params):
        return self._health()

    def _h_metrics(self, key, qs, params):
        return self._metrics()  # scrape endpoint: no auth, like health

    def _h_submit(self, key, qs, params):
        return self._submit(self.ctx.api, key)

    def _h_list_jobs(self, key, qs, params):
        return self._list(self.ctx.api, key, qs)

    def _h_job_status(self, key, qs, params):
        api, job_id = self.ctx.api, params["job_id"]
        if self._wants_sse(qs):
            return self._stream_status(api, key, job_id, qs)
        view = api.status(key, job_id,
                          wait_ms=self._int_param(qs, "wait_ms"),
                          last_status=qs.get("last_status", [None])[0])
        return self._send_json(200, dataclasses.asdict(view))

    def _h_job_history(self, key, qs, params):
        hist = self.ctx.api.status_history(key, params["job_id"])
        return self._send_json(200, {"api_version": API_VERSION,
                                     "items": [list(h) for h in hist]})

    def _h_job_logs(self, key, qs, params):
        api, job_id = self.ctx.api, params["job_id"]
        if self._wants_sse(qs):
            return self._stream_logs(api, key, job_id, qs)
        page = api.logs(key, job_id,
                        cursor=qs.get("cursor", [None])[0],
                        limit=self._int_param(qs, "limit"),
                        wait_ms=self._int_param(qs, "wait_ms"))
        return self._send_json(200, _page_to_wire(page, page.items))

    def _h_search_logs(self, key, qs, params):
        query = qs.get("q", [None])[0]
        if query is None:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           "missing query parameter 'q'")
        page = self.ctx.api.search_logs(
            key, query,
            job_id=qs.get("job_id", [None])[0],
            cursor=qs.get("cursor", [None])[0],
            limit=self._int_param(qs, "limit"))
        return self._send_json(200, _page_to_wire(
            page, [_search_rec_to_wire(r) for r in page.items]))

    def _h_job_halt(self, key, qs, params):
        body = self._json_body()
        self.ctx.api.halt(key, params["job_id"],
                          requeue=bool(body.get("requeue", False)))
        return self._send_json(200, {"api_version": API_VERSION, "ok": True})

    def _h_job_resume(self, key, qs, params):
        self.ctx.api.resume(key, params["job_id"])
        return self._send_json(200, {"api_version": API_VERSION, "ok": True})

    def _h_job_cancel(self, key, qs, params):
        self.ctx.api.cancel(key, params["job_id"])
        return self._send_json(200, {"api_version": API_VERSION, "ok": True})

    def _h_usage(self, key, qs, params):
        out = self.ctx.api.usage(key, tenant=qs.get("tenant", [None])[0])
        return self._send_json(200, {"api_version": API_VERSION, **out})

    def _h_events(self, key, qs, params):
        api = self.ctx.api
        if self._wants_sse(qs):
            return self._stream_events(api, key, qs)
        out = api.events(key, cursor=qs.get("cursor", [None])[0],
                         limit=self._int_param(qs, "limit"),
                         kind=qs.get("kind", [None])[0],
                         wait_ms=self._int_param(qs, "wait_ms"))
        return self._send_json(200, {"api_version": ADMIN_API_VERSION, **out})

    # -- v2 admin control plane handlers ----------------------------------
    # Resource routes over the shared AdminGateway (platform.admin_api).
    def _h_admin_create_tenant(self, key, qs, params):
        admin = self.ctx.platform.admin_api
        return self._send_json(201, admin.create_tenant(key,
                                                        self._json_body()))

    def _h_admin_list_tenants(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.admin_api.list_tenants(key))

    def _h_admin_get_tenant(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.admin_api.get_tenant(key,
                                                        params["tenant"]))

    def _h_admin_patch_tenant(self, key, qs, params):
        admin = self.ctx.platform.admin_api
        return self._send_json(
            200, admin.patch_tenant(key, params["tenant"],
                                    self._json_body()))

    def _h_admin_delete_tenant(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.admin_api.delete_tenant(
                key, params["tenant"]))

    def _h_admin_list_shards(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.admin_api.list_shards(key))

    def _h_admin_get_shard(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.admin_api.get_shard(key,
                                                       params["shard_id"]))

    def _h_admin_cordon(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.admin_api.cordon_shard(
                key, params["shard_id"]))

    def _h_admin_uncordon(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.admin_api.uncordon_shard(
                key, params["shard_id"]))

    def _h_admin_drain(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.admin_api.drain_shard(
                key, params["shard_id"]))

    def _h_admin_start_migration(self, key, qs, params):
        admin = self.ctx.platform.admin_api
        return self._send_json(
            202, admin.start_migration(key, self._json_body()))

    def _h_admin_list_migrations(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.admin_api.list_migrations(key))

    def _h_admin_get_migration(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.admin_api.get_migration(
                key, params["migration_id"]))

    def _h_admin_operator_status(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.admin_api.operator_status(key))

    def _h_admin_start_rollout(self, key, qs, params):
        admin = self.ctx.platform.admin_api
        # 202: waves start on the next federation tick
        return self._send_json(
            202, admin.start_rollout(key, self._json_body()))

    def _h_admin_install_fault(self, key, qs, params):
        admin = self.ctx.platform.admin_api
        return self._send_json(
            201, admin.install_fault(key, self._json_body()))

    def _h_admin_list_faults(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.admin_api.list_faults(key))

    def _h_admin_clear_faults(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.admin_api.clear_faults(key))

    def _h_admin_clear_fault(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.admin_api.clear_faults(
                key, params["fault_id"]))

    # -- v2 workloads plane handlers --------------------------------------
    # Declarative manifests as resources over the shared WorkloadGateway
    # (platform.workloads_api).
    def _h_workload_apply(self, key, qs, params):
        body = self._json_body()
        manifest = body.get("manifest_text", body.get("manifest"))
        if manifest is None:
            raise ApiError(
                ErrorCode.INVALID_ARGUMENT,
                "body must carry 'manifest' (object) or "
                "'manifest_text' (JSON/YAML-subset string)")
        view = self.ctx.platform.workloads_api.apply(key, manifest)
        return self._send_json(201 if view["created"] else 200, view)

    def _h_workload_list(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.workloads_api.list_workloads(
                key, tenant=qs.get("tenant", [None])[0]))

    def _h_workload_get(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.workloads_api.get_workload(
                key, params["name"], tenant=qs.get("tenant", [None])[0]))

    def _h_workload_delete(self, key, qs, params):
        return self._send_json(
            200, self.ctx.platform.workloads_api.delete_workload(
                key, params["name"], tenant=qs.get("tenant", [None])[0]))

    def _h_workload_invoke(self, key, qs, params):
        body = self._json_body()
        return self._send_json(
            200, self.ctx.platform.workloads_api.invoke_workload(
                key, params["name"], payload=body.get("payload"),
                tenant=qs.get("tenant", [None])[0]))

    def _health(self):
        """Liveness, aggregated over replicas AND backend shards: the
        top-level shape (status/replicas_alive/replicas_total) is stable;
        ``shards`` details each backend so operators see a dead shard
        even while every replica is up (the tier then reports
        "degraded" — that shard's tenants are getting UNAVAILABLE)."""
        replicas = self.ctx.platform.api_replicas
        backends = self.ctx.platform.router.backends
        alive = sum(1 for r in replicas if r.alive)
        shards_alive = sum(1 for b in backends if b.alive)
        degraded = alive < len(replicas) or shards_alive < len(backends)
        status = ("down" if not alive
                  else ("degraded" if degraded else "ok"))
        # additive observability fields (the operator loop reads these to
        # spot a stalled shard without scraping /metrics): uptime_ticks =
        # scheduling rounds, events_seq = the shard's event high-water mark
        self._send_json(200 if alive else 503,
                        {"api_version": API_VERSION, "status": status,
                         "replicas_alive": alive,
                         "replicas_total": len(replicas),
                         "shards_alive": shards_alive,
                         "shards_total": len(backends),
                         "uptime_ticks": max(
                             (getattr(b.platform, "ticks", 0)
                              for b in backends), default=0),
                         "shards": [{"shard_id": b.shard_id,
                                     "status": "ok" if b.alive else "down",
                                     "cordoned": b.cordoned,
                                     # circuit-breaker verdict on the
                                     # shard: closed/half_open/open (open
                                     # = quarantined for gray failure
                                     # even though alive)
                                     "breaker": b.breaker.state,
                                     "uptime_ticks": getattr(
                                         b.platform, "ticks", 0),
                                     "events_seq": b.platform.events.seq}
                                    for b in backends]})

    def _metrics(self):
        """Prometheus text exposition — plain text, not the JSON envelope
        (scrapers speak the exposition format, nothing else)."""
        text = render_metrics(self.ctx.collect_metric_families())
        self._drain_unread_body()
        self._status_sent = 200
        body = text.encode("utf-8")
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    # -- SSE streaming (the true-streaming transport) ---------------------
    def _wants_sse(self, qs: dict) -> bool:
        """``Accept: text/event-stream`` (the standard) or ``?stream=sse``
        (curl-friendly) selects the streaming transport."""
        raw = (qs.get("stream", [None])[0] or "").lower()
        return raw in ("1", "true", "sse") \
            or SSE_CONTENT_TYPE in (self.headers.get("Accept") or "")

    def _start_sse(self):
        """Commit to a chunked event stream. Everything that can fail with
        a normal error envelope (auth, 404, rate limit, stream caps) must
        have happened already — after this point errors go out mid-stream
        as ``event: error`` frames."""
        self._drain_unread_body()
        self.send_response(200)
        self.send_header("Content-Type", SSE_CONTENT_TYPE)
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Transfer-Encoding", "chunked")
        self.send_header("Connection", "close")
        self.close_connection = True
        self.end_headers()
        self._status_sent = 200
        self._sse_started = True

    def _sse_write(self, payload: bytes):
        self.wfile.write(b"%X\r\n" % len(payload) + payload + b"\r\n")
        self.wfile.flush()

    def _sse_end(self):
        self.wfile.write(b"0\r\n\r\n")
        self.wfile.flush()

    def _sse_fail(self, err: ApiError):
        """A failure after the stream started: deliver the standard error
        envelope as an ``event: error`` frame, then close. The client
        transport re-raises it as the same ApiError."""
        try:
            version = getattr(self, "_envelope_version", API_VERSION)
            self._sse_write(format_event(
                json.dumps(error_to_wire(err, version)), event="error"))
            self._sse_end()
        except OSError:
            pass  # client already gone

    def _stream_admit(self, key: str):
        """Stream admission, BEFORE the SSE response commits (failures
        here are normal envelopes): the server-wide ``max_streams`` cap
        bounds concurrent streams, and one rate-limit token is spent at
        open — a stream then holds no in-flight slot for its lifetime,
        unlike a parked long-poll."""
        self.ctx.stream_begin()
        try:
            if self.ctx.ratelimiter is not None:
                self.ctx.ratelimiter.admit_once(key)
        except BaseException:
            self.ctx.stream_end()
            raise

    def _sse_budget(self):
        now = time.monotonic()
        return now + self.ctx.max_stream_s, now + self.ctx.heartbeat_s

    def _sse_idle(self, deadline: float, next_beat: float) -> tuple:
        """One idle step: heartbeat if due; returns ``(wait_ms, next_beat,
        expired)`` where ``wait_ms`` is the next inner long-poll budget
        (≥1 so the gateway's follow-cursor contract stays engaged)."""
        now = time.monotonic()
        if now >= deadline:
            return 0, next_beat, True
        if now >= next_beat:
            # count before the write: the client may act on the frame the
            # instant it lands, and the counter must already reflect it
            self.ctx.bump_heartbeat()
            self._sse_write(format_comment("hb"))
            next_beat = now + self.ctx.heartbeat_s
        wait_s = min(next_beat - time.monotonic(), deadline - now)
        return max(1, int(wait_s * 1000)), next_beat, False

    def _stream_logs(self, api, key: str, job_id: str, qs: dict):
        raw = qs.get("cursor", [None])[0] \
            or self.headers.get("Last-Event-ID")
        try:
            cur_off = int(raw) if raw is not None else 0
        except ValueError:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           f"malformed cursor: {raw!r}")
        self._stream_admit(key)
        try:
            # first call BEFORE the stream commits: auth/404/shard-down
            # still answer as ordinary error envelopes
            page = api.logs(key, job_id, cursor=str(cur_off), wait_ms=1)
            self._start_sse()
            deadline, next_beat = self._sse_budget()
            while True:
                for line in page.items:
                    cur_off += 1
                    # id = the resume cursor AFTER this line: exact
                    # pick-up on reconnect via Last-Event-ID
                    self._sse_write(format_event(json.dumps(line),
                                                 id=str(cur_off)))
                if page.items:
                    next_beat = time.monotonic() + self.ctx.heartbeat_s
                if page.next_cursor is None:  # terminal AND fully consumed
                    self._sse_write(format_event(
                        json.dumps({"job_id": job_id, "cursor": cur_off}),
                        event="end"))
                    self._sse_end()
                    return
                wait_ms, next_beat, expired = self._sse_idle(deadline,
                                                             next_beat)
                if expired:  # stream budget spent: clean close, client
                    self._sse_end()    # reconnects from its Last-Event-ID
                    return
                page = api.logs(key, job_id, cursor=str(cur_off),
                                wait_ms=wait_ms)
        except ApiError as e:
            if not self._sse_started:
                raise
            self._sse_fail(e)
        except OSError:
            pass  # client disconnected mid-stream
        finally:
            self.ctx.stream_end()

    def _stream_status(self, api, key: str, job_id: str, qs: dict):
        last = qs.get("last_status", [None])[0] \
            or self.headers.get("Last-Event-ID")
        self._stream_admit(key)
        try:
            view = api.status(key, job_id, wait_ms=1, last_status=last)
            self._start_sse()
            deadline, next_beat = self._sse_budget()
            while True:
                if view.status != last:
                    # id = the status itself: a reconnect resumes with
                    # Last-Event-ID as last_status and only changes stream
                    self._sse_write(format_event(
                        json.dumps(dataclasses.asdict(view)),
                        event="status", id=view.status))
                    last = view.status
                    next_beat = time.monotonic() + self.ctx.heartbeat_s
                if view.status in _TERMINAL_WIRE:
                    self._sse_write(format_event(
                        json.dumps({"job_id": job_id,
                                    "status": view.status}), event="end"))
                    self._sse_end()
                    return
                wait_ms, next_beat, expired = self._sse_idle(deadline,
                                                             next_beat)
                if expired:
                    self._sse_end()
                    return
                view = api.status(key, job_id, wait_ms=wait_ms,
                                  last_status=last)
        except ApiError as e:
            if not self._sse_started:
                raise
            self._sse_fail(e)
        except OSError:
            pass
        finally:
            self.ctx.stream_end()

    def _stream_events(self, api, key: str, qs: dict):
        cursor = qs.get("cursor", [None])[0] \
            or self.headers.get("Last-Event-ID")
        kind = qs.get("kind", [None])[0]
        self._stream_admit(key)
        try:
            out = api.events(key, cursor=cursor, kind=kind, wait_ms=1)
            # Composite (multi-shard admin) streams carry a composite id
            # per item — maintained incrementally so ANY item's id is an
            # exact resume point; single-shard ids are the plain seq.
            composite = "=" in out["next_cursor"]
            shard_curs: dict = {}
            if composite:
                shard_curs, _ = parse_composite_cursor(
                    cursor, self.ctx.platform.router, OFFSET_CURSOR_RE)
            self._start_sse()
            deadline, next_beat = self._sse_budget()
            while True:
                for item in out["items"]:
                    if composite:
                        shard_curs[item["shard"]] = str(item["seq"])
                        eid = encode_composite_cursor(shard_curs, set())
                    else:
                        eid = str(item["seq"])
                    self._sse_write(format_event(json.dumps(item), id=eid))
                if out["items"]:
                    next_beat = time.monotonic() + self.ctx.heartbeat_s
                cursor = out["next_cursor"]
                if composite:
                    shard_curs, _ = parse_composite_cursor(
                        cursor, self.ctx.platform.router, OFFSET_CURSOR_RE)
                wait_ms, next_beat, expired = self._sse_idle(deadline,
                                                             next_beat)
                if expired:  # the event stream itself never ends
                    self._sse_end()
                    return
                out = api.events(key, cursor=cursor, kind=kind,
                                 wait_ms=wait_ms)
        except ApiError as e:
            if not self._sse_started:
                raise
            self._sse_fail(e)
        except OSError:
            pass
        finally:
            self.ctx.stream_end()

    def _submit(self, api, key: str):
        body = self._json_body()
        if "manifest" not in body:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           "body must carry a 'manifest' object")
        # header wins over body: retried requests re-send the same header
        idem = self.headers.get("Idempotency-Key") \
            or body.get("idempotency_key")
        req = SubmitRequest(
            manifest=manifest_from_wire(body["manifest"]),
            idempotency_key=idem,
            api_version=body.get("api_version", API_VERSION))
        resp = api.submit(key, req)
        self._send_json(200 if resp.deduplicated else 201,
                        dataclasses.asdict(resp))

    def _list(self, api, key: str, qs: dict):
        status_raw = qs.get("status", [None])[0]
        status = None
        if status_raw is not None:
            try:
                status = JobStatus(status_raw)
            except ValueError:
                raise ApiError(ErrorCode.INVALID_ARGUMENT,
                               f"unknown status {status_raw!r}")
        kwargs = {"tenant": qs.get("tenant", [None])[0], "status": status,
                  "cursor": qs.get("cursor", [None])[0]}
        limit = self._int_param(qs, "limit")
        if limit is not None:
            kwargs["limit"] = limit
        page = api.list_jobs(key, **kwargs)
        self._send_json(200, _page_to_wire(
            page, [dataclasses.asdict(v) for v in page.items]))

    def _drain_unread_body(self):
        """A route that never called ``_json_body`` (no-body verbs, or a
        failure before the read) leaves the request body on the socket;
        consume it or the next keep-alive request desyncs. A body too big
        to be worth draining forces the connection closed instead — never
        let the leftover bytes be parsed as the next request."""
        if getattr(self, "_body_read", False):
            return
        self._body_read = True
        try:
            length = self._content_length()
        except ApiError:
            return  # connection already flagged for close
        if 0 < length <= MAX_DRAIN_BYTES:
            self.rfile.read(length)
        elif length > MAX_DRAIN_BYTES:
            self.close_connection = True

    def _handle(self, method: str):
        self._body_read = False
        self._envelope_version = API_VERSION
        self._route_template = None
        self._status_sent = None
        self._sse_started = False
        t0 = time.perf_counter()
        try:
            self._route(method)
        except ApiError as e:
            if not self._sse_started:  # mid-stream failures already went
                self._send_error_envelope(e)  # out as `event: error`
        except Exception as e:  # noqa: BLE001 — never leak a traceback page
            if not self._sse_started:
                self._send_error_envelope(
                    ApiError(ErrorCode.UNAVAILABLE, f"internal error: {e}"))
        finally:
            self.ctx.record_request(
                self._route_template or f"{method} <unrouted>",
                self._status_sent or 0, time.perf_counter() - t0)

    def do_GET(self):
        self._handle("GET")

    def do_POST(self):
        self._handle("POST")

    def do_DELETE(self):
        self._handle("DELETE")

    # Unused verbs still get the v1 404 envelope, not a bare 501 page.
    def do_PUT(self):
        self._handle("PUT")

    def do_PATCH(self):
        self._handle("PATCH")


class _QuietDisconnectServer(ThreadingHTTPServer):
    """An SSE follower hanging up mid-stream surfaces as a broken pipe
    during connection teardown (after the handler already cleaned up) —
    routine for streams, so don't let socketserver splat a traceback."""

    def handle_error(self, request, client_address):
        exc = sys.exc_info()[1]
        if isinstance(exc, (ConnectionError, TimeoutError)):
            return
        super().handle_error(request, client_address)


class ApiHttpServer:
    """Threaded stdlib HTTP server over a platform's (or a
    :class:`~repro.api.federation.Federation`'s) API tier.

    ``rate_limit`` installs a :class:`RateLimitedApi` front (per-tenant
    token buckets + bounded in-flight gate). Verb handlers lock per shard
    inside the gateway (reads shared, writes exclusive); ``lock`` is the
    all-shards write lock — hold it when ticking the simulation from
    another thread (``with server.lock: platform.tick()``). A
    ``Federation`` driver can instead call ``federation.tick()``, which
    locks one shard at a time so other shards keep serving reads.
    """

    def __init__(self, platform, host: str = "127.0.0.1", port: int = 0,
                 rate_limit: Optional[RateLimitConfig] = None,
                 per_tenant: Optional[dict] = None,
                 heartbeat_s: float = 10.0, max_stream_s: float = 3600.0,
                 max_streams: int = 256):
        self.platform = platform
        self.lock = AllShardsLock(platform.router)
        self.ratelimiter = None
        if rate_limit is not None:
            self.ratelimiter = RateLimitedApi(platform.api, platform.auth,
                                              rate_limit, per_tenant)
        self.api = self.ratelimiter or platform.api
        # v2 admin plane: wire the rate limiter in so tenant PATCHes with
        # rate/burst apply live to the token buckets
        admin = getattr(platform, "admin", None)
        if admin is not None and self.ratelimiter is not None:
            admin.attach_ratelimiter(self.ratelimiter)
        # observability: throttles become rate_limited platform events
        if self.ratelimiter is not None:
            self.ratelimiter.attach_observability(platform.router)
        # -- SSE stream plane: cadence of `: hb` heartbeats on an idle
        # stream, per-stream wall budget (a spent stream closes cleanly
        # and the client resumes from its Last-Event-ID), and a server-
        # wide concurrency cap (streams hold no rate-limiter in-flight
        # slot, so they need their own bound).
        self.heartbeat_s = heartbeat_s
        self.max_stream_s = max_stream_s
        self.max_streams = max_streams
        self._metrics_lock = threading.Lock()
        self.streams_opened = 0
        self.streams_active = 0
        self.heartbeats_sent = 0
        # per-route request metrics, fed by every handled request
        self.route_requests: dict = {}   # (template, status) -> count
        self.route_latency: dict = {}    # template -> Histogram
        handler = type("BoundHandler", (_Handler,), {"ctx": self})
        self._httpd = _QuietDisconnectServer((host, port), handler)
        self._thread: Optional[threading.Thread] = None

    # -- observability plumbing (handler callbacks) -----------------------
    def record_request(self, template: str, status: int, seconds: float):
        with self._metrics_lock:
            k = (template, status)
            self.route_requests[k] = self.route_requests.get(k, 0) + 1
            h = self.route_latency.get(template)
            if h is None:
                h = self.route_latency[template] = Histogram()
        h.observe(seconds)

    def stream_begin(self):
        with self._metrics_lock:
            if self.streams_active >= self.max_streams:
                raise ApiError(
                    ErrorCode.RATE_LIMITED,
                    f"server at max concurrent streams ({self.max_streams})",
                    retry_after=1)
            self.streams_active += 1
            self.streams_opened += 1

    def stream_end(self):
        with self._metrics_lock:
            self.streams_active -= 1

    def bump_heartbeat(self):
        with self._metrics_lock:
            self.heartbeats_sent += 1

    def collect_metric_families(self) -> list:
        """Everything /metrics serves, scraped live. Platform values are
        read WITHOUT shard locks: scrapes are monitoring reads and must
        stay cheap under load — a torn gauge is tolerable, a scrape that
        queues behind a migration cutover is not. Family names are pinned
        in ``repro.obs.METRIC_NAMES``."""
        backends = self.platform.router.backends
        shard_up, chips, occ, qdepth = [], [], [], []
        wal, ev_seq, ev_drop, uptime = [], [], [], []
        brk, ddl = [], []
        snaps = []
        for b in backends:
            lbl = {"shard": b.shard_id}
            p = b.platform
            shard_up.append((lbl, 1 if b.alive else 0))
            brk.append((lbl, BREAKER_STATE_VALUE[b.breaker.state]))
            ddl.append((lbl, b.breaker.deadline_exceeded_total))
            chips.append((lbl, p.cluster.total_chips))
            occ.append((lbl, p.cluster.used_chips))
            qdepth.append((lbl, len(getattr(p.scheduler, "queue", ()))))
            wal.append((lbl, getattr(p.meta, "flushes", 0)))
            ev_seq.append((lbl, p.events.seq))
            ev_drop.append((lbl, p.events.dropped_total))
            uptime.append((lbl, getattr(p, "ticks", 0)))
            snaps.append(p.meter.snapshot())
        usage = UsageMeter.merge(snaps)
        migr = Counter()
        admin = getattr(self.platform, "admin", None)
        if admin is not None:
            for m in admin.migrations.values():
                migr[m.phase.value] += 1
        if self.ratelimiter is not None:
            limited = dict(self.ratelimiter.throttled_by_tenant)
        else:
            limited = {t: row["throttled_429s"] for t, row in usage.items()
                       if row["throttled_429s"]}
        with self._metrics_lock:
            reqs = dict(self.route_requests)
            lat = dict(self.route_latency)
            streams = (self.streams_active, self.streams_opened,
                       self.heartbeats_sent)
        return [
            ("ffdl_uptime_ticks", "gauge",
             "Scheduling rounds completed per shard", uptime),
            ("ffdl_shard_up", "gauge",
             "1 if the shard backend is alive", shard_up),
            ("ffdl_shard_chips_total", "gauge",
             "Total accelerator chips per shard", chips),
            ("ffdl_shard_occupancy_chips", "gauge",
             "Chips currently reserved by placed gangs", occ),
            ("ffdl_scheduler_queue_depth", "gauge",
             "Gangs waiting for placement", qdepth),
            ("ffdl_wal_flushes_total", "counter",
             "Metastore WAL flushes (group commit)", wal),
            ("ffdl_breaker_state", "gauge",
             "Per-shard circuit breaker (0=closed 1=half_open 2=open)",
             brk),
            ("ffdl_deadline_exceeded_total", "counter",
             "Verb/tick deadline overruns recorded against the shard",
             ddl),
            ("ffdl_events_seq", "gauge",
             "Event-bus high-water sequence number", ev_seq),
            ("ffdl_events_dropped_total", "counter",
             "Events dropped by retention", ev_drop),
            ("ffdl_migrations", "gauge", "Migrations by phase",
             [({"phase": ph}, n) for ph, n in sorted(migr.items())]),
            ("ffdl_http_requests_total", "counter",
             "HTTP requests by route and status",
             [({"route": t, "status": str(s)}, n)
              for (t, s), n in sorted(reqs.items())]),
            ("ffdl_http_request_latency_seconds", "histogram",
             "HTTP request latency by route",
             [({"route": t}, h) for t, h in sorted(lat.items())]),
            ("ffdl_http_streams_active", "gauge",
             "SSE streams currently open", [(None, streams[0])]),
            ("ffdl_http_streams_opened_total", "counter",
             "SSE streams opened since start", [(None, streams[1])]),
            ("ffdl_http_heartbeats_total", "counter",
             "SSE heartbeat comments sent", [(None, streams[2])]),
            ("ffdl_rate_limited_total", "counter",
             "Requests answered 429 per tenant",
             [({"tenant": t}, n) for t, n in sorted(limited.items())]),
            ("ffdl_tenant_chip_seconds_total", "counter",
             "Accrued chip-seconds per tenant",
             [({"tenant": t}, row["chip_seconds"])
              for t, row in sorted(usage.items())]),
            ("ffdl_tenant_jobs_total", "counter",
             "Jobs by tenant and outcome",
             [({"tenant": t, "outcome": oc}, row[f"jobs_{oc}"])
              for t, row in sorted(usage.items())
              for oc in ("submitted", "completed", "failed")]),
            ("ffdl_tenant_log_bytes_total", "counter",
             "Log bytes indexed per tenant",
             [({"tenant": t}, row["log_bytes"])
              for t, row in sorted(usage.items())]),
            ("ffdl_tenant_serving_replica_seconds_total", "counter",
             "Ready inference-replica seconds per tenant (workloads "
             "serving tier)",
             [({"tenant": t}, row["serving_replica_seconds"])
              for t, row in sorted(usage.items())]),
        ]

    @property
    def port(self) -> int:
        return self._httpd.server_port

    @property
    def base_url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}"

    def start(self) -> "ApiHttpServer":
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        kwargs={"poll_interval": 0.05},
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __enter__(self) -> "ApiHttpServer":
        return self.start()

    def __exit__(self, *exc):
        self.stop()


# --------------------------------------------------------------------------
# Client transport
# --------------------------------------------------------------------------

def _error_from_payload(status: int, payload) -> ApiError:
    """Decode a wire error envelope back into an ApiError (shared by the
    request path and the SSE stream path)."""
    try:
        wire = json.loads(payload)["error"]
        if not isinstance(wire, dict) or "code" not in wire:
            wire = None
    except (ValueError, KeyError, TypeError):
        wire = None
    if wire is None:
        err = ApiError(ErrorCode.UNAVAILABLE,
                       f"HTTP {status}: undecodable error body")
    else:
        try:
            code = ErrorCode(wire["code"])
            extra = {}
        except ValueError:
            # a newer server's code this client doesn't know: keep the raw
            # string and fall back to a NON-retryable code (UNAVAILABLE
            # would invite blind re-execution)
            code = ErrorCode.FAILED_PRECONDITION
            extra = {"wire_code": wire["code"]}
        err = ApiError(code, wire.get("message", ""),
                       **{**wire.get("details", {}), **extra})
    err.details.setdefault("http_status", status)
    return err


class HttpTransport:
    """v1 verb surface over the wire — drop-in for the in-process
    ``LoadBalancer`` anywhere a transport is expected (``ApiClient``,
    benchmarks, the ``ffdl`` CLI).

    Connections are persistent (HTTP/1.1 keep-alive) and thread-local, so
    concurrent tenant clients measure the API tier — not per-request TCP
    and thread churn. A connection the server dropped is retried once on a
    fresh socket before surfacing UNAVAILABLE.
    """

    def __init__(self, base_url: str, timeout: float = 10.0):
        self.base_url = base_url.rstrip("/")
        split = urlparse.urlsplit(self.base_url)
        if split.scheme != "http" or split.hostname is None:
            raise ValueError(f"expected an http:// URL, got {base_url!r}")
        self._host = split.hostname
        self._port = split.port or 80
        self.timeout = timeout
        self._local = threading.local()
        # optional fault-plane attachment: tests/benchmarks point this at
        # a FaultPlane to exercise the wire path's own interposition
        # points (``http.send`` / ``http.recv``) — e.g. a flaky or slow
        # network between client and API tier
        self.faults = None
        self.fault_key: Optional[str] = None
        # transport telemetry (benchmarks/observability.py compares these:
        # one SSE stream replaces a whole long-poll request train)
        self._counters_lock = threading.Lock()
        self.requests_sent = 0
        self.streams_opened = 0

    # -- low-level --------------------------------------------------------
    def _drop_conn(self):
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            conn.close()
        self._local.conn = None

    def _conn(self) -> http.client.HTTPConnection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = http.client.HTTPConnection(self._host, self._port,
                                              timeout=self.timeout)
            conn.connect()
            conn.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._local.conn = conn
        return conn

    def _request(self, method: str, path: str, api_key: Optional[str] = None,
                 body: Optional[dict] = None, query: Optional[dict] = None,
                 headers: Optional[dict] = None,
                 allow_error_status: bool = False,
                 timeout_floor: Optional[float] = None) -> tuple[int, dict]:
        with self._counters_lock:
            self.requests_sent += 1
        if query:
            qs = {k: v for k, v in query.items() if v is not None}
            if qs:
                path += "?" + urlparse.urlencode(qs)
        data = json.dumps(body).encode() if body is not None else None
        hdrs = {"Content-Type": "application/json"}
        if api_key is not None:
            hdrs["Authorization"] = f"Bearer {api_key}"
        for k, v in (headers or {}).items():
            if v is not None:
                hdrs[k] = v

        # Retry policy: a reused keep-alive socket may have been closed by
        # the server since the last call; such failures are retried once on
        # a fresh socket — but ONLY when the request cannot have executed
        # (send-phase failure) or the verb is idempotent (GET). A write
        # that succeeded followed by a read failure on a mutating verb is
        # surfaced as UNAVAILABLE instead of silently re-executing it.
        status = payload = None
        for attempt in (0, 1):
            reused = getattr(self._local, "conn", None) is not None
            conn = self._conn()
            # A long-poll (logs wait_ms) may legitimately park server-side
            # longer than the transport's socket timeout: raise this
            # request's read timeout to cover the park, restore after.
            raised_timeout = False
            if timeout_floor is not None and conn.sock is not None \
                    and timeout_floor > self.timeout:
                conn.sock.settimeout(timeout_floor)
                raised_timeout = True
            try:
                if self.faults is not None:
                    self.faults.on("http.send", key=self.fault_key,
                                   exc=lambda m: OSError(m))
                conn.request(method, path, body=data, headers=hdrs)
            except (http.client.HTTPException, OSError) as e:
                self._drop_conn()
                if reused and attempt == 0:
                    continue  # stale keep-alive socket; nothing was served
                raise ApiError(ErrorCode.UNAVAILABLE,
                               f"cannot reach API server: {e}") from None
            try:
                if self.faults is not None:
                    self.faults.on("http.recv", key=self.fault_key,
                                   exc=lambda m: OSError(m))
                resp = conn.getresponse()
                status, payload = resp.status, resp.read()
                if raised_timeout:  # keep-alive socket back to the default
                    conn.sock.settimeout(self.timeout)
                break
            except TimeoutError:
                # socket read timeout: the server (or an injected hang) is
                # holding the response past the transport's budget — the
                # client-side deadline. NOT retried here: the request may
                # be executing server-side; idempotent-verb retry is the
                # ApiClient RetryPolicy's call.
                self._drop_conn()
                budget = timeout_floor if raised_timeout else self.timeout
                raise ApiError(
                    ErrorCode.DEADLINE_EXCEEDED,
                    f"no response within the transport deadline "
                    f"({budget:.1f}s)") from None
            except (http.client.HTTPException, OSError) as e:
                self._drop_conn()
                if reused and attempt == 0 and method == "GET":
                    continue
                raise ApiError(
                    ErrorCode.UNAVAILABLE,
                    f"connection lost awaiting response: {e}") from None

        if status >= 400 and not allow_error_status:
            raise _error_from_payload(status, payload)
        try:
            return status, json.loads(payload or b"{}")
        except ValueError as e:
            raise ApiError(ErrorCode.UNAVAILABLE,
                           f"undecodable response body: {e}") from None

    def health(self) -> dict:
        """Health is special: a fully-down tier answers 503 with a valid
        health body (replica counts included), not an error envelope."""
        try:
            return self._request("GET", "/v1/health",
                                 allow_error_status=True)[1]
        except ApiError as e:
            return {"status": "down", "error": e.message,
                    **{k: v for k, v in e.details.items()}}

    # -- full v1 surface --------------------------------------------------
    def submit(self, api_key, req: SubmitRequest) -> SubmitResponse:
        body = {"manifest": dataclasses.asdict(req.manifest),
                "api_version": req.api_version}
        _, d = self._request("POST", "/v1/jobs", api_key, body=body,
                             headers={"Idempotency-Key": req.idempotency_key})
        return SubmitResponse(**d)

    def status(self, api_key, job_id, wait_ms=None,
               last_status=None) -> JobView:
        floor = None if not wait_ms else wait_ms / 1000.0 + 5.0
        _, d = self._request("GET", f"/v1/jobs/{job_id}", api_key,
                             query={"wait_ms": wait_ms,
                                    "last_status": last_status},
                             timeout_floor=floor)
        return JobView(**d)

    def status_history(self, api_key, job_id) -> list:
        _, d = self._request("GET", f"/v1/jobs/{job_id}/history", api_key)
        return [tuple(h) for h in d["items"]]

    def list_jobs(self, api_key, tenant=None, status=None, cursor=None,
                  limit=None) -> Page:
        _, d = self._request(
            "GET", "/v1/jobs", api_key,
            query={"tenant": tenant,
                   "status": getattr(status, "value", status),
                   "cursor": cursor, "limit": limit})
        return Page(items=[JobView(**v) for v in d["items"]],
                    next_cursor=d["next_cursor"])

    def logs(self, api_key, job_id, cursor=None, limit=None,
             wait_ms=None) -> Page:
        floor = None if not wait_ms else wait_ms / 1000.0 + 5.0
        _, d = self._request("GET", f"/v1/jobs/{job_id}/logs", api_key,
                             query={"cursor": cursor, "limit": limit,
                                    "wait_ms": wait_ms},
                             timeout_floor=floor)
        return Page(items=d["items"], next_cursor=d["next_cursor"])

    def search_logs(self, api_key, query, job_id=None, cursor=None,
                    limit=None) -> Page:
        _, d = self._request("GET", "/v1/logs/search", api_key,
                             query={"q": query, "job_id": job_id,
                                    "cursor": cursor, "limit": limit})
        return Page(items=[LogRecord(**r) for r in d["items"]],
                    next_cursor=d["next_cursor"])

    def halt(self, api_key, job_id, requeue: bool = False):
        self._request("POST", f"/v1/jobs/{job_id}/halt", api_key,
                      body={"requeue": requeue})

    def resume(self, api_key, job_id):
        self._request("POST", f"/v1/jobs/{job_id}/resume", api_key, body={})

    def cancel(self, api_key, job_id):
        self._request("DELETE", f"/v1/jobs/{job_id}", api_key)

    # -- observability plane ----------------------------------------------
    def usage(self, api_key, tenant=None) -> dict:
        _, d = self._request("GET", "/v1/usage", api_key,
                             query={"tenant": tenant})
        return {"items": d["items"]}

    def events(self, api_key, cursor=None, limit=None, kind=None,
               wait_ms=None) -> dict:
        floor = None if not wait_ms else wait_ms / 1000.0 + 5.0
        _, d = self._request("GET", "/v2/events", api_key,
                             query={"cursor": cursor, "limit": limit,
                                    "kind": kind, "wait_ms": wait_ms},
                             timeout_floor=floor)
        return {"items": d["items"], "next_cursor": d["next_cursor"],
                "missed": d.get("missed", 0)}

    # -- SSE streams ------------------------------------------------------
    def _stream(self, path: str, api_key: str,
                query: Optional[dict] = None,
                last_event_id: Optional[str] = None):
        """One SSE connection, yielded as parsed :class:`SseMessage`
        frames. Uses a dedicated (non-pooled) connection: the stream owns
        its socket for its whole life. Server-side error statuses raise
        the decoded ApiError; a route that answers with a non-SSE content
        type raises FAILED_PRECONDITION with ``sse_unsupported`` so
        callers can fall back to long-poll permanently."""
        with self._counters_lock:
            self.streams_opened += 1
        if query:
            qs = {k: v for k, v in query.items() if v is not None}
            if qs:
                path += "?" + urlparse.urlencode(qs)
        hdrs = {"Authorization": f"Bearer {api_key}",
                "Accept": SSE_CONTENT_TYPE}
        if last_event_id is not None:
            hdrs["Last-Event-ID"] = str(last_event_id)
        # read timeout must comfortably exceed the server's heartbeat
        # cadence — a silent stream is only dead if heartbeats stop too
        conn = http.client.HTTPConnection(
            self._host, self._port, timeout=max(self.timeout, 60.0))
        try:
            try:
                conn.connect()
                conn.sock.setsockopt(socket.IPPROTO_TCP,
                                     socket.TCP_NODELAY, 1)
                conn.request("GET", path, headers=hdrs)
                resp = conn.getresponse()
            except (http.client.HTTPException, OSError) as e:
                raise ApiError(ErrorCode.UNAVAILABLE,
                               f"cannot open stream: {e}") from None
            if resp.status >= 400:
                raise _error_from_payload(resp.status, resp.read())
            ctype = resp.getheader("Content-Type") or ""
            if SSE_CONTENT_TYPE not in ctype:
                raise ApiError(ErrorCode.FAILED_PRECONDITION,
                               f"server answered {ctype!r}, not SSE",
                               sse_unsupported=True)
            try:
                # http.client decodes chunked transfer transparently
                yield from iter_sse(resp)
            except (http.client.HTTPException, OSError) as e:
                raise ApiError(ErrorCode.UNAVAILABLE,
                               f"stream lost: {e}") from None
        finally:
            conn.close()

    def stream_logs(self, api_key, job_id, cursor=None):
        return self._stream(f"/v1/jobs/{job_id}/logs", api_key,
                            query={"stream": "sse"}, last_event_id=cursor)

    def stream_status(self, api_key, job_id, last_status=None):
        return self._stream(f"/v1/jobs/{job_id}", api_key,
                            query={"stream": "sse"},
                            last_event_id=last_status)

    def stream_events(self, api_key, cursor=None, kind=None):
        return self._stream("/v2/events", api_key,
                            query={"stream": "sse", "kind": kind},
                            last_event_id=cursor)

    # -- v2 admin control plane -------------------------------------------
    # Same method names/signatures as the in-process AdminGateway, so
    # AdminClient (repro.api.client) works over either transport.
    def create_tenant(self, api_key, body: dict) -> dict:
        return self._request("POST", "/v2/admin/tenants", api_key,
                             body=body)[1]

    def get_tenant(self, api_key, name: str) -> dict:
        return self._request("GET", f"/v2/admin/tenants/{name}", api_key)[1]

    def list_tenants(self, api_key) -> dict:
        return self._request("GET", "/v2/admin/tenants", api_key)[1]

    def patch_tenant(self, api_key, name: str, patch: dict) -> dict:
        return self._request("PATCH", f"/v2/admin/tenants/{name}", api_key,
                             body=patch)[1]

    def delete_tenant(self, api_key, name: str) -> dict:
        return self._request("DELETE", f"/v2/admin/tenants/{name}",
                             api_key)[1]

    def list_shards(self, api_key) -> dict:
        return self._request("GET", "/v2/admin/shards", api_key)[1]

    def get_shard(self, api_key, shard_id: str) -> dict:
        return self._request("GET", f"/v2/admin/shards/{shard_id}",
                             api_key)[1]

    def cordon_shard(self, api_key, shard_id: str) -> dict:
        return self._request("POST", f"/v2/admin/shards/{shard_id}/cordon",
                             api_key, body={})[1]

    def uncordon_shard(self, api_key, shard_id: str) -> dict:
        return self._request(
            "POST", f"/v2/admin/shards/{shard_id}/uncordon", api_key,
            body={})[1]

    def drain_shard(self, api_key, shard_id: str) -> dict:
        return self._request("POST", f"/v2/admin/shards/{shard_id}/drain",
                             api_key, body={})[1]

    def start_migration(self, api_key, body: dict) -> dict:
        return self._request("POST", "/v2/admin/migrations", api_key,
                             body=body)[1]

    def get_migration(self, api_key, migration_id: str) -> dict:
        return self._request("GET", f"/v2/admin/migrations/{migration_id}",
                             api_key)[1]

    def list_migrations(self, api_key) -> dict:
        return self._request("GET", "/v2/admin/migrations", api_key)[1]

    def operator_status(self, api_key) -> dict:
        return self._request("GET", "/v2/admin/operator", api_key)[1]

    def start_rollout(self, api_key, body: dict) -> dict:
        return self._request("POST", "/v2/admin/operator/rollout", api_key,
                             body=body)[1]

    def install_fault(self, api_key, body: dict) -> dict:
        return self._request("POST", "/v2/admin/faults", api_key,
                             body=body)[1]

    def list_faults(self, api_key) -> dict:
        return self._request("GET", "/v2/admin/faults", api_key)[1]

    def clear_faults(self, api_key, fault_id: Optional[str] = None) -> dict:
        path = ("/v2/admin/faults" if fault_id is None
                else f"/v2/admin/faults/{fault_id}")
        return self._request("DELETE", path, api_key)[1]

    # -- v2 workloads plane -----------------------------------------------
    # Same method names/signatures as the in-process WorkloadGateway, so
    # WorkloadClient (repro.api.client) works over either transport.
    def apply(self, api_key, manifest) -> dict:
        body = ({"manifest_text": manifest} if isinstance(manifest, str)
                else {"manifest": manifest})
        return self._request("POST", "/v2/workloads", api_key,
                             body=body)[1]

    def get_workload(self, api_key, name: str, tenant=None) -> dict:
        return self._request("GET", f"/v2/workloads/{name}", api_key,
                             query={"tenant": tenant})[1]

    def list_workloads(self, api_key, tenant=None) -> dict:
        return self._request("GET", "/v2/workloads", api_key,
                             query={"tenant": tenant})[1]

    def delete_workload(self, api_key, name: str, tenant=None) -> dict:
        return self._request("DELETE", f"/v2/workloads/{name}", api_key,
                             query={"tenant": tenant})[1]

    def invoke_workload(self, api_key, name: str, payload=None,
                        tenant=None) -> dict:
        return self._request("POST", f"/v2/workloads/{name}/invoke",
                             api_key, query={"tenant": tenant},
                             body={"payload": payload})[1]
