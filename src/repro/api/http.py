"""JSON-over-HTTP transport for the v1 API tier (FfDL §3.2).

FfDL's user-facing surface is a replicated REST tier behind a load
balancer; this module serves our v1 envelope contract over a real wire
using only the stdlib (``http.server``, threaded — no new dependencies).
The full contract is written down in ``docs/api.md`` and pinned by
``tests/test_docs_api.py``.

Server side
    :class:`ApiHttpServer` mounts the routes below over a platform's (or
    :class:`~repro.api.federation.Federation`'s) ``LoadBalancer`` — so
    HTTP composes with replica crash-masking — with an optional
    :class:`~repro.api.ratelimit.RateLimitedApi` front (per-tenant token
    buckets + bounded in-flight gate → 429 with ``Retry-After``).
    Locking is per-shard inside the gateway (reads share a shard's RW
    lock, writes take it exclusively; see ``repro.api.backend``), so a
    read on one shard never queues behind a submit — or a simulation
    tick — on another. ``server.lock`` remains for code that ticks the
    sim from another thread (``with server.lock: platform.tick()``): it
    takes every shard's write lock in shard order. Throttled calls are
    rejected *before* any lock, which is what keeps a flooding tenant
    cheap.

Client side
    :class:`HttpTransport` speaks the wire protocol and re-raises wire
    errors as ``ApiError`` with the original stable code — the same
    contract as the in-process transports, so
    ``ApiClient(HttpTransport(url), key)`` behaves like
    ``ApiClient(platform.api, key)``.

Routes (``{job_id}`` is a path segment)::

    GET    /v1/health                   liveness + replica counts (no auth)
    POST   /v1/jobs                     submit        (201; 200 when deduped)
    GET    /v1/jobs                     list_jobs     (tenant,status,cursor,limit)
    GET    /v1/jobs/{job_id}            status → JobView (wait_ms,last_status
                                        = watch long-poll)
    GET    /v1/jobs/{job_id}/history    status_history
    GET    /v1/jobs/{job_id}/logs       logs          (cursor,limit)
    GET    /v1/logs/search              search_logs   (q,job_id,cursor,limit)
    POST   /v1/jobs/{job_id}/halt       halt          (body: {"requeue": bool})
    POST   /v1/jobs/{job_id}/resume     resume
    DELETE /v1/jobs/{job_id}            cancel

The **v2 admin control plane** (``repro.api.admin``; requires an operator
key carrying the ``admin`` scope, envelopes stamped ``"v2"``)::

    POST   /v2/admin/tenants                        create tenant
    GET    /v2/admin/tenants                        list tenants
    GET    /v2/admin/tenants/{tenant}               get tenant
    PATCH  /v2/admin/tenants/{tenant}               patch quota/tier/rate
    DELETE /v2/admin/tenants/{tenant}               delete tenant
    GET    /v2/admin/shards                         list shards + occupancy
    GET    /v2/admin/shards/{shard_id}              get shard
    POST   /v2/admin/shards/{shard_id}/cordon       cordon
    POST   /v2/admin/shards/{shard_id}/uncordon     uncordon
    POST   /v2/admin/shards/{shard_id}/drain        migrate all off + cordon
    POST   /v2/admin/migrations                     start tenant→shard move
    GET    /v2/admin/migrations                     list migrations
    GET    /v2/admin/migrations/{migration_id}      get migration phase

Operator-keyed admin calls bypass the per-tenant rate limiter (they are
the operator's backpressure controls, not tenant traffic); unknown or
tenant keys probing /v2 still spend tokens from their usual bucket. The
error envelope and ``STATUS_OF`` mapping are shared with v1.

Headers: ``Authorization: Bearer <key>`` on every authenticated route;
``Idempotency-Key`` on submit; ``Retry-After`` on 429/503 responses.
"""

from __future__ import annotations

import dataclasses
import http.client
import json
import math
import socket
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib import parse as urlparse

from repro.api.backend import AllShardsLock
from repro.api.ratelimit import RateLimitConfig, RateLimitedApi
from repro.api.types import (
    ADMIN_API_VERSION,
    API_VERSION,
    ApiError,
    ErrorCode,
    JobView,
    Page,
    SubmitRequest,
    SubmitResponse,
)
from repro.core.helpers import LogRecord
from repro.core.types import JobManifest, JobStatus

# Stable ErrorCode → HTTP status mapping. docs/api.md documents exactly
# this table and tests/test_docs_api.py fails if they ever diverge (or if
# a new code is added without a mapping).
STATUS_OF = {
    ErrorCode.UNAUTHENTICATED: 401,
    ErrorCode.FORBIDDEN: 403,
    ErrorCode.NOT_FOUND: 404,
    ErrorCode.INVALID_ARGUMENT: 400,
    ErrorCode.QUOTA_EXCEEDED: 429,
    ErrorCode.FAILED_PRECONDITION: 409,
    ErrorCode.CONFLICT: 409,
    ErrorCode.UNAVAILABLE: 503,
    ErrorCode.UNSUPPORTED_VERSION: 400,
    ErrorCode.RATE_LIMITED: 429,
}

# Canonical route table (docs/api.md is checked against this).
ROUTES = (
    ("GET", "/v1/health"),
    ("POST", "/v1/jobs"),
    ("GET", "/v1/jobs"),
    ("GET", "/v1/jobs/{job_id}"),
    ("GET", "/v1/jobs/{job_id}/history"),
    ("GET", "/v1/jobs/{job_id}/logs"),
    ("GET", "/v1/logs/search"),
    ("POST", "/v1/jobs/{job_id}/halt"),
    ("POST", "/v1/jobs/{job_id}/resume"),
    ("DELETE", "/v1/jobs/{job_id}"),
)

# The v2 admin control plane (docs/api.md is checked against this too).
ADMIN_ROUTES = (
    ("POST", "/v2/admin/tenants"),
    ("GET", "/v2/admin/tenants"),
    ("GET", "/v2/admin/tenants/{tenant}"),
    ("PATCH", "/v2/admin/tenants/{tenant}"),
    ("DELETE", "/v2/admin/tenants/{tenant}"),
    ("GET", "/v2/admin/shards"),
    ("GET", "/v2/admin/shards/{shard_id}"),
    ("POST", "/v2/admin/shards/{shard_id}/cordon"),
    ("POST", "/v2/admin/shards/{shard_id}/uncordon"),
    ("POST", "/v2/admin/shards/{shard_id}/drain"),
    ("POST", "/v2/admin/migrations"),
    ("GET", "/v2/admin/migrations"),
    ("GET", "/v2/admin/migrations/{migration_id}"),
)

MAX_BODY_BYTES = 1 << 20  # a manifest is small; reject anything bigger
# An oversized-but-bounded body is still drained (so the 400 envelope is
# delivered cleanly and the keep-alive connection survives); beyond this
# cap we stop reading and close the connection instead.
MAX_DRAIN_BYTES = 4 * MAX_BODY_BYTES

_MANIFEST_FIELDS = {f.name for f in dataclasses.fields(JobManifest)}


# --------------------------------------------------------------------------
# Wire codecs
# --------------------------------------------------------------------------

def manifest_from_wire(d) -> JobManifest:
    if not isinstance(d, dict):
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       "manifest must be a JSON object")
    unknown = sorted(set(d) - _MANIFEST_FIELDS)
    if unknown:
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       f"unknown manifest fields: {unknown}")
    if "name" not in d:
        raise ApiError(ErrorCode.INVALID_ARGUMENT, "manifest.name is required")
    try:
        return JobManifest(**d)
    except TypeError as e:
        raise ApiError(ErrorCode.INVALID_ARGUMENT, f"bad manifest: {e}")


def error_to_wire(err: ApiError, version: str = API_VERSION) -> dict:
    return {"api_version": version,
            "error": {"code": err.code.value, "message": err.message,
                      "details": err.details}}


def _page_to_wire(page: Page, items) -> dict:
    return {"api_version": API_VERSION, "items": items,
            "next_cursor": page.next_cursor}


def _search_rec_to_wire(rec) -> dict:
    if isinstance(rec, LogRecord):
        return dataclasses.asdict(rec)
    return dict(rec)


# --------------------------------------------------------------------------
# Server
# --------------------------------------------------------------------------

class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    # One buffered write per response + no Nagle: without these, the
    # status line / headers / body go out as separate small segments and
    # loopback latency jumps to the delayed-ACK timer (~40ms tails).
    wbufsize = -1
    disable_nagle_algorithm = True
    timeout = 30  # bound stuck reads; a stalled client can't pin a thread
    ctx: "ApiHttpServer"  # bound per-server via a dynamic subclass

    # -- plumbing ---------------------------------------------------------
    def log_message(self, *_args):  # no stderr noise from the test suite
        pass

    def _send_json(self, status: int, payload: dict,
                   extra_headers: Optional[dict] = None):
        self._drain_unread_body()  # keep-alive: never leave request bytes
        body = json.dumps(payload).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (extra_headers or {}).items():
            self.send_header(k, str(v))
        if self.close_connection:  # e.g. an undrainable oversized body
            self.send_header("Connection", "close")
        self.end_headers()
        self.wfile.write(body)

    def _send_error_envelope(self, err: ApiError):
        headers = {}
        if err.code == ErrorCode.RATE_LIMITED:
            headers["Retry-After"] = max(1, math.ceil(err.retry_after or 0))
        elif err.code == ErrorCode.UNAVAILABLE:
            headers["Retry-After"] = 1
        version = getattr(self, "_envelope_version", API_VERSION)
        self._send_json(STATUS_OF[err.code],
                        error_to_wire(err, version), headers)

    def _api_key(self) -> str:
        auth = self.headers.get("Authorization")
        if auth is None:
            raise ApiError(ErrorCode.UNAUTHENTICATED,
                           "missing Authorization header")
        scheme, _, key = auth.partition(" ")
        if scheme.lower() != "bearer" or not key.strip():
            raise ApiError(ErrorCode.UNAUTHENTICATED,
                           "Authorization must be 'Bearer <api-key>'")
        return key.strip()

    def _content_length(self) -> int:
        """Never trust the header: a negative value would turn
        ``rfile.read`` into read-until-EOF (thread pinned until the client
        hangs up), a non-numeric one would escape as ValueError."""
        raw = self.headers.get("Content-Length") or "0"
        try:
            n = int(raw)
        except ValueError:
            n = -1
        if n < 0:
            self.close_connection = True  # can't know where the body ends
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           f"invalid Content-Length: {raw!r}")
        return n

    def _json_body(self) -> dict:
        length = self._content_length()
        if length > MAX_BODY_BYTES:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           f"request body exceeds {MAX_BODY_BYTES} bytes")
        raw = self.rfile.read(length) if length else b""
        self._body_read = True
        if not raw:
            return {}
        try:
            body = json.loads(raw)
        except ValueError:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           "request body is not valid JSON")
        if not isinstance(body, dict):
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           "request body must be a JSON object")
        return body

    @staticmethod
    def _int_param(qs: dict, name: str) -> Optional[int]:
        raw = qs.get(name, [None])[0]
        if raw is None:
            return None
        try:
            return int(raw)
        except ValueError:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           f"{name} must be an integer, got {raw!r}")

    # -- routing ----------------------------------------------------------
    @staticmethod
    def _known_route(method: str, parts: list) -> bool:
        """ROUTES/ADMIN_ROUTES are the authoritative tables: anything they
        don't name is a 404 *before* auth, so probing the route space needs
        no credential and a typo'd URL isn't misreported as an auth
        failure."""
        for m, template in ROUTES + ADMIN_ROUTES:
            t_parts = [p for p in template.split("/") if p]
            if m == method and len(t_parts) == len(parts) and all(
                    tp.startswith("{") or tp == pp
                    for tp, pp in zip(t_parts, parts)):
                return True
        return False

    def _route(self, method: str):
        split = urlparse.urlsplit(self.path)
        qs = urlparse.parse_qs(split.query)
        parts = [p for p in split.path.split("/") if p]
        api = self.ctx.api

        if parts[:1] == ["v2"]:
            self._envelope_version = ADMIN_API_VERSION
        if not self._known_route(method, parts):
            raise ApiError(ErrorCode.NOT_FOUND,
                           f"no route for {method} {split.path}")
        if method == "GET" and parts == ["v1", "health"]:
            return self._health()

        key = self._api_key()

        if parts[:2] == ["v2", "admin"]:
            return self._admin_route(method, parts[2:], key)

        if parts[:2] == ["v1", "jobs"]:
            if method == "POST" and len(parts) == 2:
                return self._submit(api, key)
            if method == "GET" and len(parts) == 2:
                return self._list(api, key, qs)
            if len(parts) == 3:
                job_id = parts[2]
                if method == "GET":
                    view = api.status(
                        key, job_id,
                        wait_ms=self._int_param(qs, "wait_ms"),
                        last_status=qs.get("last_status", [None])[0])
                    return self._send_json(200, dataclasses.asdict(view))
                if method == "DELETE":
                    api.cancel(key, job_id)
                    return self._send_json(
                        200, {"api_version": API_VERSION, "ok": True})
            if len(parts) == 4:
                job_id, tail = parts[2], parts[3]
                if method == "GET" and tail == "history":
                    hist = api.status_history(key, job_id)
                    return self._send_json(
                        200, {"api_version": API_VERSION,
                              "items": [list(h) for h in hist]})
                if method == "GET" and tail == "logs":
                    page = api.logs(key, job_id,
                                    cursor=qs.get("cursor", [None])[0],
                                    limit=self._int_param(qs, "limit"),
                                    wait_ms=self._int_param(qs, "wait_ms"))
                    return self._send_json(
                        200, _page_to_wire(page, page.items))
                if method == "POST" and tail == "halt":
                    body = self._json_body()
                    api.halt(key, job_id,
                             requeue=bool(body.get("requeue", False)))
                    return self._send_json(
                        200, {"api_version": API_VERSION, "ok": True})
                if method == "POST" and tail == "resume":
                    api.resume(key, job_id)
                    return self._send_json(
                        200, {"api_version": API_VERSION, "ok": True})
        elif method == "GET" and parts == ["v1", "logs", "search"]:
            query = qs.get("q", [None])[0]
            if query is None:
                raise ApiError(ErrorCode.INVALID_ARGUMENT,
                               "missing query parameter 'q'")
            page = api.search_logs(key, query,
                                   job_id=qs.get("job_id", [None])[0],
                                   cursor=qs.get("cursor", [None])[0],
                                   limit=self._int_param(qs, "limit"))
            return self._send_json(200, _page_to_wire(
                page, [_search_rec_to_wire(r) for r in page.items]))

        raise ApiError(ErrorCode.NOT_FOUND,
                       f"no route for {method} {split.path}")

    def _health(self):
        """Liveness, aggregated over replicas AND backend shards: the
        top-level shape (status/replicas_alive/replicas_total) is stable;
        ``shards`` details each backend so operators see a dead shard
        even while every replica is up (the tier then reports
        "degraded" — that shard's tenants are getting UNAVAILABLE)."""
        replicas = self.ctx.platform.api_replicas
        backends = self.ctx.platform.router.backends
        alive = sum(1 for r in replicas if r.alive)
        shards_alive = sum(1 for b in backends if b.alive)
        degraded = alive < len(replicas) or shards_alive < len(backends)
        status = ("down" if not alive
                  else ("degraded" if degraded else "ok"))
        self._send_json(200 if alive else 503,
                        {"api_version": API_VERSION, "status": status,
                         "replicas_alive": alive,
                         "replicas_total": len(replicas),
                         "shards_alive": shards_alive,
                         "shards_total": len(backends),
                         "shards": [{"shard_id": b.shard_id,
                                     "status": "ok" if b.alive else "down",
                                     "cordoned": b.cordoned}
                                    for b in backends]})

    def _admin_route(self, method: str, tail: list, key: str):
        """The v2 admin control plane: resource routes over the shared
        AdminGateway (``platform.admin_api``). Operator-keyed traffic
        bypasses the per-tenant rate limiter — these are the operator's
        backpressure controls, not tenant traffic — but unknown/tenant
        keys still spend a token, so credential-guessing floods against
        /v2 are 429-throttled before auth exactly like against v1."""
        if self.ctx.ratelimiter is not None:
            self.ctx.ratelimiter.throttle_non_admin(key)
        admin = self.ctx.platform.admin_api
        if tail and tail[0] == "tenants":
            if len(tail) == 1:
                if method == "POST":
                    return self._send_json(
                        201, admin.create_tenant(key, self._json_body()))
                if method == "GET":
                    return self._send_json(200, admin.list_tenants(key))
            elif len(tail) == 2:
                name = tail[1]
                if method == "GET":
                    return self._send_json(200, admin.get_tenant(key, name))
                if method == "PATCH":
                    return self._send_json(
                        200, admin.patch_tenant(key, name,
                                                self._json_body()))
                if method == "DELETE":
                    return self._send_json(
                        200, admin.delete_tenant(key, name))
        elif tail and tail[0] == "shards":
            if len(tail) == 1 and method == "GET":
                return self._send_json(200, admin.list_shards(key))
            if len(tail) == 2 and method == "GET":
                return self._send_json(200, admin.get_shard(key, tail[1]))
            if len(tail) == 3 and method == "POST":
                verb = {"cordon": admin.cordon_shard,
                        "uncordon": admin.uncordon_shard,
                        "drain": admin.drain_shard}.get(tail[2])
                if verb is not None:
                    return self._send_json(200, verb(key, tail[1]))
        elif tail and tail[0] == "migrations":
            if len(tail) == 1:
                if method == "POST":
                    return self._send_json(
                        202, admin.start_migration(key, self._json_body()))
                if method == "GET":
                    return self._send_json(200, admin.list_migrations(key))
            elif len(tail) == 2 and method == "GET":
                return self._send_json(
                    200, admin.get_migration(key, tail[1]))
        raise ApiError(ErrorCode.NOT_FOUND,
                       f"no route for {method} /v2/admin/{'/'.join(tail)}")

    def _submit(self, api, key: str):
        body = self._json_body()
        if "manifest" not in body:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           "body must carry a 'manifest' object")
        # header wins over body: retried requests re-send the same header
        idem = self.headers.get("Idempotency-Key") \
            or body.get("idempotency_key")
        req = SubmitRequest(
            manifest=manifest_from_wire(body["manifest"]),
            idempotency_key=idem,
            api_version=body.get("api_version", API_VERSION))
        resp = api.submit(key, req)
        self._send_json(200 if resp.deduplicated else 201,
                        dataclasses.asdict(resp))

    def _list(self, api, key: str, qs: dict):
        status_raw = qs.get("status", [None])[0]
        status = None
        if status_raw is not None:
            try:
                status = JobStatus(status_raw)
            except ValueError:
                raise ApiError(ErrorCode.INVALID_ARGUMENT,
                               f"unknown status {status_raw!r}")
        kwargs = {"tenant": qs.get("tenant", [None])[0], "status": status,
                  "cursor": qs.get("cursor", [None])[0]}
        limit = self._int_param(qs, "limit")
        if limit is not None:
            kwargs["limit"] = limit
        page = api.list_jobs(key, **kwargs)
        self._send_json(200, _page_to_wire(
            page, [dataclasses.asdict(v) for v in page.items]))

    def _drain_unread_body(self):
        """A route that never called ``_json_body`` (no-body verbs, or a
        failure before the read) leaves the request body on the socket;
        consume it or the next keep-alive request desyncs. A body too big
        to be worth draining forces the connection closed instead — never
        let the leftover bytes be parsed as the next request."""
        if getattr(self, "_body_read", False):
            return
        self._body_read = True
        try:
            length = self._content_length()
        except ApiError:
            return  # connection already flagged for close
        if 0 < length <= MAX_DRAIN_BYTES:
            self.rfile.read(length)
        elif length > MAX_DRAIN_BYTES:
            self.close_connection = True

    def _handle(self, method: str):
        self._body_read = False
        self._envelope_version = API_VERSION
        try:
            self._route(method)
        except ApiError as e:
            self._send_error_envelope(e)
        except Exception as e:  # noqa: BLE001 — never leak a traceback page
            self._send_error_envelope(
                ApiError(ErrorCode.UNAVAILABLE, f"internal error: {e}"))

    def do_GET(self):
        self._handle("GET")

    def do_POST(self):
        self._handle("POST")

    def do_DELETE(self):
        self._handle("DELETE")

    # Unused verbs still get the v1 404 envelope, not a bare 501 page.
    def do_PUT(self):
        self._handle("PUT")

    def do_PATCH(self):
        self._handle("PATCH")


class ApiHttpServer:
    """Threaded stdlib HTTP server over a platform's (or a
    :class:`~repro.api.federation.Federation`'s) API tier.

    ``rate_limit`` installs a :class:`RateLimitedApi` front (per-tenant
    token buckets + bounded in-flight gate). Verb handlers lock per shard
    inside the gateway (reads shared, writes exclusive); ``lock`` is the
    all-shards write lock — hold it when ticking the simulation from
    another thread (``with server.lock: platform.tick()``). A
    ``Federation`` driver can instead call ``federation.tick()``, which
    locks one shard at a time so other shards keep serving reads.
    """

    def __init__(self, platform, host: str = "127.0.0.1", port: int = 0,
                 rate_limit: Optional[RateLimitConfig] = None,
                 per_tenant: Optional[dict] = None):
        self.platform = platform
        self.lock = AllShardsLock(platform.router)
        self.ratelimiter = None
        if rate_limit is not None:
            self.ratelimiter = RateLimitedApi(platform.api, platform.auth,
                                              rate_limit, per_tenant)
        self.api = self.ratelimiter or platform.api
        # v2 admin plane: wire the rate limiter in so tenant PATCHes with
        # rate/burst apply live to the token buckets
        admin = getattr(platform, "admin", None)
        if admin is not None and self.ratelimiter is not None:
            admin.attach_ratelimiter(self.ratelimiter)
        handler = type("BoundHandler", (_Handler,), {"ctx": self})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._thread: Optional[threading.Thread] = None

    @property
    def port(self) -> int:
        return self._httpd.server_port

    @property
    def base_url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}"

    def start(self) -> "ApiHttpServer":
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        kwargs={"poll_interval": 0.05},
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __enter__(self) -> "ApiHttpServer":
        return self.start()

    def __exit__(self, *exc):
        self.stop()


# --------------------------------------------------------------------------
# Client transport
# --------------------------------------------------------------------------

class HttpTransport:
    """v1 verb surface over the wire — drop-in for the in-process
    ``LoadBalancer`` anywhere a transport is expected (``ApiClient``,
    benchmarks, the ``ffdl`` CLI).

    Connections are persistent (HTTP/1.1 keep-alive) and thread-local, so
    concurrent tenant clients measure the API tier — not per-request TCP
    and thread churn. A connection the server dropped is retried once on a
    fresh socket before surfacing UNAVAILABLE.
    """

    def __init__(self, base_url: str, timeout: float = 10.0):
        self.base_url = base_url.rstrip("/")
        split = urlparse.urlsplit(self.base_url)
        if split.scheme != "http" or split.hostname is None:
            raise ValueError(f"expected an http:// URL, got {base_url!r}")
        self._host = split.hostname
        self._port = split.port or 80
        self.timeout = timeout
        self._local = threading.local()

    # -- low-level --------------------------------------------------------
    def _drop_conn(self):
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            conn.close()
        self._local.conn = None

    def _conn(self) -> http.client.HTTPConnection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = http.client.HTTPConnection(self._host, self._port,
                                              timeout=self.timeout)
            conn.connect()
            conn.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._local.conn = conn
        return conn

    def _request(self, method: str, path: str, api_key: Optional[str] = None,
                 body: Optional[dict] = None, query: Optional[dict] = None,
                 headers: Optional[dict] = None,
                 allow_error_status: bool = False,
                 timeout_floor: Optional[float] = None) -> tuple[int, dict]:
        if query:
            qs = {k: v for k, v in query.items() if v is not None}
            if qs:
                path += "?" + urlparse.urlencode(qs)
        data = json.dumps(body).encode() if body is not None else None
        hdrs = {"Content-Type": "application/json"}
        if api_key is not None:
            hdrs["Authorization"] = f"Bearer {api_key}"
        for k, v in (headers or {}).items():
            if v is not None:
                hdrs[k] = v

        # Retry policy: a reused keep-alive socket may have been closed by
        # the server since the last call; such failures are retried once on
        # a fresh socket — but ONLY when the request cannot have executed
        # (send-phase failure) or the verb is idempotent (GET). A write
        # that succeeded followed by a read failure on a mutating verb is
        # surfaced as UNAVAILABLE instead of silently re-executing it.
        status = payload = None
        for attempt in (0, 1):
            reused = getattr(self._local, "conn", None) is not None
            conn = self._conn()
            # A long-poll (logs wait_ms) may legitimately park server-side
            # longer than the transport's socket timeout: raise this
            # request's read timeout to cover the park, restore after.
            raised_timeout = False
            if timeout_floor is not None and conn.sock is not None \
                    and timeout_floor > self.timeout:
                conn.sock.settimeout(timeout_floor)
                raised_timeout = True
            try:
                conn.request(method, path, body=data, headers=hdrs)
            except (http.client.HTTPException, OSError) as e:
                self._drop_conn()
                if reused and attempt == 0:
                    continue  # stale keep-alive socket; nothing was served
                raise ApiError(ErrorCode.UNAVAILABLE,
                               f"cannot reach API server: {e}") from None
            try:
                resp = conn.getresponse()
                status, payload = resp.status, resp.read()
                if raised_timeout:  # keep-alive socket back to the default
                    conn.sock.settimeout(self.timeout)
                break
            except (http.client.HTTPException, OSError) as e:
                self._drop_conn()
                if reused and attempt == 0 and method == "GET":
                    continue
                raise ApiError(
                    ErrorCode.UNAVAILABLE,
                    f"connection lost awaiting response: {e}") from None

        if status >= 400 and not allow_error_status:
            try:
                wire = json.loads(payload)["error"]
                if not isinstance(wire, dict) or "code" not in wire:
                    wire = None
            except (ValueError, KeyError, TypeError):
                wire = None
            if wire is None:
                err = ApiError(ErrorCode.UNAVAILABLE,
                               f"HTTP {status}: undecodable error body")
            else:
                try:
                    code = ErrorCode(wire["code"])
                    extra = {}
                except ValueError:
                    # a newer server's code this client doesn't know: keep
                    # the raw string and fall back to a NON-retryable code
                    # (UNAVAILABLE would invite blind re-execution)
                    code = ErrorCode.FAILED_PRECONDITION
                    extra = {"wire_code": wire["code"]}
                err = ApiError(code, wire.get("message", ""),
                               **{**wire.get("details", {}), **extra})
            err.details.setdefault("http_status", status)
            raise err
        try:
            return status, json.loads(payload or b"{}")
        except ValueError as e:
            raise ApiError(ErrorCode.UNAVAILABLE,
                           f"undecodable response body: {e}") from None

    def health(self) -> dict:
        """Health is special: a fully-down tier answers 503 with a valid
        health body (replica counts included), not an error envelope."""
        try:
            return self._request("GET", "/v1/health",
                                 allow_error_status=True)[1]
        except ApiError as e:
            return {"status": "down", "error": e.message,
                    **{k: v for k, v in e.details.items()}}

    # -- full v1 surface --------------------------------------------------
    def submit(self, api_key, req: SubmitRequest) -> SubmitResponse:
        body = {"manifest": dataclasses.asdict(req.manifest),
                "api_version": req.api_version}
        _, d = self._request("POST", "/v1/jobs", api_key, body=body,
                             headers={"Idempotency-Key": req.idempotency_key})
        return SubmitResponse(**d)

    def status(self, api_key, job_id, wait_ms=None,
               last_status=None) -> JobView:
        floor = None if not wait_ms else wait_ms / 1000.0 + 5.0
        _, d = self._request("GET", f"/v1/jobs/{job_id}", api_key,
                             query={"wait_ms": wait_ms,
                                    "last_status": last_status},
                             timeout_floor=floor)
        return JobView(**d)

    def status_history(self, api_key, job_id) -> list:
        _, d = self._request("GET", f"/v1/jobs/{job_id}/history", api_key)
        return [tuple(h) for h in d["items"]]

    def list_jobs(self, api_key, tenant=None, status=None, cursor=None,
                  limit=None) -> Page:
        _, d = self._request(
            "GET", "/v1/jobs", api_key,
            query={"tenant": tenant,
                   "status": getattr(status, "value", status),
                   "cursor": cursor, "limit": limit})
        return Page(items=[JobView(**v) for v in d["items"]],
                    next_cursor=d["next_cursor"])

    def logs(self, api_key, job_id, cursor=None, limit=None,
             wait_ms=None) -> Page:
        floor = None if not wait_ms else wait_ms / 1000.0 + 5.0
        _, d = self._request("GET", f"/v1/jobs/{job_id}/logs", api_key,
                             query={"cursor": cursor, "limit": limit,
                                    "wait_ms": wait_ms},
                             timeout_floor=floor)
        return Page(items=d["items"], next_cursor=d["next_cursor"])

    def search_logs(self, api_key, query, job_id=None, cursor=None,
                    limit=None) -> Page:
        _, d = self._request("GET", "/v1/logs/search", api_key,
                             query={"q": query, "job_id": job_id,
                                    "cursor": cursor, "limit": limit})
        return Page(items=[LogRecord(**r) for r in d["items"]],
                    next_cursor=d["next_cursor"])

    def halt(self, api_key, job_id, requeue: bool = False):
        self._request("POST", f"/v1/jobs/{job_id}/halt", api_key,
                      body={"requeue": requeue})

    def resume(self, api_key, job_id):
        self._request("POST", f"/v1/jobs/{job_id}/resume", api_key, body={})

    def cancel(self, api_key, job_id):
        self._request("DELETE", f"/v1/jobs/{job_id}", api_key)

    # -- v2 admin control plane -------------------------------------------
    # Same method names/signatures as the in-process AdminGateway, so
    # AdminClient (repro.api.client) works over either transport.
    def create_tenant(self, api_key, body: dict) -> dict:
        return self._request("POST", "/v2/admin/tenants", api_key,
                             body=body)[1]

    def get_tenant(self, api_key, name: str) -> dict:
        return self._request("GET", f"/v2/admin/tenants/{name}", api_key)[1]

    def list_tenants(self, api_key) -> dict:
        return self._request("GET", "/v2/admin/tenants", api_key)[1]

    def patch_tenant(self, api_key, name: str, patch: dict) -> dict:
        return self._request("PATCH", f"/v2/admin/tenants/{name}", api_key,
                             body=patch)[1]

    def delete_tenant(self, api_key, name: str) -> dict:
        return self._request("DELETE", f"/v2/admin/tenants/{name}",
                             api_key)[1]

    def list_shards(self, api_key) -> dict:
        return self._request("GET", "/v2/admin/shards", api_key)[1]

    def get_shard(self, api_key, shard_id: str) -> dict:
        return self._request("GET", f"/v2/admin/shards/{shard_id}",
                             api_key)[1]

    def cordon_shard(self, api_key, shard_id: str) -> dict:
        return self._request("POST", f"/v2/admin/shards/{shard_id}/cordon",
                             api_key, body={})[1]

    def uncordon_shard(self, api_key, shard_id: str) -> dict:
        return self._request(
            "POST", f"/v2/admin/shards/{shard_id}/uncordon", api_key,
            body={})[1]

    def drain_shard(self, api_key, shard_id: str) -> dict:
        return self._request("POST", f"/v2/admin/shards/{shard_id}/drain",
                             api_key, body={})[1]

    def start_migration(self, api_key, body: dict) -> dict:
        return self._request("POST", "/v2/admin/migrations", api_key,
                             body=body)[1]

    def get_migration(self, api_key, migration_id: str) -> dict:
        return self._request("GET", f"/v2/admin/migrations/{migration_id}",
                             api_key)[1]

    def list_migrations(self, api_key) -> dict:
        return self._request("GET", "/v2/admin/migrations", api_key)[1]
