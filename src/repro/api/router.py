"""TenantRouter: deterministic tenant→shard placement (FfDL §3).

FfDL shards its MongoDB metastore and scales backend microservices
independently of the REST tier; the thing that keeps the wire contract
stable across that re-architecture is a *deterministic* mapping from
tenant to backend. We reproduce it as:

  * **hash-by-tenant** — SHA-256 of the tenant name modulo the shard
    count. Stable across processes and runs (no ``hash()`` randomization),
    so a tenant's jobs always live on one shard and any gateway replica
    resolves the same shard for the same key;
  * **an explicit pin table** — tests, benchmarks, and operators can place
    a tenant on a named shard (``pin("team-a", "shard-2")``), overriding
    the hash. Pins are how the federation drill puts one tenant per shard;
    the v2 admin plane (``repro.api.admin``) places tenants and flips
    their pin at migration cutover.

**Migration locks**: while a tenant is being rebalanced between shards,
its routing is frozen — ``pin``/``unpin`` answer ``FAILED_PRECONDITION``
so an operator's pin-table edit can never race the migration's cutover
(which flips the pin itself, atomically, under both shards' write locks,
via the internal ``_force_pin``). ``migration_target`` exposes the
in-flight destination so cross-shard reads can hide the half-imported
copy until cutover.

Cross-shard admin listings paginate behind a **composite cursor**: an
opaque string carrying one cursor per shard
(``ms1~shard-0=job-00004~shard-1=job-1000002``). For job listings each
entry is a position in that shard's **minting-id stream** — the
contiguous id interval the shard mints from, which a record belongs to
for life even after a migration moves it to another shard — so
already-served items never repeat and never go missing across cutovers;
for log search the entries are physical append offsets (at-least-once
across a cutover: never lost, possibly repeated once from the
destination). Items that arrive mid-iteration on a still-open stream are
picked up by a later page. A stream that answers an *empty* page is
marked **exhausted** with a ``!`` suffix on its segment
(``shard-0=job-00004!``) and is never queried again for the rest of the
walk — an admin paging through a mostly-drained federation stops paying
one probe per exhausted shard per page. Malformed composite cursors are
rejected with ``INVALID_ARGUMENT`` like any other bad cursor.
"""

from __future__ import annotations

import hashlib
import re
from typing import Dict, Optional, Set, Tuple

from repro.api.types import ApiError, ErrorCode

# Composite-cursor wire prefix. Versioned so a future cursor format can
# coexist; everything after it is ``~shard_id=per_shard_cursor`` segments,
# with a ``!`` suffix marking a shard whose final page was already served.
COMPOSITE_PREFIX = "ms1"
EXHAUSTED_MARK = "!"

# What a valid per-shard cursor looks like, per surface.
JOB_CURSOR_RE = re.compile(r"job-\d+")
OFFSET_CURSOR_RE = re.compile(r"\d+")


class TenantRouter:
    """Deterministic tenant→Backend resolution over a fixed shard list."""

    def __init__(self, backends, pins: Optional[Dict[str, str]] = None):
        if not backends:
            raise ValueError("need at least one backend shard")
        self.backends = list(backends)
        self._by_id = {b.shard_id: b for b in self.backends}
        if len(self._by_id) != len(self.backends):
            raise ValueError("shard ids must be unique")
        self.pins: Dict[str, str] = {}
        # tenant → (src_shard_id, dst_shard_id) while a migration is live
        self._migrating: Dict[str, Tuple[str, str]] = {}
        for tenant, shard_id in (pins or {}).items():
            self.pin(tenant, shard_id)

    @property
    def shard_ids(self) -> list:
        return [b.shard_id for b in self.backends]

    def backend(self, shard_id: str):
        return self._by_id[shard_id]

    def pin(self, tenant: str, shard_id: str):
        """Place ``tenant`` on a named shard, overriding the hash."""
        if shard_id not in self._by_id:
            raise ValueError(f"unknown shard {shard_id!r} "
                             f"(have {sorted(self._by_id)})")
        self._check_not_migrating(tenant)
        self.pins[tenant] = shard_id

    def unpin(self, tenant: str):
        self._check_not_migrating(tenant)
        self.pins.pop(tenant, None)

    def shard_for(self, tenant: str):
        """The Backend owning ``tenant`` — pinned, else hashed.

        Cordon enforcement: a NEVER-SEEN tenant whose hash lands on a
        cordoned shard is deterministically re-hashed over the open
        shards (same digest, smaller modulus — pure, no state mutated by
        reads). Tenants already resident on a cordoned shard keep routing
        to it — cordon stops new placements, it does not evict. A write
        that is about to CREATE records calls :meth:`pin_for_write`
        first, which makes the reroute sticky (pinned), so lifting the
        cordon later cannot snap the hash back and orphan the records.
        """
        pinned = self.pins.get(tenant)
        if pinned is not None:
            return self._by_id[pinned]
        digest = int(hashlib.sha256(tenant.encode()).hexdigest(), 16)
        backend = self.backends[digest % len(self.backends)]
        if backend.cordoned and not self._resident(backend, tenant):
            rerouted = self._reroute(digest)
            if rerouted is not None:
                return rerouted
        return backend

    def _reroute(self, digest: int):
        open_backends = [b for b in self.backends if not b.cordoned]
        if not open_backends:
            return None
        return open_backends[digest % len(open_backends)]

    def pin_for_write(self, tenant: str):
        """Called before a record-creating write (submit): if the
        tenant's routing is currently a cordon reroute, PIN it there so
        the placement survives an uncordon. Reads never pin — a GET for
        an arbitrary tenant name must not grow the pin table or decide a
        future tenant's placement."""
        if tenant in self.pins or tenant in self._migrating:
            return
        digest = int(hashlib.sha256(tenant.encode()).hexdigest(), 16)
        backend = self.backends[digest % len(self.backends)]
        if backend.cordoned and not self._resident(backend, tenant):
            rerouted = self._reroute(digest)
            if rerouted is not None:
                self.pins[tenant] = rerouted.shard_id

    @staticmethod
    def _resident(backend, tenant: str) -> bool:
        """Does the shard already hold records for this tenant?"""
        try:
            return bool(backend.platform.meta._by_tenant.get(tenant))
        except Exception:  # metastore down: treat as resident (no reroute)
            return True

    # -- migration coordination (repro.api.admin) -------------------------
    def _check_not_migrating(self, tenant: str):
        """An operator pin-table edit must never race a live migration's
        cutover — the cutover itself flips the pin via ``_force_pin``."""
        if tenant in self._migrating:
            src, dst = self._migrating[tenant]
            raise ApiError(ErrorCode.FAILED_PRECONDITION,
                           f"tenant {tenant!r} is migrating "
                           f"({src} -> {dst}); routing is frozen",
                           tenant=tenant, src=src, dst=dst)

    def lock_tenant(self, tenant: str, src_id: str, dst_id: str):
        if tenant in self._migrating:
            raise ApiError(ErrorCode.CONFLICT,
                           f"tenant {tenant!r} already has a live migration",
                           tenant=tenant)
        self._migrating[tenant] = (src_id, dst_id)

    def unlock_tenant(self, tenant: str):
        self._migrating.pop(tenant, None)

    def migration_target(self, tenant: str) -> Optional[str]:
        """Destination shard id of the tenant's live migration (None when
        not migrating). Cross-shard reads hide the destination's
        half-imported copy behind this until cutover."""
        entry = self._migrating.get(tenant)
        return entry[1] if entry else None

    def migrating_into(self, shard_id: str) -> list:
        """Tenants whose live migration is importing INTO ``shard_id``."""
        return [t for t, (_src, dst) in list(self._migrating.items())
                if dst == shard_id]

    def _force_pin(self, tenant: str, shard_id: str):
        """Cutover-internal pin flip: bypasses the migration freeze. Only
        the migration state machine calls this, under BOTH shards' write
        locks, so no v1 verb can observe a half-moved tenant."""
        if shard_id not in self._by_id:
            raise ValueError(f"unknown shard {shard_id!r}")
        self.pins[tenant] = shard_id


# --------------------------------------------------------------------------
# Composite cursors (cross-shard pagination)
# --------------------------------------------------------------------------

def encode_composite_cursor(cursors: Dict[str, str],
                            exhausted: Optional[Set[str]] = None) -> str:
    """``{shard_id: per_shard_cursor}`` (+ exhausted shard ids) → one
    opaque wire cursor. An exhausted shard keeps its last cursor (or an
    empty one if it never served an item) with a ``!`` suffix."""
    exhausted = exhausted or set()
    parts = []
    for sid in sorted(set(cursors) | exhausted):
        mark = EXHAUSTED_MARK if sid in exhausted else ""
        parts.append(f"{sid}={cursors.get(sid, '')}{mark}")
    return "~".join([COMPOSITE_PREFIX] + parts)


def parse_composite_cursor(cursor: Optional[str], router: TenantRouter,
                           item_re: re.Pattern
                           ) -> Tuple[Dict[str, str], Set[str]]:
    """Validate + decode a composite cursor into ``({shard_id: cursor},
    exhausted_shard_ids)``.

    Anything that is not exactly ``ms1`` followed by unique
    ``known_shard=valid_cursor`` segments (optionally ``!``-suffixed) is
    rejected with the stable ``INVALID_ARGUMENT`` code — a garbage cursor
    must never silently compare against real ids and serve a wrong (empty
    or duplicated) page.
    """
    if cursor is None:
        return {}, set()
    bad = ApiError(ErrorCode.INVALID_ARGUMENT,
                   f"malformed cursor: {cursor!r}")
    parts = str(cursor).split("~")
    if parts[0] != COMPOSITE_PREFIX or len(parts) < 2:
        raise bad
    out: Dict[str, str] = {}
    exhausted: Set[str] = set()
    for seg in parts[1:]:
        shard_id, eq, per_shard = seg.partition("=")
        if not eq or shard_id not in router._by_id \
                or shard_id in out or shard_id in exhausted:
            raise bad
        if per_shard.endswith(EXHAUSTED_MARK):
            exhausted.add(shard_id)
            per_shard = per_shard[:-len(EXHAUSTED_MARK)]
            if not per_shard:  # exhausted before serving a single item
                continue
        if not item_re.fullmatch(per_shard):
            raise bad
        out[shard_id] = per_shard
    return out, exhausted
