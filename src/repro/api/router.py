"""TenantRouter: deterministic tenant→shard placement (FfDL §3).

FfDL shards its MongoDB metastore and scales backend microservices
independently of the REST tier; the thing that keeps the wire contract
stable across that re-architecture is a *deterministic* mapping from
tenant to backend. We reproduce it as:

  * **hash-by-tenant** — SHA-256 of the tenant name modulo the shard
    count. Stable across processes and runs (no ``hash()`` randomization),
    so a tenant's jobs always live on one shard and any gateway replica
    resolves the same shard for the same key;
  * **an explicit pin table** — tests, benchmarks, and operators can place
    a tenant on a named shard (``pin("team-a", "shard-2")``), overriding
    the hash. Pins are how the federation drill puts one tenant per shard
    and how an operator would drain a shard.

Cross-shard admin listings paginate behind a **composite cursor**: an
opaque string carrying one per-shard cursor per shard
(``ms1~shard-0=job-00004~shard-1=job-1000002``). Each per-shard cursor is
the shard's own stable cursor (job ids for listings, append offsets for
log search), so the merged walk inherits the per-shard guarantees:
already-served items never repeat, and items that arrive mid-iteration
are still picked up on a later page. Malformed composite cursors are
rejected with ``INVALID_ARGUMENT`` like any other bad cursor.
"""

from __future__ import annotations

import hashlib
import re
from typing import Dict, Optional

from repro.api.types import ApiError, ErrorCode

# Composite-cursor wire prefix. Versioned so a future cursor format can
# coexist; everything after it is ``~shard_id=per_shard_cursor`` segments.
COMPOSITE_PREFIX = "ms1"

# What a valid per-shard cursor looks like, per surface.
JOB_CURSOR_RE = re.compile(r"job-\d+")
OFFSET_CURSOR_RE = re.compile(r"\d+")


class TenantRouter:
    """Deterministic tenant→Backend resolution over a fixed shard list."""

    def __init__(self, backends, pins: Optional[Dict[str, str]] = None):
        if not backends:
            raise ValueError("need at least one backend shard")
        self.backends = list(backends)
        self._by_id = {b.shard_id: b for b in self.backends}
        if len(self._by_id) != len(self.backends):
            raise ValueError("shard ids must be unique")
        self.pins: Dict[str, str] = {}
        for tenant, shard_id in (pins or {}).items():
            self.pin(tenant, shard_id)

    @property
    def shard_ids(self) -> list:
        return [b.shard_id for b in self.backends]

    def backend(self, shard_id: str):
        return self._by_id[shard_id]

    def pin(self, tenant: str, shard_id: str):
        """Place ``tenant`` on a named shard, overriding the hash."""
        if shard_id not in self._by_id:
            raise ValueError(f"unknown shard {shard_id!r} "
                             f"(have {sorted(self._by_id)})")
        self.pins[tenant] = shard_id

    def unpin(self, tenant: str):
        self.pins.pop(tenant, None)

    def shard_for(self, tenant: str):
        """The Backend owning ``tenant`` — pinned, else hashed."""
        pinned = self.pins.get(tenant)
        if pinned is not None:
            return self._by_id[pinned]
        digest = hashlib.sha256(tenant.encode()).hexdigest()
        return self.backends[int(digest, 16) % len(self.backends)]


# --------------------------------------------------------------------------
# Composite cursors (cross-shard pagination)
# --------------------------------------------------------------------------

def encode_composite_cursor(cursors: Dict[str, str]) -> str:
    """``{shard_id: per_shard_cursor}`` → one opaque wire cursor."""
    parts = [f"{sid}={cur}" for sid, cur in sorted(cursors.items())]
    return "~".join([COMPOSITE_PREFIX] + parts)


def parse_composite_cursor(cursor: Optional[str], router: TenantRouter,
                           item_re: re.Pattern) -> Dict[str, str]:
    """Validate + decode a composite cursor into ``{shard_id: cursor}``.

    Anything that is not exactly ``ms1`` followed by unique
    ``known_shard=valid_cursor`` segments is rejected with the stable
    ``INVALID_ARGUMENT`` code — a garbage cursor must never silently
    compare against real ids and serve a wrong (empty or duplicated) page.
    """
    if cursor is None:
        return {}
    bad = ApiError(ErrorCode.INVALID_ARGUMENT,
                   f"malformed cursor: {cursor!r}")
    parts = str(cursor).split("~")
    if parts[0] != COMPOSITE_PREFIX or len(parts) < 2:
        raise bad
    out: Dict[str, str] = {}
    for seg in parts[1:]:
        shard_id, eq, per_shard = seg.partition("=")
        if not eq or shard_id not in router._by_id or shard_id in out \
                or not item_re.fullmatch(per_shard):
            raise bad
        out[shard_id] = per_shard
    return out
