"""Tenant-scoped convenience client over any v1 transport.

``ApiClient`` binds an API key to a *transport* — anything exposing the
nine v1 verbs with ``(api_key, ...)`` signatures: the in-process
``LoadBalancer``, a single ``ApiGateway`` replica, a ``RateLimitedApi``
front, or :class:`repro.api.http.HttpTransport` for a remote server. The
same calling code therefore works in-process and over the wire.

It replaces the retired ``FfDLPlatform.submit/status/...`` facade with the
same ergonomic return shapes (job ids, ``JobStatus``, plain lists) but the
v1 error contract: every failure is an ``ApiError`` with a stable code —
never a raw ``KeyError``/``ValueError``/``PermissionError``.
"""

from __future__ import annotations

from typing import Optional

from repro.api.auth import ALL_TENANTS, READ, WRITE
from repro.api.types import Page, SubmitRequest, SubmitResponse
from repro.core.types import TERMINAL, JobManifest, JobStatus


class ApiClient:
    def __init__(self, transport, api_key: str):
        self.transport = transport
        self.api_key = api_key

    @classmethod
    def for_platform(cls, platform, tenant: str = ALL_TENANTS,
                     scopes: tuple = (READ, WRITE)) -> "ApiClient":
        """Mint a key for ``tenant`` and bind it to the platform's load
        balancer. The default ``"*"`` tenant is an operator credential —
        tests/ops tooling; real tenants should pass their own name."""
        return cls(platform.api, platform.auth.issue_key(tenant, scopes))

    # -- submit -----------------------------------------------------------
    def submit(self, manifest: JobManifest,
               idempotency_key: Optional[str] = None) -> str:
        """Durable-before-ack submit; returns the job id. Use
        :meth:`submit_envelope` when the ``deduplicated`` flag matters."""
        return self.submit_envelope(manifest, idempotency_key).job_id

    def submit_envelope(self, manifest: JobManifest,
                        idempotency_key: Optional[str] = None
                        ) -> SubmitResponse:
        return self.transport.submit(
            self.api_key, SubmitRequest(manifest=manifest,
                                        idempotency_key=idempotency_key))

    # -- reads ------------------------------------------------------------
    def status(self, job_id: str) -> JobStatus:
        return JobStatus(self.transport.status(self.api_key, job_id).status)

    def view(self, job_id: str):
        """The full tenant-visible ``JobView`` projection."""
        return self.transport.status(self.api_key, job_id)

    def status_history(self, job_id: str) -> list:
        return self.transport.status_history(self.api_key, job_id)

    def watch_status(self, job_id: str, wait_ms: int = 8000):
        """Yield the job's ``JobView`` once now and again on every status
        change, long-polling the server (bounded ``wait_ms`` per call,
        parked off-lock server-side) until the job reaches a terminal
        state — the engine behind ``ffdl status --watch``."""
        last = None
        while True:
            view = self.transport.status(self.api_key, job_id,
                                         wait_ms=wait_ms, last_status=last)
            if view.status != last:
                yield view
            last = view.status
            if JobStatus(view.status) in TERMINAL:
                return

    def list_jobs(self, **kwargs) -> Page:
        return self.transport.list_jobs(self.api_key, **kwargs)

    def logs(self, job_id: str, cursor: Optional[str] = None,
             limit: Optional[int] = None) -> list:
        """All log lines (auto-paginates when the transport pages)."""
        if limit is not None:
            return self.transport.logs(self.api_key, job_id, cursor=cursor,
                                       limit=limit).items
        out, cur = [], cursor
        while True:
            page = self.transport.logs(self.api_key, job_id, cursor=cur)
            out += page.items
            cur = page.next_cursor
            if cur is None:
                return out

    def follow_logs(self, job_id: str, cursor: Optional[str] = None,
                    wait_ms: int = 8000):
        """Yield log lines as they appear, long-polling the server-side
        cursor (bounded ``wait_ms`` per call), until the job reaches a
        terminal state and the stream is fully consumed — the engine
        behind ``ffdl logs --follow``."""
        while True:
            page = self.transport.logs(self.api_key, job_id, cursor=cursor,
                                       wait_ms=wait_ms)
            yield from page.items
            cursor = page.next_cursor
            if cursor is None:
                return

    def search_logs(self, query: str, job_id: Optional[str] = None,
                    cursor: Optional[str] = None,
                    limit: Optional[int] = None) -> list:
        """All matches (auto-paginates, like :meth:`logs`); with ``limit``
        set, exactly one page of at most that many records."""
        if limit is not None:
            return self.transport.search_logs(
                self.api_key, query, job_id=job_id, cursor=cursor,
                limit=limit).items
        out, cur = [], cursor
        while True:
            page = self.transport.search_logs(self.api_key, query,
                                              job_id=job_id, cursor=cur)
            out += page.items
            cur = page.next_cursor
            if cur is None:
                return out

    # -- lifecycle writes -------------------------------------------------
    def halt(self, job_id: str, requeue: bool = False):
        return self.transport.halt(self.api_key, job_id, requeue=requeue)

    def resume(self, job_id: str):
        return self.transport.resume(self.api_key, job_id)

    def cancel(self, job_id: str):
        return self.transport.cancel(self.api_key, job_id)


class AdminClient:
    """Operator-key convenience client for the v2 admin control plane.

    ``transport`` is anything exposing the thirteen v2 admin verbs with
    ``(api_key, ...)`` signatures: the in-process
    :class:`~repro.api.admin.AdminGateway` (``platform.admin_api`` /
    ``federation.admin_api``) or an
    :class:`~repro.api.http.HttpTransport`. Verbs return the wire dicts
    verbatim (``"api_version": "v2"`` envelopes).
    """

    def __init__(self, transport, api_key: str):
        self.transport = transport
        self.api_key = api_key

    @classmethod
    def for_platform(cls, platform) -> "AdminClient":
        """Mint an operator key with the ``admin`` scope and bind it to
        the platform's (or federation's) in-process admin gateway."""
        return cls(platform.admin_api, platform.auth.issue_admin_key())

    # -- tenants ----------------------------------------------------------
    def create_tenant(self, name: str, **fields) -> dict:
        return self.transport.create_tenant(self.api_key,
                                            {"name": name, **fields})

    def get_tenant(self, name: str) -> dict:
        return self.transport.get_tenant(self.api_key, name)

    def list_tenants(self) -> list:
        return self.transport.list_tenants(self.api_key)["items"]

    def patch_tenant(self, name: str, **fields) -> dict:
        return self.transport.patch_tenant(self.api_key, name, fields)

    def delete_tenant(self, name: str) -> dict:
        return self.transport.delete_tenant(self.api_key, name)

    # -- shards -----------------------------------------------------------
    def list_shards(self) -> list:
        return self.transport.list_shards(self.api_key)["items"]

    def get_shard(self, shard_id: str) -> dict:
        return self.transport.get_shard(self.api_key, shard_id)

    def cordon(self, shard_id: str) -> dict:
        return self.transport.cordon_shard(self.api_key, shard_id)

    def uncordon(self, shard_id: str) -> dict:
        return self.transport.uncordon_shard(self.api_key, shard_id)

    def drain(self, shard_id: str) -> dict:
        return self.transport.drain_shard(self.api_key, shard_id)

    # -- migrations -------------------------------------------------------
    def migrate(self, tenant: str, to_shard: str) -> dict:
        return self.transport.start_migration(
            self.api_key, {"tenant": tenant, "to_shard": to_shard})

    def migration(self, migration_id: str) -> dict:
        return self.transport.get_migration(self.api_key, migration_id)

    def list_migrations(self) -> list:
        return self.transport.list_migrations(self.api_key)["items"]
