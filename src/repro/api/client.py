"""Tenant-scoped convenience client over any v1 transport.

``ApiClient`` binds an API key to a *transport* — anything exposing the
nine v1 verbs with ``(api_key, ...)`` signatures: the in-process
``LoadBalancer``, a single ``ApiGateway`` replica, a ``RateLimitedApi``
front, or :class:`repro.api.http.HttpTransport` for a remote server. The
same calling code therefore works in-process and over the wire.

It replaces the retired ``FfDLPlatform.submit/status/...`` facade with the
same ergonomic return shapes (job ids, ``JobStatus``, plain lists) but the
v1 error contract: every failure is an ``ApiError`` with a stable code —
never a raw ``KeyError``/``ValueError``/``PermissionError``.

Streaming: when the transport exposes SSE (``stream_logs`` /
``stream_status`` / ``stream_events`` — :class:`HttpTransport` does,
in-process transports don't), ``follow_logs``/``watch_status``/
``follow_events`` ride ONE server-sent-events connection with heartbeats
instead of a long-poll request train. A dropped stream reconnects from its
``Last-Event-ID`` (exact resume, no replay and no gap) after a *jittered
exponential backoff* — a fleet of followers dropped by one API restart
must not stampede back in lockstep. A server without SSE
(``sse_unsupported``) demotes the client to long-poll permanently.
``prefer_sse=False`` forces long-poll (the ``--long-poll`` CLI flag).

Retries: an optional :class:`RetryPolicy` makes the *idempotent read
verbs* (status/history/list/logs/search/usage/events) retry transient
failures (``UNAVAILABLE``, ``DEADLINE_EXCEEDED``) with capped
exponential backoff and full jitter, honouring a server-supplied
``retry_after`` hint as the floor. Mutating verbs are never retried by
the policy — ``submit`` dedup rides idempotency keys, and re-issuing
``halt``/``cancel`` is the caller's decision. Default is ``None``: no
behaviour change for existing callers.
"""

from __future__ import annotations

import json
import random
import time
from dataclasses import dataclass, field
from typing import Optional

from repro.api.auth import ALL_TENANTS, READ, WRITE
from repro.api.types import ApiError, ErrorCode, JobView, Page, \
    SubmitRequest, SubmitResponse
from repro.core.types import TERMINAL, JobManifest, JobStatus

# consecutive UNAVAILABLE stream (re)opens before giving up — a live
# server that keeps resetting streams is as unreachable as a dead one
_MAX_STREAM_FAILURES = 3

# reconnect backoff for dropped SSE streams (always on; first retry is
# near-immediate so a one-off drop costs ~nothing)
_STREAM_BACKOFF_BASE_S = 0.05
_STREAM_BACKOFF_CAP_S = 2.0


def _backoff_s(attempt: int, retry_after, rng: random.Random,
               base_s: float, cap_s: float) -> float:
    """Capped exponential backoff with **full jitter** (uniform over
    [0, min(cap, base·2^attempt)]), floored at the server's
    ``Retry-After`` hint when one was sent — the server knows its own
    recovery horizon better than the client's doubling schedule."""
    ceiling = min(cap_s, base_s * (2 ** attempt))
    delay = rng.uniform(0.0, ceiling)
    if retry_after is not None:
        try:
            delay = max(delay, float(retry_after))
        except (TypeError, ValueError):
            pass
    return delay


@dataclass
class RetryPolicy:
    """Client-side retry budget for idempotent reads (opt-in)."""
    max_attempts: int = 4
    base_s: float = 0.05
    cap_s: float = 2.0
    seed: int = 0
    codes: tuple = (ErrorCode.UNAVAILABLE, ErrorCode.DEADLINE_EXCEEDED)
    rng: random.Random = field(init=False, repr=False)

    def __post_init__(self):
        self.rng = random.Random(self.seed)


def _frame_error(data) -> ApiError:
    """Decode an ``event: error`` frame (the standard wire error envelope
    delivered in-stream) back into the ApiError it carries."""
    try:
        wire = json.loads(data)["error"]
        code = ErrorCode(wire["code"])
        details = {k: v for k, v in wire.items()
                   if k not in ("code", "message")}
        return ApiError(code, wire.get("message", ""), **details)
    except (ValueError, KeyError, TypeError):
        return ApiError(ErrorCode.UNAVAILABLE,
                        f"undecodable stream error frame: {data!r}")


class ApiClient:
    def __init__(self, transport, api_key: str, prefer_sse: bool = True,
                 retry: Optional[RetryPolicy] = None):
        self.transport = transport
        self.api_key = api_key
        self.prefer_sse = prefer_sse
        self.retry = retry
        self._stream_rng = random.Random(0xF501)

    def _sse(self, verb: str) -> bool:
        return self.prefer_sse and hasattr(self.transport, verb)

    def _read(self, fn, *args, **kwargs):
        """Run an idempotent read verb under the retry policy (when one
        is configured): transient codes are retried with jittered
        exponential backoff, anything else propagates immediately."""
        pol = self.retry
        if pol is None:
            return fn(*args, **kwargs)
        attempt = 0
        while True:
            try:
                return fn(*args, **kwargs)
            except ApiError as e:
                attempt += 1
                if e.code not in pol.codes or attempt >= pol.max_attempts:
                    raise
                time.sleep(_backoff_s(attempt - 1,
                                      e.details.get("retry_after"),
                                      pol.rng, pol.base_s, pol.cap_s))

    def _stream_backoff(self, failures: int, err: ApiError):
        """Pause before reopening a dropped SSE stream (jittered, capped,
        Retry-After-aware) so reconnecting followers don't stampede."""
        time.sleep(_backoff_s(max(0, failures - 1),
                              err.details.get("retry_after"),
                              self._stream_rng,
                              _STREAM_BACKOFF_BASE_S,
                              _STREAM_BACKOFF_CAP_S))

    @classmethod
    def for_platform(cls, platform, tenant: str = ALL_TENANTS,
                     scopes: tuple = (READ, WRITE)) -> "ApiClient":
        """Mint a key for ``tenant`` and bind it to the platform's load
        balancer. The default ``"*"`` tenant is an operator credential —
        tests/ops tooling; real tenants should pass their own name."""
        return cls(platform.api, platform.auth.issue_key(tenant, scopes))

    # -- submit -----------------------------------------------------------
    def submit(self, manifest: JobManifest,
               idempotency_key: Optional[str] = None) -> str:
        """Durable-before-ack submit; returns the job id. Use
        :meth:`submit_envelope` when the ``deduplicated`` flag matters."""
        return self.submit_envelope(manifest, idempotency_key).job_id

    def submit_envelope(self, manifest: JobManifest,
                        idempotency_key: Optional[str] = None
                        ) -> SubmitResponse:
        return self.transport.submit(
            self.api_key, SubmitRequest(manifest=manifest,
                                        idempotency_key=idempotency_key))

    # -- reads ------------------------------------------------------------
    def status(self, job_id: str) -> JobStatus:
        return JobStatus(
            self._read(self.transport.status,
                       self.api_key, job_id).status)

    def view(self, job_id: str):
        """The full tenant-visible ``JobView`` projection."""
        return self._read(self.transport.status, self.api_key, job_id)

    def status_history(self, job_id: str) -> list:
        return self._read(self.transport.status_history,
                          self.api_key, job_id)

    def watch_status(self, job_id: str, wait_ms: int = 8000):
        """Yield the job's ``JobView`` once now and again on every status
        change until the job reaches a terminal state — the engine behind
        ``ffdl status --watch``. Rides one SSE connection when the
        transport streams; otherwise long-polls (bounded ``wait_ms`` per
        call, parked off-lock server-side)."""
        last = None
        if self._sse("stream_status"):
            failures = 0
            while True:
                ended = False
                try:
                    for fr in self.transport.stream_status(
                            self.api_key, job_id, last_status=last):
                        if fr.comment is not None:
                            continue
                        if fr.event == "end":
                            ended = True
                            break
                        if fr.event == "error":
                            raise _frame_error(fr.data)
                        failures = 0
                        view = JobView(**json.loads(fr.data))
                        last = fr.id or view.status
                        yield view
                except ApiError as e:
                    if e.details.get("sse_unsupported"):
                        break  # server can't stream: long-poll forever
                    failures += 1
                    if e.code is not ErrorCode.UNAVAILABLE \
                            or failures >= _MAX_STREAM_FAILURES:
                        raise
                    self._stream_backoff(failures, e)
                else:
                    if ended:
                        return
                    # clean close (stream budget spent): resume from last
        while True:
            view = self._read(self.transport.status, self.api_key, job_id,
                              wait_ms=wait_ms, last_status=last)
            if view.status != last:
                yield view
            last = view.status
            if JobStatus(view.status) in TERMINAL:
                return

    def list_jobs(self, **kwargs) -> Page:
        return self._read(self.transport.list_jobs, self.api_key, **kwargs)

    def logs(self, job_id: str, cursor: Optional[str] = None,
             limit: Optional[int] = None) -> list:
        """All log lines (auto-paginates when the transport pages)."""
        if limit is not None:
            return self._read(self.transport.logs, self.api_key, job_id,
                              cursor=cursor, limit=limit).items
        out, cur = [], cursor
        while True:
            page = self._read(self.transport.logs, self.api_key, job_id,
                              cursor=cur)
            out += page.items
            cur = page.next_cursor
            if cur is None:
                return out

    def follow_logs(self, job_id: str, cursor: Optional[str] = None,
                    wait_ms: int = 8000):
        """Yield log lines as they appear until the job reaches a terminal
        state and the stream is fully consumed — the engine behind
        ``ffdl logs --follow``. One SSE connection when the transport
        streams (every frame id is the exact resume cursor); long-poll on
        the server-side cursor otherwise."""
        if self._sse("stream_logs"):
            failures = 0
            while True:
                ended = False
                try:
                    for fr in self.transport.stream_logs(
                            self.api_key, job_id, cursor=cursor):
                        if fr.comment is not None:
                            continue
                        if fr.event == "end":
                            ended = True
                            break
                        if fr.event == "error":
                            raise _frame_error(fr.data)
                        failures = 0
                        if fr.id is not None:
                            cursor = fr.id
                        yield json.loads(fr.data)
                except ApiError as e:
                    if e.details.get("sse_unsupported"):
                        break
                    failures += 1
                    if e.code is not ErrorCode.UNAVAILABLE \
                            or failures >= _MAX_STREAM_FAILURES:
                        raise
                    self._stream_backoff(failures, e)
                else:
                    if ended:
                        return
        while True:
            page = self._read(self.transport.logs, self.api_key, job_id,
                              cursor=cursor, wait_ms=wait_ms)
            yield from page.items
            cursor = page.next_cursor
            if cursor is None:
                return

    def search_logs(self, query: str, job_id: Optional[str] = None,
                    cursor: Optional[str] = None,
                    limit: Optional[int] = None) -> list:
        """All matches (auto-paginates, like :meth:`logs`); with ``limit``
        set, exactly one page of at most that many records."""
        if limit is not None:
            return self._read(self.transport.search_logs,
                self.api_key, query, job_id=job_id, cursor=cursor,
                limit=limit).items
        out, cur = [], cursor
        while True:
            page = self._read(self.transport.search_logs, self.api_key,
                              query, job_id=job_id, cursor=cur)
            out += page.items
            cur = page.next_cursor
            if cur is None:
                return out

    # -- lifecycle writes -------------------------------------------------
    def halt(self, job_id: str, requeue: bool = False):
        return self.transport.halt(self.api_key, job_id, requeue=requeue)

    def resume(self, job_id: str):
        return self.transport.resume(self.api_key, job_id)

    def cancel(self, job_id: str):
        return self.transport.cancel(self.api_key, job_id)

    # -- observability plane ----------------------------------------------
    def usage(self, tenant: Optional[str] = None) -> list:
        """Per-tenant usage rows (chip-seconds, job counts, log bytes,
        429s). A tenant key reads its own row; an admin key reads all
        tenants (or one, with ``tenant=``)."""
        return self._read(self.transport.usage, self.api_key,
                          tenant=tenant)["items"]

    def events(self, cursor: Optional[str] = None,
               limit: Optional[int] = None, kind: Optional[str] = None,
               wait_ms: Optional[int] = None) -> dict:
        """One page of the platform event stream:
        ``{"items", "next_cursor", "missed"}``. The cursor chain serves
        every retained event exactly once; ``missed`` counts events that
        aged out of retention before this page read them."""
        return self._read(self.transport.events, self.api_key,
                          cursor=cursor, limit=limit, kind=kind,
                          wait_ms=wait_ms)

    def follow_events(self, cursor: Optional[str] = None,
                      kind: Optional[str] = None, wait_ms: int = 8000):
        """Yield platform events as they happen — the engine behind
        ``ffdl events --follow``. The stream has no natural end; iterate
        until done and close the generator. SSE when the transport
        streams, long-poll otherwise."""
        if self._sse("stream_events"):
            failures = 0
            while True:
                try:
                    for fr in self.transport.stream_events(
                            self.api_key, cursor=cursor, kind=kind):
                        if fr.comment is not None:
                            continue
                        if fr.event == "end":
                            return
                        if fr.event == "error":
                            raise _frame_error(fr.data)
                        failures = 0
                        if fr.id is not None:
                            cursor = fr.id
                        yield json.loads(fr.data)
                except ApiError as e:
                    if e.details.get("sse_unsupported"):
                        break
                    failures += 1
                    if e.code is not ErrorCode.UNAVAILABLE \
                            or failures >= _MAX_STREAM_FAILURES:
                        raise
                    self._stream_backoff(failures, e)
                # clean close: reconnect from the last delivered id
        while True:
            out = self._read(self.transport.events, self.api_key,
                             cursor=cursor, kind=kind, wait_ms=wait_ms)
            yield from out["items"]
            cursor = out["next_cursor"]


class AdminClient:
    """Operator-key convenience client for the v2 admin control plane.

    ``transport`` is anything exposing the fifteen v2 admin verbs with
    ``(api_key, ...)`` signatures: the in-process
    :class:`~repro.api.admin.AdminGateway` (``platform.admin_api`` /
    ``federation.admin_api``) or an
    :class:`~repro.api.http.HttpTransport`. Verbs return the wire dicts
    verbatim (``"api_version": "v2"`` envelopes).
    """

    def __init__(self, transport, api_key: str):
        self.transport = transport
        self.api_key = api_key

    @classmethod
    def for_platform(cls, platform) -> "AdminClient":
        """Mint an operator key with the ``admin`` scope and bind it to
        the platform's (or federation's) in-process admin gateway."""
        return cls(platform.admin_api, platform.auth.issue_admin_key())

    # -- tenants ----------------------------------------------------------
    def create_tenant(self, name: str, **fields) -> dict:
        return self.transport.create_tenant(self.api_key,
                                            {"name": name, **fields})

    def get_tenant(self, name: str) -> dict:
        return self.transport.get_tenant(self.api_key, name)

    def list_tenants(self) -> list:
        return self.transport.list_tenants(self.api_key)["items"]

    def patch_tenant(self, name: str, **fields) -> dict:
        return self.transport.patch_tenant(self.api_key, name, fields)

    def delete_tenant(self, name: str) -> dict:
        return self.transport.delete_tenant(self.api_key, name)

    # -- shards -----------------------------------------------------------
    def list_shards(self) -> list:
        return self.transport.list_shards(self.api_key)["items"]

    def get_shard(self, shard_id: str) -> dict:
        return self.transport.get_shard(self.api_key, shard_id)

    def cordon(self, shard_id: str) -> dict:
        return self.transport.cordon_shard(self.api_key, shard_id)

    def uncordon(self, shard_id: str) -> dict:
        return self.transport.uncordon_shard(self.api_key, shard_id)

    def drain(self, shard_id: str) -> dict:
        return self.transport.drain_shard(self.api_key, shard_id)

    # -- migrations -------------------------------------------------------
    def migrate(self, tenant: str, to_shard: str) -> dict:
        return self.transport.start_migration(
            self.api_key, {"tenant": tenant, "to_shard": to_shard})

    def migration(self, migration_id: str) -> dict:
        return self.transport.get_migration(self.api_key, migration_id)

    def list_migrations(self) -> list:
        return self.transport.list_migrations(self.api_key)["items"]

    # -- fault injection ---------------------------------------------------
    def install_fault(self, point: str, **fields) -> dict:
        """Install a fault plan on a named interposition point (e.g.
        ``install_fault("wal.flush", latency_s=2.0)``)."""
        return self.transport.install_fault(self.api_key,
                                            {"point": point, **fields})

    def list_faults(self) -> dict:
        return self.transport.list_faults(self.api_key)

    def clear_faults(self, fault_id: Optional[str] = None) -> dict:
        return self.transport.clear_faults(self.api_key, fault_id)

    # -- autonomous operator ----------------------------------------------
    def operator_status(self) -> dict:
        return self.transport.operator_status(self.api_key)

    def rollout(self, version: str) -> dict:
        """Start a GUARD-style rolling shard upgrade to ``version``."""
        return self.transport.start_rollout(self.api_key,
                                            {"version": version})


class WorkloadClient:
    """Convenience client for the v2 workloads plane (tenant- or
    admin-keyed).

    ``transport`` is anything exposing the five workload verbs with
    ``(api_key, ...)`` signatures: the in-process
    :class:`~repro.workloads.plane.WorkloadGateway`
    (``platform.workloads_api`` / ``federation.workloads_api``) or an
    :class:`~repro.api.http.HttpTransport`. Verbs return the wire dicts
    verbatim (``"api_version": "v2"`` envelopes).
    """

    def __init__(self, transport, api_key: str):
        self.transport = transport
        self.api_key = api_key

    @classmethod
    def for_platform(cls, platform, tenant: Optional[str] = None
                     ) -> "WorkloadClient":
        """Bind to the platform's (or federation's) in-process workloads
        gateway: a tenant key when ``tenant`` is given, else an admin
        key."""
        key = (platform.auth.issue_key(tenant) if tenant is not None
               else platform.auth.issue_admin_key())
        return cls(platform.workloads_api, key)

    def apply(self, manifest) -> dict:
        """Apply one manifest: a dict, or JSON / YAML-subset text."""
        return self.transport.apply(self.api_key, manifest)

    def get(self, name: str, tenant: Optional[str] = None) -> dict:
        return self.transport.get_workload(self.api_key, name,
                                           tenant=tenant)

    def list(self, tenant: Optional[str] = None) -> list:
        return self.transport.list_workloads(self.api_key,
                                             tenant=tenant)["items"]

    def delete(self, name: str, tenant: Optional[str] = None) -> dict:
        return self.transport.delete_workload(self.api_key, name,
                                              tenant=tenant)

    def invoke(self, name: str, payload=None,
               tenant: Optional[str] = None) -> dict:
        """One inference request against a RUNNING Service."""
        return self.transport.invoke_workload(self.api_key, name,
                                              payload=payload,
                                              tenant=tenant)
