# The replicated, versioned API tier fronting the platform (FfDL §3.2):
# typed envelopes + stable error codes, per-tenant auth, idempotent submit,
# cursor pagination, and round-robin failover across stateless replicas.
from repro.api.auth import ALL_TENANTS, AuthService, Principal, READ, WRITE
from repro.api.gateway import ApiGateway
from repro.api.lb import LoadBalancer
from repro.api.types import (
    API_VERSION,
    ApiError,
    ErrorCode,
    JobView,
    Page,
    SubmitRequest,
    SubmitResponse,
)

__all__ = [
    "ALL_TENANTS",
    "API_VERSION",
    "ApiError",
    "ApiGateway",
    "AuthService",
    "ErrorCode",
    "JobView",
    "LoadBalancer",
    "Page",
    "Principal",
    "READ",
    "SubmitRequest",
    "SubmitResponse",
    "WRITE",
]
