# The replicated, versioned API tier fronting the platform (FfDL §3.2):
# typed envelopes + stable error codes, per-tenant auth, idempotent submit,
# cursor pagination, round-robin failover across stateless replicas, a
# JSON-over-HTTP transport with per-tenant rate limiting, and the `ffdl`
# CLI speaking only the wire protocol (python -m repro.api.cli).
from repro.api.auth import ALL_TENANTS, AuthService, Principal, READ, WRITE
from repro.api.client import ApiClient
from repro.api.gateway import ApiGateway
from repro.api.http import ApiHttpServer, HttpTransport, ROUTES, STATUS_OF
from repro.api.lb import LoadBalancer
from repro.api.ratelimit import RateLimitConfig, RateLimitedApi, TokenBucket
from repro.api.types import (
    API_VERSION,
    ApiError,
    ErrorCode,
    JobView,
    Page,
    SubmitRequest,
    SubmitResponse,
)

__all__ = [
    "ALL_TENANTS",
    "API_VERSION",
    "ApiClient",
    "ApiError",
    "ApiGateway",
    "ApiHttpServer",
    "AuthService",
    "ErrorCode",
    "HttpTransport",
    "JobView",
    "LoadBalancer",
    "Page",
    "Principal",
    "RateLimitConfig",
    "RateLimitedApi",
    "READ",
    "ROUTES",
    "STATUS_OF",
    "SubmitRequest",
    "SubmitResponse",
    "TokenBucket",
    "WRITE",
]
