# The replicated, versioned API tier fronting the platform (FfDL §3.2):
# typed envelopes + stable error codes, per-tenant auth, idempotent submit,
# cursor pagination, round-robin failover across stateless replicas, a
# JSON-over-HTTP transport with per-tenant rate limiting, and the `ffdl`
# CLI speaking only the wire protocol (python -m repro.api.cli).
# The tier routes tenants to independent backend shards (repro.api.backend
# / router / federation): each shard carries its own readers-writer lock,
# so read traffic scales across handler threads and shards.
from repro.api.admin import AdminGateway, AdminPlane, MigrationPhase
from repro.api.auth import (
    ADMIN,
    ALL_TENANTS,
    AuthService,
    Principal,
    READ,
    WRITE,
)
from repro.api.backend import AllShardsLock, Backend, RWLock
from repro.api.client import AdminClient, ApiClient, WorkloadClient
from repro.api.gateway import ApiGateway
from repro.api.http import (
    ADMIN_ROUTES,
    ApiHttpServer,
    HttpTransport,
    OBS_ROUTES,
    ROUTES,
    STATUS_OF,
    WORKLOAD_ROUTES,
)
from repro.api.lb import LoadBalancer
from repro.api.ratelimit import RateLimitConfig, RateLimitedApi, TokenBucket
from repro.api.router import TenantRouter
from repro.api.types import (
    ADMIN_API_VERSION,
    API_VERSION,
    ApiError,
    ErrorCode,
    JobView,
    Page,
    SubmitRequest,
    SubmitResponse,
)
# Federation composes FfDLPlatform shards, which import repro.api.* — keep
# it last so the submodules above are fully initialized first.
from repro.api.federation import Federation, JOB_ID_STRIDE

__all__ = [
    "ADMIN",
    "ADMIN_API_VERSION",
    "ADMIN_ROUTES",
    "ALL_TENANTS",
    "API_VERSION",
    "AdminClient",
    "AdminGateway",
    "AdminPlane",
    "AllShardsLock",
    "ApiClient",
    "ApiError",
    "ApiGateway",
    "ApiHttpServer",
    "AuthService",
    "Backend",
    "ErrorCode",
    "Federation",
    "HttpTransport",
    "JOB_ID_STRIDE",
    "JobView",
    "LoadBalancer",
    "MigrationPhase",
    "OBS_ROUTES",
    "Page",
    "Principal",
    "RateLimitConfig",
    "RateLimitedApi",
    "READ",
    "ROUTES",
    "RWLock",
    "STATUS_OF",
    "SubmitRequest",
    "SubmitResponse",
    "TenantRouter",
    "TokenBucket",
    "WORKLOAD_ROUTES",
    "WRITE",
    "WorkloadClient",
]
