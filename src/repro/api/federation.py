"""Federation: N independent FfDLPlatform shards behind one gateway tier.

FfDL §3 scales the backend microservices *independently* of the REST tier:
the metastore is sharded, and the stateless API layer in front of it never
changes its wire contract when the backend is re-architected. This module
is that composition for our reproduction:

  * **N shards** — each an ordinary :class:`FfDLPlatform` (own metastore
    WAL, scheduler, cluster, log index, sim clock), constructed with the
    shard hooks (``shard_id``, ``job_id_base``) so job ids are globally
    unique (shard *i* mints ``job-{i*10^6 + n}``);
  * **one auth domain** — a single shared :class:`AuthService`; a tenant's
    key works at any gateway replica regardless of which shard holds the
    tenant's jobs;
  * **one gateway tier** — replicated :class:`ApiGateway` instances over a
    :class:`TenantRouter` (hash-by-tenant + pin table), fronted by the
    same round-robin :class:`LoadBalancer`. Replica crashes are masked
    exactly as on a single platform; a *shard* crash surfaces as
    ``UNAVAILABLE`` for that shard's tenants only.

``tick()`` advances every live shard under its own write lock — while
shard 0 is mid-tick, reads for tenants on shards 1..N-1 proceed. This
per-shard ticking is what the ``benchmarks/api_tier.py`` federation drill
measures against the old global-lock baseline.

A ``Federation`` quacks like a platform to the HTTP layer: it exposes
``api``, ``auth``, ``api_replicas``, and ``router``, so
``ApiHttpServer(Federation(...))`` serves the identical v1 wire contract.

The **v2 admin control plane** (``repro.api.admin``) rides on top:
``federation.admin`` is the shared :class:`AdminPlane` (tenants, shards,
migrations as resources), ``federation.admin_api`` the admin-scoped
gateway over it, and ``tick()`` advances live tenant migrations one phase
per round after the shard ticks.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.api.admin import AdminGateway, AdminPlane
from repro.api.auth import AuthService
from repro.api.gateway import ApiGateway
from repro.api.lb import LoadBalancer
from repro.api.router import TenantRouter
from repro.core.faults import DeadlineExceeded, FaultPlane, deadline_scope

# Shard i mints job ids from i*STRIDE + 1: globally unique, still matching
# the wire's ``job-\d+`` shape, and ordered within every shard.
JOB_ID_STRIDE = 1_000_000

# Per-shard tick budget (seconds, wall clock). A gray-failed shard whose
# tick hangs would otherwise wedge the federation's whole ticker thread;
# instead the tick raises DeadlineExceeded at the budget, the shard's
# breaker records the overrun, and the fleet keeps ticking.
DEFAULT_TICK_BUDGET_S = 5.0


class Federation:
    def __init__(self, n_shards: int = 2, n_api_replicas: int = 3,
                 seed: int = 0, shared_reads: bool = True,
                 pins: Optional[Dict[str, str]] = None,
                 tick_budget_s: float = DEFAULT_TICK_BUDGET_S,
                 **platform_kwargs):
        # lazy import: repro.core.platform itself imports repro.api.*
        from repro.core.platform import FfDLPlatform
        # Construction recipe kept so the operator can mint identical
        # shards at scale-up time (add_shard).
        self._seed = seed
        self._shared_reads = shared_reads
        self._platform_kwargs = dict(platform_kwargs)
        self._next_shard_idx = max(1, n_shards)
        self.tick_budget_s = tick_budget_s
        # ONE fault plane for the whole fleet: every shard's interposition
        # points draw from this seeded registry, and one /v2/admin/faults
        # surface controls it all.
        self.faults = FaultPlane(seed=seed)
        self.shards = [
            FfDLPlatform(shard_id=f"shard-{i}",
                         job_id_base=i * JOB_ID_STRIDE,
                         shared_reads=shared_reads,
                         n_api_replicas=1,  # shards' own tiers are unused
                         seed=seed + i, fault_plane=self.faults,
                         **platform_kwargs)
            for i in range(max(1, n_shards))]
        # Reuse each platform's OWN Backend: one lock per shard, shared by
        # every front (the shard's vestigial tier and this federation).
        self.backends = [p.backend for p in self.shards]
        self.router = TenantRouter(self.backends, pins=pins)
        self.auth = AuthService(seed=seed)
        self.api_replicas = [
            ApiGateway(self.router, self.auth, replica_id=f"api-{i}")
            for i in range(max(1, n_api_replicas))]
        self.api = LoadBalancer(self.api_replicas)
        # v2 admin control plane: one shared plane, admin-scoped gateway
        self.admin = AdminPlane(self.router, self.auth)
        self.admin.faults = self.faults
        self.admin_api = AdminGateway(self.admin, self.auth)
        # autonomous operator (repro.api.ops.install_operator attaches one)
        self.operator = None
        # v2 workloads plane: declarative manifests + the reconciler that
        # converges them once per tick (after admin.advance/operator.step)
        from repro.workloads import (WorkloadGateway, WorkloadPlane,
                                     WorkloadReconciler)
        self.workloads = WorkloadPlane(self.router, self.auth)
        self.workloads_api = WorkloadGateway(self.workloads, self.auth)
        self.reconciler = WorkloadReconciler(self, self.workloads)

    # -- routing ----------------------------------------------------------
    def pin(self, tenant: str, shard_id: str):
        """Place a tenant on a named shard (overrides hash routing)."""
        self.router.pin(tenant, shard_id)

    def shard_of(self, tenant: str) -> str:
        return self.router.shard_for(tenant).shard_id

    # -- admin convenience (the wire surface is repro.api.admin) ----------
    def migrate(self, tenant: str, to_shard: str) -> str:
        """Start a live tenant migration; returns the migration id. The
        state machine advances one phase per ``tick()``."""
        return self.admin.start_migration(tenant, to_shard)["migration_id"]

    # -- elasticity (driven by repro.obs.operator) -------------------------
    def add_shard(self) -> str:
        """Mint a fresh shard with the federation's own construction recipe
        and join it to the fleet. Returns the new shard id.

        Routing safety: appending a backend changes the tenant-hash
        modulus, so BEFORE the list grows every tenant with state anywhere
        (plane spec, pin, or job records) is force-pinned to the shard it
        currently routes to — its placement cannot jump. Only tenants the
        platform has never seen re-hash over the larger fleet.
        """
        from repro.core.platform import FfDLPlatform
        with self.admin._mutex:
            known = set(self.admin.tenants) | set(self.router.pins)
            for b in self.backends:
                if b.alive:
                    with b.read_locked():
                        known |= {t for t, ids in
                                  b.platform.meta._by_tenant.items() if ids}
            for tenant in sorted(known):
                self.router._force_pin(
                    tenant, self.router.shard_for(tenant).shard_id)
            i = self._next_shard_idx
            self._next_shard_idx += 1
            p = FfDLPlatform(shard_id=f"shard-{i}",
                             job_id_base=i * JOB_ID_STRIDE,
                             shared_reads=self._shared_reads,
                             n_api_replicas=1,
                             seed=self._seed + i, fault_plane=self.faults,
                             **self._platform_kwargs)
            self.shards.append(p)
            self.backends.append(p.backend)
            # The router holds its OWN copy of the backend list — register
            # with both, or the new shard is invisible to routing.
            self.router.backends.append(p.backend)
            self.router._by_id[p.backend.shard_id] = p.backend
            # Tenant quotas follow the tenant to ANY shard: register every
            # existing quota with the new shard's admission controller.
            for spec in self.admin.tenants.values():
                if spec.quota_chips is not None:
                    p.admission.register_tenant(
                        spec.name, spec.quota_chips, tier=spec.tier)
            return p.backend.shard_id

    def retire_shard(self, shard_id: str):
        """Fence a drained shard out of the fleet: cordoned + no longer
        ticked. It stays in the router (hash modulus, composite cursors)."""
        self.router.backend(shard_id).retire()

    # -- engine -----------------------------------------------------------
    def tick(self):
        """One round on every live shard, each under its OWN write lock —
        reads on other shards are never blocked by this shard's tick.
        Live tenant migrations advance one phase per round afterwards,
        then the autonomous operator (when installed) reconciles once,
        then the workloads reconciler converges applied manifests.

        Each shard tick runs under a wall-clock deadline budget
        (``tick_budget_s``): a shard whose tick hangs or runs long (gray
        failure) raises out of its scope instead of wedging the ticker
        thread, its breaker records the overrun (feeding the quarantine
        the gateway enforces), and the remaining shards still tick."""
        for backend in self.backends:
            if not backend.alive or backend.retired:
                continue
            try:
                with backend.write_locked(), \
                        deadline_scope(self.tick_budget_s):
                    backend.platform.tick()
            except DeadlineExceeded:
                backend.breaker.record_failure(deadline=True)
                if backend.platform.events is not None:
                    backend.platform.events.emit(
                        "federation", "shard_tick_deadline",
                        shard=backend.shard_id,
                        budget_s=self.tick_budget_s)
        self.admin.advance()
        if self.operator is not None:
            self.operator.step()
        self.reconciler.step()

    def run_for(self, sim_seconds: float):
        n = int(sim_seconds / self.shards[0].tick_period)
        for _ in range(n):
            self.tick()

    # -- chaos ------------------------------------------------------------
    def shard_crash(self, shard: int):
        self.backends[shard].crash()

    def shard_restart(self, shard: int):
        self.backends[shard].restart()

    def api_crash(self, replica: Optional[int] = None):
        targets = (self.api_replicas if replica is None
                   else [self.api_replicas[replica]])
        for r in targets:
            r.alive = False

    def api_restart(self, replica: Optional[int] = None):
        targets = (self.api_replicas if replica is None
                   else [self.api_replicas[replica]])
        for r in targets:
            if not r.alive:
                r.restart()
