"""ApiGateway: one stateless API-tier replica over routed shards (FfDL §3.2).

"The API layer stores all the metadata in MongoDB before acknowledging the
request" — and the tier itself is a set of replicated, stateless REST
services in front of *independently scalable* backends (the paper shards
its MongoDB metastore and scales each microservice on its own). Each
:class:`ApiGateway` instance is one such replica; it holds **no** platform
of its own. Instead every v1 verb:

  1. authenticates the caller (shared :class:`AuthService`);
  2. resolves the caller's shard through the :class:`TenantRouter`
     (hash-by-tenant, pin-table override) — a dead shard answers
     ``UNAVAILABLE`` for *its* tenants only, before any side effect;
  3. takes **that shard's** lock — read verbs (``status``, ``list_jobs``,
     ``logs``, ``search_logs``, ``status_history``) share a reader lock,
     write verbs (``submit``, ``halt``, ``resume``, ``cancel``) take it
     exclusively. A read on shard A never serializes behind a submit on
     shard B, replacing the old single global ``server.lock``.

Cross-shard surfaces stay contract-compatible: an admin ``list_jobs`` (and
admin log search) over a multi-shard federation merges per-shard pages
behind a composite cursor (see :mod:`repro.api.router`); on a single shard
the wire cursors are byte-identical to the pre-federation ones. Replicas
stay individually crashable (``crash()``/``restart()``) and the
``LoadBalancer`` masks them exactly as before.

``logs`` additionally supports a bounded long-poll (``wait_ms``, capped at
10s): when the cursor is at the end of the stream, the call parks —
WITHOUT holding the shard lock — until new lines land or the job goes
terminal, which is what ``ffdl logs --follow`` rides on. ``status``
supports the same machinery for watching (``wait_ms`` + ``last_status``:
park until the status changes), behind ``ffdl status --watch``.
"""

from __future__ import annotations

import functools
import inspect
import re
import threading
import time
from contextlib import contextmanager
from dataclasses import asdict
from typing import Optional

from repro.api.auth import AuthService, Principal, READ, WRITE
from repro.api.router import (
    JOB_CURSOR_RE,
    OFFSET_CURSOR_RE,
    TenantRouter,
    encode_composite_cursor,
    parse_composite_cursor,
)
from repro.api.types import (
    ApiError,
    ErrorCode,
    JobView,
    Page,
    SubmitRequest,
    SubmitResponse,
    check_version,
)
from repro.core.faults import DeadlineExceeded, deadline_scope
from repro.core.types import (
    TRAIN_SPEC_FIELDS,
    JobStatus,
    TERMINAL,
    gang_chips,
    unknown_spec_fields,
)
from repro.obs import UsageMeter, event_to_wire

DEFAULT_PAGE = 20
# /v2/events default page size (its own knob: event pages are cheap —
# no metastore projection — so the default is bigger than DEFAULT_PAGE)
DEFAULT_EVENTS_PAGE = 100
# Upper bound on any page size: one tenant must not be able to drag the
# whole metastore/log index through a single call (multi-tenant fairness).
MAX_PAGE = 1000
# logs long-poll: hard server-side cap on how long one call may park, and
# how often a parked call re-checks the (lock-free-released) shard.
MAX_WAIT_MS = 10_000
_POLL_S = 0.02
# Per-verb deadline budget (seconds). Every v1 verb runs inside a
# repro.core.faults.deadline_scope of this much (plus the caller's
# wait_ms for long-poll verbs): lock waits, injected latency, and
# injected hangs all observe it, so no request can block past its
# budget — a wedged shard answers DEADLINE_EXCEEDED instead of
# stalling the caller. Generous by default (normal verbs finish in
# microseconds-to-milliseconds); gray-failure drills tighten it.
DEFAULT_VERB_BUDGET_S = 10.0

# Which backend this thread's in-flight verb touched, for breaker
# outcome attribution when the deadline fires mid-verb.
_VERB_TLS = threading.local()


def _deadlined(fn):
    """Wrap a public v1 verb in a deadline scope + breaker accounting.

    On :class:`DeadlineExceeded` the touched shard's breaker records a
    failure and the caller gets the stable ``DEADLINE_EXCEEDED`` code
    (HTTP 504). NOT LB-retryable: every replica fronts the same shard,
    so failing over would just burn another full budget. A normal
    return records a breaker success; other ApiErrors are neutral (the
    shard answered — promptly — even if the answer was an error)."""
    sig = inspect.signature(fn)
    has_wait = "wait_ms" in sig.parameters
    name = fn.__name__

    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        wait_s = 0.0
        if has_wait:
            try:
                bound = sig.bind(self, *args, **kwargs)
                w = bound.arguments.get("wait_ms")
                if isinstance(w, int) and not isinstance(w, bool) and w > 0:
                    wait_s = min(w, MAX_WAIT_MS) / 1000.0
            except TypeError:
                pass  # let fn raise its own signature error
        budget = self.verb_budget_s + wait_s
        _VERB_TLS.backend = None
        try:
            with deadline_scope(budget):
                plane = self._fault_plane()
                if plane is not None:
                    plane.on("gateway.dispatch", key=name,
                             exc=lambda m: ApiError(ErrorCode.UNAVAILABLE,
                                                    m, injected=True))
                out = fn(self, *args, **kwargs)
        except DeadlineExceeded:
            backend = getattr(_VERB_TLS, "backend", None)
            details = {"verb": name, "budget_s": round(budget, 3)}
            if backend is not None:
                backend.breaker.record_failure(deadline=True)
                details["shard"] = backend.shard_id
            raise ApiError(ErrorCode.DEADLINE_EXCEEDED,
                           f"{name} exceeded its {budget:.2f}s deadline "
                           f"budget", **details)
        backend = getattr(_VERB_TLS, "backend", None)
        if backend is not None:
            backend.breaker.record_success()
        return out
    return wrapper


def _parse_limit(limit):
    """Page sizes must be positive; 0/negative would corrupt cursors
    (skipped records, non-advancing pagination loops). Oversized pages are
    rejected rather than clamped so clients learn the real contract."""
    if limit is not None and (not isinstance(limit, int) or limit < 1):
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       f"limit must be a positive integer, got {limit!r}")
    if limit is not None and limit > MAX_PAGE:
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       f"limit {limit} exceeds maximum page size {MAX_PAGE}")
    return limit


def _parse_job_cursor(cursor):
    """list_jobs cursors are job ids minted by jobs_page; anything else
    would silently compare lexically against real ids and return an empty
    listing — reject it with the stable code instead."""
    if cursor is not None and not re.fullmatch(r"job-\d+", str(cursor)):
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       f"malformed cursor: {cursor!r}")
    return cursor


def _parse_cursor(cursor) -> int:
    """Offset cursors are opaque to clients; reject anything malformed
    with a stable code instead of leaking a raw ValueError."""
    if cursor is None:
        return 0
    try:
        n = int(cursor)
    except (TypeError, ValueError):
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       f"malformed cursor: {cursor!r}")
    if n < 0:
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       f"malformed cursor: {cursor!r}")
    return n


def _parse_wait_ms(wait_ms) -> int:
    """Long-poll budget: a non-negative integer, capped at MAX_WAIT_MS so
    one parked call can never pin a handler thread indefinitely."""
    if wait_ms is None:
        return 0
    if not isinstance(wait_ms, int) or isinstance(wait_ms, bool) \
            or wait_ms < 0:
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       f"wait_ms must be a non-negative integer, "
                       f"got {wait_ms!r}")
    return min(wait_ms, MAX_WAIT_MS)


def _parse_last_status(last_status) -> Optional[JobStatus]:
    """The status the watcher has already seen; anything that is not a
    JobStatus value would park forever (it can never equal the record)."""
    if last_status is None:
        return None
    try:
        return JobStatus(last_status)
    except ValueError:
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       f"unknown status {last_status!r}")


@contextmanager
def _meta_guard():
    """Translate metastore outages into the stable UNAVAILABLE code."""
    try:
        yield
    except ConnectionError as e:
        raise ApiError(ErrorCode.UNAVAILABLE, str(e) or "metastore down")


def _shard_down(backend) -> ApiError:
    """A dead shard is UNAVAILABLE for its tenants only. ``shard_down``
    tells the LoadBalancer not to burn failovers on it: every replica
    routes the tenant to the same dead shard, unlike a dead replica."""
    return ApiError(ErrorCode.UNAVAILABLE,
                    f"shard {backend.shard_id} is down",
                    shard=backend.shard_id, shard_down=True)


def _breaker_open(backend) -> ApiError:
    """A gray-failed (wedged-but-alive) shard is quarantined exactly like
    a dead one: fast UNAVAILABLE with ``shard_down`` so the LB does not
    burn failovers, plus ``breaker_open`` so clients/operators can tell
    quarantine from crash. ``retry_after`` hints the half-open probe
    cadence."""
    return ApiError(ErrorCode.UNAVAILABLE,
                    f"shard {backend.shard_id} is quarantined "
                    f"(circuit breaker open)",
                    shard=backend.shard_id, shard_down=True,
                    breaker_open=True, retry_after=1.0)


class ApiGateway:
    # per-verb deadline budget; instances may tighten it (drills do)
    verb_budget_s = DEFAULT_VERB_BUDGET_S

    def __init__(self, router: TenantRouter, auth: AuthService,
                 replica_id: str = "api-0", events=None):
        self.router = router
        self.auth = auth
        self.replica_id = replica_id
        self.event_log = events  # the owning shard's bus (verb `events` differs)
        self.alive = True

    def _fault_plane(self):
        """The fleet-wide FaultPlane (every shard of a federation shares
        one; a standalone platform owns its own)."""
        backends = self.router.backends
        if not backends:
            return None
        return getattr(backends[0].platform, "faults", None)

    # -- replica lifecycle (chaos) --------------------------------------
    def crash(self):
        self.alive = False
        if self.event_log is not None:
            self.event_log.emit("api", "replica_crashed",
                             replica=self.replica_id)

    def restart(self):
        self.alive = True
        if self.event_log is not None:
            self.event_log.emit("api", "api_restarted", replica=self.replica_id)

    def _require(self, api_key: str, scope: str) -> Principal:
        # Liveness first: a dead replica fails before touching any state.
        if not self.alive:
            raise ApiError(ErrorCode.UNAVAILABLE,
                           f"replica {self.replica_id} is down",
                           replica=self.replica_id)
        return self.auth.require(api_key, scope)

    # -- shard resolution -------------------------------------------------
    def _check_backend(self, backend):
        """Liveness + breaker gate, plus deadline-attribution note: every
        path that is about to touch a shard funnels through here."""
        if not backend.alive:
            raise _shard_down(backend)
        if not backend.breaker.allow():
            raise _breaker_open(backend)
        _VERB_TLS.backend = backend
        return backend

    def _shard_for(self, tenant: str):
        return self._check_backend(self.router.shard_for(tenant))

    def _sole_shard(self):
        return self._check_backend(self.router.backends[0])

    def _locate(self, principal: Principal, job_id: str):
        """The shard that owns ``job_id`` for this caller.

        A tenant key only ever looks on the tenant's own shard — a job id
        minted by another shard is NOT_FOUND for it, never data (tenant
        isolation holds across shards exactly as within one). An admin key
        scans shards (read-locking one at a time); a copy found on a shard
        the job's tenant is NOT routed to (the half-imported destination of
        a live migration) is skipped in favour of the routed source of
        truth. If the job is nowhere but some shard was down, the honest
        answer is UNAVAILABLE, not NOT_FOUND.
        """
        if not principal.is_admin:
            return self._shard_for(principal.tenant)
        dead = None
        unrouted_tenant = None
        for backend in self.router.backends:
            # a breaker-quarantined shard is skipped exactly like a dead
            # one: scanning it would wedge the whole admin walk
            if not backend.alive or not backend.breaker.allow():
                dead = backend
                continue
            _VERB_TLS.backend = backend
            with backend.read_locked(), _meta_guard():
                rec = backend.platform.meta.get(job_id)
            if rec is not None:
                if self.router.shard_for(rec.manifest.tenant) is backend:
                    return backend
                unrouted_tenant = rec.manifest.tenant
        if unrouted_tenant is not None:
            # only a mid-migration copy exists and its source of truth is
            # unreachable — never serve the stale import
            raise _shard_down(self.router.shard_for(unrouted_tenant))
        if dead is not None:
            raise (_shard_down(dead) if not dead.alive
                   else _breaker_open(dead))
        raise ApiError(ErrorCode.NOT_FOUND, f"no such job: {job_id}",
                       job_id=job_id)

    @contextmanager
    def _tenant_locked(self, tenant: str, write: bool = False):
        """The tenant's backend with its lock held AND the routing verified
        under that lock. A migration cutover flips the pin table while
        holding both shards' write locks, so a verb that resolved the old
        shard but acquired its lock only after the flip re-resolves — an
        in-flight request can never observe a half-moved tenant."""
        while True:
            backend = self._shard_for(tenant)
            ctx = (backend.write_locked() if write
                   else backend.read_locked())
            with ctx:
                if self.router.shard_for(tenant) is backend:
                    yield backend
                    return
            # pin flipped while we waited for the lock: retry on the new one

    @contextmanager
    def _job_locked(self, principal: Principal, job_id: str,
                    write: bool = False):
        """Locate + lock + ownership-check in one step, stable across a
        concurrent migration cutover (re-locates once if the record moved
        between resolution and lock acquisition)."""
        attempt = 0
        while True:
            backend = self._locate(principal, job_id)
            ctx = (backend.write_locked() if write
                   else backend.read_locked())
            with ctx:
                moved = (not principal.is_admin and
                         self.router.shard_for(principal.tenant)
                         is not backend)
                if not moved:
                    with _meta_guard():
                        rec = backend.platform.meta.get(job_id)
                    if rec is None and principal.is_admin and attempt == 0:
                        pass  # moved since the admin scan: re-scan once
                    else:
                        if rec is None:
                            raise ApiError(ErrorCode.NOT_FOUND,
                                           f"no such job: {job_id}",
                                           job_id=job_id)
                        if not principal.owns(rec.manifest.tenant):
                            raise ApiError(
                                ErrorCode.FORBIDDEN,
                                f"job {job_id} belongs to another tenant",
                                job_id=job_id)
                        yield backend, rec
                        return
            attempt += 1

    # -- submit ----------------------------------------------------------
    @_deadlined
    def submit(self, api_key: str, req: SubmitRequest) -> SubmitResponse:
        principal = self._require(api_key, WRITE)
        check_version(req.api_version)
        m = req.manifest
        if not principal.owns(m.tenant):
            raise ApiError(ErrorCode.FORBIDDEN,
                           f"key for tenant {principal.tenant!r} cannot "
                           f"submit as {m.tenant!r}")
        if m.n_learners < 1 or m.chips_per_learner < 0:
            raise ApiError(ErrorCode.INVALID_ARGUMENT, "invalid manifest")
        # Spec hygiene: an unknown train key would be silently ignored by
        # the learner runtime — reject it here (both transports funnel
        # through this verb) so manifest typos can't mask themselves.
        bad = unknown_spec_fields(m)
        if bad:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           f"unknown train spec fields: {bad} "
                           f"(known: {list(TRAIN_SPEC_FIELDS)})")
        # about to create records: if the tenant's hash shard is cordoned,
        # make the reroute sticky so an uncordon can't orphan the records
        self.router.pin_for_write(m.tenant)
        with self._tenant_locked(m.tenant, write=True) as backend:
            p = backend.platform
            if gang_chips(m) > p.cluster.total_chips:
                raise ApiError(
                    ErrorCode.INVALID_ARGUMENT,
                    f"job needs {gang_chips(m)} chips; cluster has "
                    f"{p.cluster.total_chips}")
            with _meta_guard():
                if req.idempotency_key is not None:
                    existing = p.meta.find_idempotent(m.tenant,
                                                      req.idempotency_key)
                    if existing is not None:
                        # same key + different payload is a client bug:
                        # surface it instead of silently dropping the job
                        prior = p.meta.get(existing)
                        if prior is not None and \
                                asdict(prior.manifest) != asdict(m):
                            raise ApiError(
                                ErrorCode.CONFLICT,
                                f"idempotency key {req.idempotency_key!r} "
                                f"was already used for {existing} with a "
                                f"different manifest", job_id=existing)
                        p.events.emit("api", "submit_deduplicated",
                                      job=existing, tenant=m.tenant,
                                      replica=self.replica_id)
                        return SubmitResponse(job_id=existing,
                                              deduplicated=True)
                ok, why = p.admission.check(m)
                if not ok:
                    p.events.emit("api", "admission_rejected",
                                  tenant=m.tenant, reason=why)
                    raise ApiError(ErrorCode.QUOTA_EXCEEDED,
                                   f"admission denied: {why}")
                job_id = p._next_job_id()
                # durable BEFORE ack (idempotency rides the same WAL op)
                p.meta.insert_job(job_id, m,
                                  idempotency_key=req.idempotency_key)
                p.admission.mark(job_id, m)
            p.events.emit("api", "job_submitted", job=job_id,
                          tenant=m.tenant, replica=self.replica_id,
                          shard=backend.shard_id)
        return SubmitResponse(job_id=job_id)

    # -- reads -----------------------------------------------------------
    @_deadlined
    def status(self, api_key: str, job_id: str,
               wait_ms: Optional[int] = None,
               last_status: Optional[str] = None) -> JobView:
        """One job's JobView; with ``wait_ms`` + ``last_status``, a watch
        long-poll: the call parks — OFF the shard lock, same machinery as
        the logs long-poll — until the status differs from ``last_status``,
        the job goes terminal, or the budget runs out. ``ffdl status
        --watch`` / ``ApiClient.watch_status`` loop on exactly this."""
        principal = self._require(api_key, READ)
        last = _parse_last_status(last_status)
        deadline = time.monotonic() + _parse_wait_ms(wait_ms) / 1000.0
        while True:
            # re-resolve every round: a migration cutover may move the
            # tenant between polls, and a parked watcher must follow it
            with self._job_locked(principal, job_id) as (backend, rec):
                view = JobView.of(rec)  # project under the lock
                terminal = rec.status in TERMINAL
            if last is None or view.status != last.value or terminal \
                    or time.monotonic() >= deadline:
                return view
            # Park OUTSIDE the shard lock: a watcher must never block the
            # ticker (writer) or other readers while it waits.
            time.sleep(_POLL_S)

    @_deadlined
    def status_history(self, api_key: str, job_id: str) -> list:
        principal = self._require(api_key, READ)
        with self._job_locked(principal, job_id) as (_backend, rec):
            return list(rec.status_history)

    @_deadlined
    def list_jobs(self, api_key: str, tenant: Optional[str] = None,
                  status: Optional[JobStatus] = None,
                  cursor: Optional[str] = None,
                  limit: int = DEFAULT_PAGE) -> "Page[JobView]":
        principal = self._require(api_key, READ)
        if tenant is None:
            tenant = None if principal.is_admin else principal.tenant
        elif not principal.owns(tenant):
            raise ApiError(ErrorCode.FORBIDDEN,
                           f"cannot list jobs of tenant {tenant!r}")
        limit = _parse_limit(limit) or DEFAULT_PAGE
        if tenant is None and len(self.router.backends) > 1:
            return self._list_jobs_federated(status, cursor, limit)
        if tenant is not None:
            with self._tenant_locked(tenant) as backend, _meta_guard():
                recs, next_cursor = backend.platform.meta.jobs_page(
                    tenant=tenant, status=status,
                    cursor=_parse_job_cursor(cursor), limit=limit)
                # project INSIDE the lock: a concurrent tick may mutate the
                # records the moment we release it (torn status/finished_at)
                items = [JobView.of(r) for r in recs]
            return Page(items=items, next_cursor=next_cursor)
        backend = self._sole_shard()
        with backend.read_locked(), _meta_guard():
            recs, next_cursor = backend.platform.meta.jobs_page(
                tenant=tenant, status=status,
                cursor=_parse_job_cursor(cursor), limit=limit)
            items = [JobView.of(r) for r in recs]
        return Page(items=items, next_cursor=next_cursor)

    def _hidden_import(self, backend, tenant: str) -> bool:
        """True for records living on the DESTINATION shard of the
        tenant's live migration: the half-imported copy must stay
        invisible to cross-shard reads until cutover makes it the routed
        source of truth (otherwise an admin walk would serve the same job
        from both shards)."""
        return self.router.migration_target(tenant) == backend.shard_id

    def _mint_span(self, backend) -> tuple:
        """The id interval ``(lo, hi]`` (as job-id strings, ``hi`` None =
        unbounded) that ``backend`` mints from: ``job_id_base`` up to the
        next shard's base. Every job id belongs to exactly one shard's
        span, for life — even after a migration moves the record."""
        base = getattr(backend.platform, "job_id_base", 0)
        later = [b2 for b in self.router.backends
                 if (b2 := getattr(b.platform, "job_id_base", 0)) > base]
        hi = min(later) if later else None
        return (f"job-{base:05d}",
                None if hi is None else f"job-{hi:05d}")

    def _stream_page(self, owner, status, cursors, need: int) -> list:
        """One page of ``owner``'s minting-id stream: its span's records
        in id order, collected from EVERY shard (a migration may have
        moved them) past the stream's cursor. Half-imported destination
        copies are hidden (the source still serves the id); equal ids are
        deduped keeping the routed copy. Advances the stream cursor."""
        lo, hi = self._mint_span(owner)
        cur = cursors.get(owner.shard_id)
        best: dict = {}  # job_id -> (is_routed_copy, JobView)
        for backend in self.router.backends:
            # a partial admin listing would silently hide a shard's
            # tenants; fail honestly instead (dead OR quarantined)
            self._check_backend(backend)
            with backend.read_locked(), _meta_guard():
                for r in backend.platform.meta.jobs_span(
                        lo=lo, hi=hi, status=status, cursor=cur,
                        limit=need):
                    if self._hidden_import(backend, r.manifest.tenant):
                        continue
                    routed = self.router.shard_for(r.manifest.tenant) \
                        is backend
                    prev = best.get(r.job_id)
                    if prev is None or (routed and not prev[0]):
                        best[r.job_id] = (routed, JobView.of(r))
        page = [best[jid][1] for jid in sorted(best)[:need]]
        if page:
            cursors[owner.shard_id] = page[-1].job_id
        return page

    def _list_jobs_federated(self, status, cursor, limit: int) -> Page:
        """Admin all-tenant listing over >1 shard, merged behind a
        composite cursor with one entry per shard's **minting-id
        stream** — the contiguous id interval the shard mints from. A
        record belongs to its minting stream for life, wherever a
        migration moves it, so the stream cursor keeps meaning "every id
        up to here was served" across any number of cutovers: items never
        repeat and never go missing, even when a migration starts AND
        finishes between two pages of the walk. Submits that land
        mid-iteration on a still-open stream are served by a later page;
        a stream that answers an EMPTY page is marked exhausted in the
        cursor and never queried again for the rest of the walk — long
        admin walks stop paying one probe per drained shard per page."""
        cursors, exhausted = parse_composite_cursor(cursor, self.router,
                                                    JOB_CURSOR_RE)
        items: list = []
        for owner in self.router.backends:
            sid = owner.shard_id
            if sid in exhausted:
                continue
            if len(items) >= limit:
                break
            while len(items) < limit:
                need = limit - len(items)
                page = self._stream_page(owner, status, cursors, need)
                if not page:
                    exhausted.add(sid)  # final page already served
                    break
                items += page
                if len(page) < need:
                    break  # stream dry for NOW — stays open so submits
                    #        landing mid-iteration are served later
        next_cursor = (encode_composite_cursor(cursors, exhausted)
                       if len(items) == limit else None)
        return Page(items=items, next_cursor=next_cursor)

    @_deadlined
    def logs(self, api_key: str, job_id: str, cursor: Optional[str] = None,
             limit: Optional[int] = None,
             wait_ms: Optional[int] = None) -> "Page[str]":
        principal = self._require(api_key, READ)
        start = _parse_cursor(cursor)
        limit = _parse_limit(limit) or MAX_PAGE
        budget_s = _parse_wait_ms(wait_ms) / 1000.0
        deadline = time.monotonic() + budget_s
        while True:
            # re-resolve every round: a cutover may move the tenant while
            # a follower is parked; per-job log offsets survive the move,
            # so the SAME cursor keeps meaning the same line
            with self._job_locked(principal, job_id) as (backend, rec):
                # no limit means "a full page", never "the whole stream":
                # MAX_PAGE bounds every single call
                lines, next_off = backend.platform.log_index.stream_page(
                    job_id, cursor=start, limit=limit)
                terminal = rec.status in TERMINAL
            if lines or terminal or time.monotonic() >= deadline:
                break
            # Park OUTSIDE the shard lock: a long-poll must never block
            # the ticker (writer) or other readers while it waits.
            time.sleep(_POLL_S)
        if budget_s > 0:
            # Follow-mode cursor contract: next_cursor stays set (the
            # resume offset) until the job is terminal AND fully consumed,
            # so `logs --follow` can keep polling from it.
            done = terminal and next_off is None
            next_off = None if done else start + len(lines)
        return Page(items=lines,
                    next_cursor=None if next_off is None else str(next_off))

    @_deadlined
    def search_logs(self, api_key: str, query: str,
                    job_id: Optional[str] = None,
                    cursor: Optional[str] = None,
                    limit: Optional[int] = None) -> "Page":
        principal = self._require(api_key, READ)
        limit = _parse_limit(limit) or MAX_PAGE
        if job_id is None and principal.is_admin \
                and len(self.router.backends) > 1:
            return self._search_logs_federated(query, cursor, limit)
        start = _parse_cursor(cursor)
        if job_id is not None:
            with self._job_locked(principal, job_id) as (backend, _rec):
                recs, next_cursor = backend.platform.log_index.search_page(
                    query, job_id=job_id, cursor=start, limit=limit,
                    allow=None)
        elif principal.is_admin:
            backend = self._sole_shard()
            with backend.read_locked():
                recs, next_cursor = backend.platform.log_index.search_page(
                    query, job_id=None, cursor=start, limit=limit,
                    allow=None)
        else:
            with self._tenant_locked(principal.tenant) as backend:
                allow = self._tenant_filter(backend, principal)
                recs, next_cursor = backend.platform.log_index.search_page(
                    query, job_id=None, cursor=start, limit=limit,
                    allow=allow)
        return Page(items=recs,
                    next_cursor=None if next_cursor is None
                    else str(next_cursor))

    @staticmethod
    def _tenant_filter(backend, principal: Principal):
        tenant_of: dict = {}

        def allow(jid, _memo=tenant_of):
            if jid not in _memo:
                with _meta_guard():
                    rec = backend.platform.meta.get(jid)
                _memo[jid] = rec.manifest.tenant if rec else None
            return _memo[jid] == principal.tenant

        return allow

    def _fed_search_allow(self, backend):
        """Cross-shard search filter: hide lines of jobs this shard's
        metastore does not know (tombstoned leftovers) and of tenants
        whose live migration is importing INTO this shard (the half-moved
        copy — the routed source shard still serves those lines). The
        hidden set is computed ONCE per page, not per scanned record; the
        per-record check is then two dict probes. Caller holds the
        shard's read lock."""
        meta = backend.platform.meta
        with _meta_guard():
            meta._check()  # one availability check for the whole page
        hidden: set = set()
        for tenant in self.router.migrating_into(backend.shard_id):
            hidden.update(meta._by_tenant.get(tenant, ()))
        jobs = meta._jobs

        def allow(jid, _jobs=jobs, _hidden=hidden):
            return jid in _jobs and jid not in _hidden
        return allow

    def _search_logs_federated(self, query: str, cursor, limit: int) -> Page:
        """Admin all-shard log search: same composite-cursor merge (and
        exhausted-shard markers) as the federated listing, with per-shard
        append offsets as cursors."""
        cursors, exhausted = parse_composite_cursor(cursor, self.router,
                                                    OFFSET_CURSOR_RE)
        items: list = []
        for backend in self.router.backends:
            sid = backend.shard_id
            if sid in exhausted:
                continue
            if len(items) >= limit:
                break
            self._check_backend(backend)
            need = limit - len(items)
            with backend.read_locked():
                recs, next_off = backend.platform.log_index.search_page(
                    query, cursor=int(cursors.get(sid, 0)),
                    limit=need, allow=self._fed_search_allow(backend))
                if next_off is None:
                    # scanned to the end: remember how far, so records
                    # appended later are still found by a later page —
                    # and an EMPTY scan closes the shard for this walk
                    next_off = len(backend.platform.log_index.records)
                    if not recs:
                        exhausted.add(sid)
            cursors[sid] = str(next_off)
            items += recs
        next_cursor = (encode_composite_cursor(cursors, exhausted)
                       if len(items) == limit else None)
        return Page(items=items, next_cursor=next_cursor)

    # -- lifecycle writes -------------------------------------------------
    @_deadlined
    def halt(self, api_key: str, job_id: str, requeue: bool = False):
        principal = self._require(api_key, WRITE)
        with self._job_locked(principal, job_id, write=True) \
                as (backend, rec):
            # a late/retried halt must never rewrite a terminal record
            # (COMPLETED → HALTED would let resume() re-run a finished job)
            if rec.status in TERMINAL:
                raise ApiError(ErrorCode.FAILED_PRECONDITION,
                               f"{job_id} is already {rec.status.value}")
            with _meta_guard():
                backend.platform._halt_internal(job_id, requeue=requeue)

    @_deadlined
    def resume(self, api_key: str, job_id: str):
        principal = self._require(api_key, WRITE)
        with self._job_locked(principal, job_id, write=True) \
                as (backend, rec):
            if rec.status != JobStatus.HALTED:
                raise ApiError(ErrorCode.FAILED_PRECONDITION,
                               f"{job_id} is not HALTED")
            with _meta_guard():
                backend.platform._resume_internal(job_id)

    @_deadlined
    def cancel(self, api_key: str, job_id: str):
        principal = self._require(api_key, WRITE)
        with self._job_locked(principal, job_id, write=True) \
                as (backend, rec):
            if rec.status in TERMINAL:
                raise ApiError(ErrorCode.FAILED_PRECONDITION,
                               f"{job_id} is already {rec.status.value}")
            with _meta_guard():
                backend.platform._cancel_internal(job_id)

    # -- observability plane (repro.obs) ----------------------------------
    @_deadlined
    def usage(self, api_key: str, tenant: Optional[str] = None) -> dict:
        """GET /v1/usage: per-tenant usage rows summed across every shard
        (a migrated tenant's history lives on both its shards' meters).
        A tenant key sees its own row; an admin key sees all tenants, or
        one with ``?tenant=``. Any dead shard fails the read — billing
        must never silently undercount."""
        principal = self._require(api_key, READ)
        if tenant is None:
            tenant = None if principal.is_admin else principal.tenant
        elif not principal.owns(tenant):
            raise ApiError(ErrorCode.FORBIDDEN,
                           f"cannot read usage of tenant {tenant!r}")
        snaps = []
        for backend in self.router.backends:
            self._check_backend(backend)
            with backend.read_locked():
                snaps.append(backend.platform.meter.snapshot())
        merged = UsageMeter.merge(snaps, tenant=tenant)
        if tenant is not None and tenant not in merged:
            merged[tenant] = UsageMeter().get(tenant)  # all-zero row
        items = [{"tenant": t,
                  **{f: (round(v, 3) if isinstance(v, float) else v)
                     for f, v in row.items()}}
                 for t, row in sorted(merged.items())]
        return {"items": items}

    @staticmethod
    def _parse_event_kind(kind):
        if kind is not None and (not isinstance(kind, str) or not kind):
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           f"kind must be a non-empty string, got {kind!r}")
        return kind

    @_deadlined
    def events(self, api_key: str, cursor: Optional[str] = None,
               limit: Optional[int] = None, kind: Optional[str] = None,
               wait_ms: Optional[int] = None) -> dict:
        """GET /v2/events: cursor replay over the platform event stream.

        Exactly-once per shard over successful responses: each page's
        ``next_cursor`` continues precisely after the last sequence number
        the page consumed, so a seq is never served twice on one cursor
        chain, and retention drops are reported explicitly as ``missed``
        (never silently skipped). A tenant key reads its own shard's
        stream filtered to its own events; an admin key reads everything —
        across a federation, behind the same composite-cursor scheme as
        the other admin walks. ``next_cursor`` is ALWAYS present: the
        stream has no end. With ``wait_ms`` the call parks (off the shard
        lock) until an event matches, for ``events --follow``."""
        principal = self._require(api_key, READ)
        limit = _parse_limit(limit) or DEFAULT_EVENTS_PAGE
        kind = self._parse_event_kind(kind)
        deadline = time.monotonic() + _parse_wait_ms(wait_ms) / 1000.0
        multi = principal.is_admin and len(self.router.backends) > 1
        while True:
            if multi:
                out = self._events_federated(cursor, limit, kind)
            else:
                out = self._events_single(principal, cursor, limit, kind)
            if out["items"] or time.monotonic() >= deadline:
                return out
            cursor = out["next_cursor"]  # keep the scan's progress
            time.sleep(_POLL_S)

    def _events_single(self, principal: Principal, cursor, limit: int,
                       kind) -> dict:
        if principal.is_admin:
            backend = self._sole_shard()
            visible = None
        else:
            backend = self._shard_for(principal.tenant)
            tenant = principal.tenant
            # tenant isolation: ONLY events stamped with this tenant;
            # unstamped platform-internal events are admin-only
            visible = (lambda e, _t=tenant: e.tenant == _t)
        cur = _parse_cursor(cursor)
        with backend.read_locked():
            evs, nxt, missed = backend.platform.events.read_since(
                cur, limit, visible=visible, kind=kind)
            items = [event_to_wire(e, backend.shard_id) for e in evs]
        return {"items": items, "next_cursor": str(nxt), "missed": missed}

    def _events_federated(self, cursor, limit: int, kind) -> dict:
        """Admin event walk over >1 shard: shard-major fill behind a
        composite cursor (one integer seq per shard). No exhausted
        markers — an event stream never ends — so every response carries
        the full composite for the next poll."""
        cursors, _exhausted = parse_composite_cursor(cursor, self.router,
                                                     OFFSET_CURSOR_RE)
        items: list = []
        missed = 0
        for backend in self.router.backends:
            sid = backend.shard_id
            if len(items) >= limit:
                break
            # a partial admin stream would silently lose a shard's
            # events for this page; fail honestly, cursor unchanged
            self._check_backend(backend)
            need = limit - len(items)
            with backend.read_locked():
                evs, nxt, m = backend.platform.events.read_since(
                    int(cursors.get(sid, 0)), need, kind=kind)
                items += [event_to_wire(e, sid) for e in evs]
            cursors[sid] = str(nxt)
            missed += m
        return {"items": items,
                "next_cursor": encode_composite_cursor(cursors, set()),
                "missed": missed}
