"""ApiGateway: one stateless API-tier replica (FfDL §3.2).

"The API layer stores all the metadata in MongoDB before acknowledging the
request" — and the tier itself is a set of replicated, stateless REST
services: any replica can serve any request, and a crashed replica loses
nothing because all state lives in the metastore.

Each :class:`ApiGateway` instance is one such replica. It is individually
crashable (``crash()``/``restart()``); while down, every call raises
``ApiError(UNAVAILABLE)`` *before any side effect*, so the load balancer
can transparently retry on a healthy sibling. All replicas implement the
full v1 surface:

  * ``submit`` — validate → authenticate → admission → **durable before
    ack** insert. Client-supplied idempotency keys are journaled with the
    insert, so a duplicate submit (same tenant + key) returns the original
    job id even after a metastore crash/recover;
  * ``status``/``status_history``/``list_jobs`` — tenant-scoped reads;
    listings are cursor-paginated;
  * ``logs``/``search_logs`` — cursor-paginated reads of the log index;
  * ``halt``/``resume``/``cancel`` — lifecycle writes, ownership-checked.

A metastore outage surfaces as ``UNAVAILABLE`` too (retryable — though all
replicas share the store, so the LB will exhaust them and propagate).
"""

from __future__ import annotations

import re
from contextlib import contextmanager
from dataclasses import asdict
from typing import Optional

from repro.api.auth import AuthService, Principal, READ, WRITE
from repro.api.types import (
    ApiError,
    ErrorCode,
    JobView,
    Page,
    SubmitRequest,
    SubmitResponse,
    check_version,
)
from repro.core.types import JobStatus, TERMINAL, gang_chips

DEFAULT_PAGE = 20
# Upper bound on any page size: one tenant must not be able to drag the
# whole metastore/log index through a single call (multi-tenant fairness).
MAX_PAGE = 1000


def _parse_limit(limit):
    """Page sizes must be positive; 0/negative would corrupt cursors
    (skipped records, non-advancing pagination loops). Oversized pages are
    rejected rather than clamped so clients learn the real contract."""
    if limit is not None and (not isinstance(limit, int) or limit < 1):
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       f"limit must be a positive integer, got {limit!r}")
    if limit is not None and limit > MAX_PAGE:
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       f"limit {limit} exceeds maximum page size {MAX_PAGE}")
    return limit


def _parse_job_cursor(cursor):
    """list_jobs cursors are job ids minted by jobs_page; anything else
    would silently compare lexically against real ids and return an empty
    listing — reject it with the stable code instead."""
    if cursor is not None and not re.fullmatch(r"job-\d+", str(cursor)):
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       f"malformed cursor: {cursor!r}")
    return cursor


def _parse_cursor(cursor) -> int:
    """Offset cursors are opaque to clients; reject anything malformed
    with a stable code instead of leaking a raw ValueError."""
    if cursor is None:
        return 0
    try:
        n = int(cursor)
    except (TypeError, ValueError):
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       f"malformed cursor: {cursor!r}")
    if n < 0:
        raise ApiError(ErrorCode.INVALID_ARGUMENT,
                       f"malformed cursor: {cursor!r}")
    return n


@contextmanager
def _meta_guard():
    """Translate metastore outages into the stable UNAVAILABLE code."""
    try:
        yield
    except ConnectionError as e:
        raise ApiError(ErrorCode.UNAVAILABLE, str(e) or "metastore down")


class ApiGateway:
    def __init__(self, platform, auth: AuthService, replica_id: str = "api-0"):
        self.p = platform
        self.auth = auth
        self.replica_id = replica_id
        self.alive = True

    # -- replica lifecycle (chaos) --------------------------------------
    def crash(self):
        self.alive = False
        self.p.events.emit("api", "replica_crashed", replica=self.replica_id)

    def restart(self):
        self.alive = True
        self.p.events.emit("api", "api_restarted", replica=self.replica_id)

    def _require(self, api_key: str, scope: str) -> Principal:
        # Liveness first: a dead replica fails before touching any state.
        if not self.alive:
            raise ApiError(ErrorCode.UNAVAILABLE,
                           f"replica {self.replica_id} is down",
                           replica=self.replica_id)
        return self.auth.require(api_key, scope)

    def _owned_record(self, principal: Principal, job_id: str):
        with _meta_guard():
            rec = self.p.meta.get(job_id)
        if rec is None:
            raise ApiError(ErrorCode.NOT_FOUND, f"no such job: {job_id}",
                           job_id=job_id)
        if not principal.owns(rec.manifest.tenant):
            raise ApiError(ErrorCode.FORBIDDEN,
                           f"job {job_id} belongs to another tenant",
                           job_id=job_id)
        return rec

    # -- submit ----------------------------------------------------------
    def submit(self, api_key: str, req: SubmitRequest) -> SubmitResponse:
        principal = self._require(api_key, WRITE)
        check_version(req.api_version)
        m = req.manifest
        if not principal.owns(m.tenant):
            raise ApiError(ErrorCode.FORBIDDEN,
                           f"key for tenant {principal.tenant!r} cannot "
                           f"submit as {m.tenant!r}")
        if m.n_learners < 1 or m.chips_per_learner < 0:
            raise ApiError(ErrorCode.INVALID_ARGUMENT, "invalid manifest")
        if gang_chips(m) > self.p.cluster.total_chips:
            raise ApiError(
                ErrorCode.INVALID_ARGUMENT,
                f"job needs {gang_chips(m)} chips; cluster has "
                f"{self.p.cluster.total_chips}")
        with _meta_guard():
            if req.idempotency_key is not None:
                existing = self.p.meta.find_idempotent(m.tenant,
                                                       req.idempotency_key)
                if existing is not None:
                    # same key + different payload is a client bug: surface
                    # it instead of silently dropping the new job
                    prior = self.p.meta.get(existing)
                    if prior is not None and \
                            asdict(prior.manifest) != asdict(m):
                        raise ApiError(
                            ErrorCode.CONFLICT,
                            f"idempotency key {req.idempotency_key!r} was "
                            f"already used for {existing} with a different "
                            f"manifest", job_id=existing)
                    self.p.events.emit("api", "submit_deduplicated",
                                       job=existing, tenant=m.tenant,
                                       replica=self.replica_id)
                    return SubmitResponse(job_id=existing, deduplicated=True)
            ok, why = self.p.admission.check(m)
            if not ok:
                self.p.events.emit("api", "admission_rejected",
                                   tenant=m.tenant, reason=why)
                raise ApiError(ErrorCode.QUOTA_EXCEEDED,
                               f"admission denied: {why}")
            job_id = self.p._next_job_id()
            # durable BEFORE ack (idempotency mapping rides the same WAL op)
            self.p.meta.insert_job(job_id, m,
                                   idempotency_key=req.idempotency_key)
            self.p.admission.mark(job_id, m)
        self.p.events.emit("api", "job_submitted", job=job_id, tenant=m.tenant,
                           replica=self.replica_id)
        return SubmitResponse(job_id=job_id)

    # -- reads -----------------------------------------------------------
    def status(self, api_key: str, job_id: str) -> JobView:
        principal = self._require(api_key, READ)
        return JobView.of(self._owned_record(principal, job_id))

    def status_history(self, api_key: str, job_id: str) -> list:
        principal = self._require(api_key, READ)
        return list(self._owned_record(principal, job_id).status_history)

    def list_jobs(self, api_key: str, tenant: Optional[str] = None,
                  status: Optional[JobStatus] = None,
                  cursor: Optional[str] = None,
                  limit: int = DEFAULT_PAGE) -> "Page[JobView]":
        principal = self._require(api_key, READ)
        if tenant is None:
            tenant = None if principal.is_admin else principal.tenant
        elif not principal.owns(tenant):
            raise ApiError(ErrorCode.FORBIDDEN,
                           f"cannot list jobs of tenant {tenant!r}")
        with _meta_guard():
            recs, next_cursor = self.p.meta.jobs_page(
                tenant=tenant, status=status,
                cursor=_parse_job_cursor(cursor),
                limit=_parse_limit(limit) or DEFAULT_PAGE)
        return Page(items=[JobView.of(r) for r in recs],
                    next_cursor=next_cursor)

    def logs(self, api_key: str, job_id: str, cursor: Optional[str] = None,
             limit: Optional[int] = None) -> "Page[str]":
        principal = self._require(api_key, READ)
        self._owned_record(principal, job_id)  # existence + ownership
        # no limit means "a full page", never "the whole stream": MAX_PAGE
        # bounds every single call (clients follow next_cursor)
        lines, next_cursor = self.p.log_index.stream_page(
            job_id, cursor=_parse_cursor(cursor),
            limit=_parse_limit(limit) or MAX_PAGE)
        return Page(items=lines,
                    next_cursor=None if next_cursor is None
                    else str(next_cursor))

    def search_logs(self, api_key: str, query: str,
                    job_id: Optional[str] = None,
                    cursor: Optional[str] = None,
                    limit: Optional[int] = None) -> "Page":
        principal = self._require(api_key, READ)
        if job_id is not None:
            self._owned_record(principal, job_id)
            allow = None
        elif principal.is_admin:
            allow = None
        else:
            tenant_of = {}

            def allow(jid, _memo=tenant_of):
                if jid not in _memo:
                    with _meta_guard():
                        rec = self.p.meta.get(jid)
                    _memo[jid] = rec.manifest.tenant if rec else None
                return _memo[jid] == principal.tenant
        recs, next_cursor = self.p.log_index.search_page(
            query, job_id=job_id, cursor=_parse_cursor(cursor),
            limit=_parse_limit(limit) or MAX_PAGE, allow=allow)
        return Page(items=recs,
                    next_cursor=None if next_cursor is None
                    else str(next_cursor))

    # -- lifecycle writes -------------------------------------------------
    def halt(self, api_key: str, job_id: str, requeue: bool = False):
        principal = self._require(api_key, WRITE)
        rec = self._owned_record(principal, job_id)
        # a late/retried halt must never rewrite a terminal record
        # (COMPLETED → HALTED would let resume() re-run a finished job)
        if rec.status in TERMINAL:
            raise ApiError(ErrorCode.FAILED_PRECONDITION,
                           f"{job_id} is already {rec.status.value}")
        with _meta_guard():
            self.p._halt_internal(job_id, requeue=requeue)

    def resume(self, api_key: str, job_id: str):
        principal = self._require(api_key, WRITE)
        rec = self._owned_record(principal, job_id)
        if rec.status != JobStatus.HALTED:
            raise ApiError(ErrorCode.FAILED_PRECONDITION,
                           f"{job_id} is not HALTED")
        with _meta_guard():
            self.p._resume_internal(job_id)

    def cancel(self, api_key: str, job_id: str):
        principal = self._require(api_key, WRITE)
        rec = self._owned_record(principal, job_id)
        if rec.status in TERMINAL:
            raise ApiError(ErrorCode.FAILED_PRECONDITION,
                           f"{job_id} is already {rec.status.value}")
        with _meta_guard():
            self.p._cancel_internal(job_id)
