"""Operator wiring: attach the autonomous reconciler to a federation.

``install_operator`` is the single composition point between the
observability plane's :class:`~repro.obs.operator.Operator` and the
serving stack: it hangs the operator off the federation (so
``Federation.tick`` runs one reconcile pass per round, after the shard
ticks and migration ``advance()``) and off the admin plane (so
``GET /v2/admin/operator`` and ``POST /v2/admin/operator/rollout`` reach
it through the ordinary admin gateway / transport / CLI chain).

Deployments that never call this keep exactly the PR-5 behaviour: a
human drives the v2 verbs, and the operator routes answer NOT_FOUND.
"""

from __future__ import annotations

from typing import Optional

from repro.obs.operator import Operator, OperatorConfig


def install_operator(federation,
                     config: Optional[OperatorConfig] = None) -> Operator:
    """Create an :class:`Operator` for ``federation`` and wire it into the
    tick loop and the admin plane. Idempotent-ish: installing again
    replaces the previous operator (fresh policy state)."""
    op = Operator(federation, config=config)
    federation.operator = op
    federation.admin.operator = op
    return op


def uninstall_operator(federation):
    """Detach the operator: the fleet goes back to human-driven."""
    federation.operator = None
    federation.admin.operator = None
