"""The v2 admin control plane: tenants, shards, and migrations as wire
resources (FfDL §3-4; Boag et al. 2018; Saxena et al. 2020).

FfDL's operators manage tenants, quotas, and cluster shards as first-class
platform objects, not as side effects of job verbs; the dependability
companion paper stresses operator-driven lifecycle actions as the main
lever for surviving faults, and the elastic-scaling work motivates moving
workloads between resource pools *without killing them*. This module is
that control plane for our reproduction:

  * **tenants** — create/get/list/patch/delete. A tenant resource carries
    its chip quota (registered with every shard's admission controller),
    its tier, an optional per-tenant rate-limit override (applied live to
    the HTTP tier's token buckets), and an optional shard pin;
  * **shards** — get/list with live occupancy (resident tenants, job
    counts, chips), plus cordon/uncordon and ``drain`` = migrate every
    resident tenant off, then cordon;
  * **migrations** — POST a tenant→shard move, GET its phase. The headline
    mechanism: a live rebalance through a four-phase state machine,

        SNAPSHOT  bulk-copy the tenant's metastore slice + logs while its
                  jobs keep running (WAL-consistent export at a journal
                  watermark);
        CATCHUP   re-export only the mutations that landed during the
                  copy; quiesce the tenant's running work through the
                  platform's own checkpoint-and-halt path (the same
                  machinery admission-control preemption uses);
        CUTOVER   under BOTH shards' write locks: final delta, move
                  volumes/checkpoints, purge the source, atomically flip
                  the pin table, resume the quiesced jobs on the
                  destination. No v1 verb can interleave, so in-flight
                  requests never observe a half-moved tenant — they
                  resolve the old shard before the locks or the new shard
                  after;
        DONE.

    Crash at any phase recovers to a consistent source-of-truth shard: a
    dead source or destination aborts the migration (``FAILED``), unlocks
    routing, resumes anything the quiesce halted back on the source, and
    purges the destination's partial import — either cleanup is deferred
    and retried every tick while its shard is down. Routing edits
    (``pin``/``unpin``) are frozen with ``FAILED_PRECONDITION`` while a
    tenant migrates.

Admin calls require an operator key carrying the ``admin`` scope
(``AuthService.issue_admin_key``); v2 envelopes are stamped
``"api_version": "v2"``. The v1 job data plane is bit-for-bit unchanged.
"""

from __future__ import annotations

import itertools
import threading
from collections import Counter
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional

from repro.api.auth import ADMIN, AuthService, Principal
from repro.api.ratelimit import RateLimitConfig
from repro.api.types import (ADMIN_API_VERSION, ApiError, ErrorCode,
                             deadline_guarded)
from repro.core.types import TERMINAL, JobStatus
from repro.data.objectstore import ObjectStoreError


class MigrationPhase(str, Enum):
    SNAPSHOT = "SNAPSHOT"
    CATCHUP = "CATCHUP"
    CUTOVER = "CUTOVER"
    DONE = "DONE"
    FAILED = "FAILED"


LIVE_PHASES = {MigrationPhase.SNAPSHOT, MigrationPhase.CATCHUP,
               MigrationPhase.CUTOVER}


def _serialized(fn):
    """Every public AdminPlane verb under the plane mutex: admin verbs run
    on HTTP handler threads concurrently with the tick thread's advance(),
    and e.g. two simultaneous POST /v2/admin/migrations for one tenant
    must not both pass the lock_tenant check. Reentrant (drain calls
    start_migration). Ordering is always plane mutex -> shard lock, never
    the reverse, so this cannot deadlock against the v1 data plane."""
    def wrapper(self, *args, **kwargs):
        with self._mutex:
            return fn(self, *args, **kwargs)
    wrapper.__name__ = fn.__name__
    wrapper.__doc__ = fn.__doc__
    return wrapper


@dataclass
class TenantSpec:
    """The tenant resource (control-plane state, not derivable from jobs)."""

    name: str
    quota_chips: Optional[int] = None
    tier: str = "paid"
    rate: Optional[float] = None    # per-tenant rate-limit override
    burst: Optional[int] = None
    shard: Optional[str] = None     # explicit pin (None = hash-routed)
    created_at: float = 0.0


@dataclass
class Migration:
    """One tenant→shard move, addressable while (and after) it runs."""

    migration_id: str
    tenant: str
    from_shard: str
    to_shard: str
    phase: MigrationPhase = MigrationPhase.SNAPSHOT
    error: str = ""
    created_at: float = 0.0
    updated_at: float = 0.0
    wal_watermark: int = 0                 # source journal ops copied so far
    log_watermarks: Dict[str, int] = field(default_factory=dict)
    halted_jobs: List[str] = field(default_factory=list)  # quiesced by us
    stats: Dict[str, int] = field(default_factory=lambda: {
        "ops_copied": 0, "records_copied": 0, "log_lines_copied": 0,
        "volumes_moved": 0, "objects_copied": 0, "catchup_rounds": 0})


class AdminPlane:
    """Shared control-plane state + the migration state machine.

    One instance per federation (and per standalone platform — the
    1-shard case); every gateway replica's :class:`AdminGateway` fronts
    the same plane, like every v1 replica fronts the same router.
    ``advance()`` is called once per federation tick and performs at most
    one phase step per live migration, so tests can crash shards/replicas
    "mid-phase" deterministically.
    """

    def __init__(self, router, auth: AuthService):
        self.router = router
        self.auth = auth
        self.tenants: Dict[str, TenantSpec] = {}
        self.migrations: Dict[str, Migration] = {}
        self._mig_ctr = itertools.count(1)
        self.ratelimiter = None  # attached by ApiHttpServer when present
        self.operator = None     # attached by repro.api.ops.install_operator
        self.faults = None       # attached by the platform/federation ctor
        # (shard_id, tenant) purges waiting for a dead destination to return
        self._deferred_purges: List[tuple] = []
        # (shard_id, [job_ids]) resumes waiting for a dead SOURCE to return
        # (jobs a migration quiesced must never be left HALTED forever)
        self._deferred_resumes: List[tuple] = []
        # Admin verbs run on HTTP handler threads concurrently with the
        # tick thread's advance(); unlike the v1 data plane (per-shard RW
        # locks), the plane's own state (tenants/migrations/pins) is one
        # shared structure — serialize it. Reentrant: verbs call helpers
        # that re-enter (e.g. drain -> start_migration).
        self._mutex = threading.RLock()

    # -- plumbing ---------------------------------------------------------
    def _now(self) -> float:
        return self.router.backends[0].platform.clock.now()

    @_serialized
    def attach_ratelimiter(self, ratelimiter):
        """Wire the HTTP tier's rate limiter so tenant PATCHes apply live.
        Replays every stored per-tenant override into it."""
        self.ratelimiter = ratelimiter
        if ratelimiter is None:
            return
        for spec in self.tenants.values():
            if spec.rate is not None:
                ratelimiter.set_tenant_config(
                    spec.name, RateLimitConfig(rate=spec.rate,
                                               burst=spec.burst))

    def _apply_rate(self, spec: TenantSpec):
        if self.ratelimiter is None:
            return
        cfg = (RateLimitConfig(rate=spec.rate, burst=spec.burst)
               if spec.rate is not None else None)
        self.ratelimiter.set_tenant_config(spec.name, cfg)

    def _backend(self, shard_id: str):
        try:
            return self.router.backend(shard_id)
        except KeyError:
            raise ApiError(ErrorCode.NOT_FOUND,
                           f"no such shard: {shard_id}", shard=shard_id)

    # -- tenant resource --------------------------------------------------
    def tenant_view(self, spec: TenantSpec) -> dict:
        return {"api_version": ADMIN_API_VERSION, "name": spec.name,
                "quota_chips": spec.quota_chips, "tier": spec.tier,
                "rate": spec.rate, "burst": spec.burst,
                "shard": self.router.shard_for(spec.name).shard_id,
                "pinned": spec.name in self.router.pins,
                "migrating": self.router.migration_target(spec.name)
                is not None}

    @_serialized
    def create_tenant(self, spec: TenantSpec) -> dict:
        if not spec.name or spec.name == "*":
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           f"invalid tenant name {spec.name!r}")
        if spec.name in self.tenants:
            raise ApiError(ErrorCode.CONFLICT,
                           f"tenant {spec.name!r} already exists")
        self._validate_quota_rate(spec.quota_chips, spec.rate, spec.burst)
        if spec.shard is not None:
            backend = self._backend(spec.shard)
            if backend.cordoned:
                raise ApiError(ErrorCode.FAILED_PRECONDITION,
                               f"shard {spec.shard} is cordoned",
                               shard=spec.shard)
            self.router.pin(spec.name, spec.shard)
        spec.created_at = self._now()
        self.tenants[spec.name] = spec
        self._register_quota(spec)
        self._apply_rate(spec)
        return self.tenant_view(spec)

    def _validate_quota_rate(self, quota, rate, burst):
        if quota is not None and (not isinstance(quota, int)
                                  or isinstance(quota, bool) or quota < 0):
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           f"quota_chips must be a non-negative integer, "
                           f"got {quota!r}")
        if (rate is None) != (burst is None):
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           "rate and burst must be set together")
        if rate is not None and (rate <= 0 or burst <= 0):
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           "rate and burst must be positive")

    def _register_quota(self, spec: TenantSpec):
        # Registered with EVERY shard's admission controller: quota follows
        # the tenant wherever routing (or a migration) places it.
        for backend in self.router.backends:
            if spec.quota_chips is None:
                backend.platform.admission.unregister_tenant(spec.name)
            else:
                backend.platform.admission.register_tenant(
                    spec.name, spec.quota_chips, tier=spec.tier)

    @_serialized
    def get_tenant(self, name: str) -> dict:
        spec = self.tenants.get(name)
        if spec is None:
            raise ApiError(ErrorCode.NOT_FOUND, f"no such tenant: {name}")
        return self.tenant_view(spec)

    @_serialized
    def list_tenants(self) -> dict:
        return {"api_version": ADMIN_API_VERSION,
                "items": [self.tenant_view(self.tenants[n])
                          for n in sorted(self.tenants)]}

    @_serialized
    def patch_tenant(self, name: str, patch: dict) -> dict:
        spec = self.tenants.get(name)
        if spec is None:
            raise ApiError(ErrorCode.NOT_FOUND, f"no such tenant: {name}")
        unknown = sorted(set(patch) - {"quota_chips", "tier", "rate",
                                       "burst"})
        if unknown:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           f"unknown tenant fields: {unknown}")
        quota = patch.get("quota_chips", spec.quota_chips)
        rate = patch.get("rate", spec.rate)
        burst = patch.get("burst", spec.burst)
        self._validate_quota_rate(quota, rate, burst)
        spec.quota_chips = quota
        spec.tier = patch.get("tier", spec.tier)
        spec.rate, spec.burst = rate, burst
        self._register_quota(spec)
        self._apply_rate(spec)
        return self.tenant_view(spec)

    @_serialized
    def delete_tenant(self, name: str) -> dict:
        spec = self.tenants.get(name)
        if spec is None:
            raise ApiError(ErrorCode.NOT_FOUND, f"no such tenant: {name}")
        if self.router.migration_target(name) is not None:
            raise ApiError(ErrorCode.FAILED_PRECONDITION,
                           f"tenant {name!r} is migrating")
        backend = self.router.shard_for(name)
        if not backend.alive:
            # cannot verify the tenant is idle: never guess-delete
            raise ApiError(ErrorCode.UNAVAILABLE,
                           f"shard {backend.shard_id} is down; cannot "
                           f"verify tenant {name!r} has no active jobs",
                           shard=backend.shard_id, shard_down=True)
        with backend.read_locked():
            records = backend.platform.meta.jobs(tenant=name)
            active = [r.job_id for r in records
                      if r.status not in TERMINAL
                      and r.status != JobStatus.HALTED]
        if active:
            raise ApiError(ErrorCode.FAILED_PRECONDITION,
                           f"tenant {name!r} still has active jobs",
                           jobs=active)
        del self.tenants[name]
        spec.quota_chips = None
        self._register_quota(spec)   # unregister everywhere
        spec.rate = None
        self._apply_rate(spec)       # back to the default bucket
        if not records:
            # only drop the pin when no history remains: unpinning a
            # tenant whose terminal records live on the pinned shard would
            # re-route its reads to the hash shard and strand the history
            self.router.unpin(name)
        return {"api_version": ADMIN_API_VERSION, "name": name,
                "deleted": True}

    # -- shard resource ---------------------------------------------------
    def shard_view(self, backend) -> dict:
        view = {"api_version": ADMIN_API_VERSION,
                "shard_id": backend.shard_id,
                "status": "ok" if backend.alive else "down",
                "cordoned": backend.cordoned,
                "version": getattr(backend, "version", "v0"),
                "retired": getattr(backend, "retired", False),
                "breaker": (backend.breaker.state
                            if getattr(backend, "breaker", None) is not None
                            else "closed"),
                "tenants": [], "jobs": 0, "active_jobs": 0,
                "chips_total": 0, "chips_used": 0, "queue_depth": 0}
        if not backend.alive:
            return view
        with backend.read_locked():
            p = backend.platform
            meta = p.meta
            resident = {t for t, ids in meta._by_tenant.items() if ids}
            # snapshot: shard_for's cordon-reroute may insert a pin from a
            # v1 request thread while we iterate (dict(...) is atomic)
            resident |= {t for t, sid in dict(self.router.pins).items()
                         if sid == backend.shard_id}
            active = 0
            for st, ids in meta._by_status.items():
                if st not in TERMINAL and st != JobStatus.HALTED:
                    active += len(ids)
            view.update({
                "tenants": sorted(resident),
                "jobs": len(meta._order),
                "active_jobs": active,
                "chips_total": p.cluster.total_chips,
                "chips_used": p.cluster.used_chips,
                "queue_depth": p.scheduler.queue_depth(),
            })
        return view

    @_serialized
    def list_shards(self) -> dict:
        return {"api_version": ADMIN_API_VERSION,
                "items": [self.shard_view(b) for b in self.router.backends]}

    @_serialized
    def get_shard(self, shard_id: str) -> dict:
        return self.shard_view(self._backend(shard_id))

    @_serialized
    def cordon(self, shard_id: str) -> dict:
        self._backend(shard_id).cordon()
        return self.get_shard(shard_id)

    @_serialized
    def uncordon(self, shard_id: str) -> dict:
        self._backend(shard_id).uncordon()
        return self.get_shard(shard_id)

    # -- operator resource (repro.obs.operator) ---------------------------
    def _operator(self):
        if self.operator is None:
            raise ApiError(ErrorCode.NOT_FOUND,
                           "no operator installed on this deployment")
        return self.operator

    @_serialized
    def operator_status(self) -> dict:
        """Status + decision log of the autonomous operator."""
        return self._operator().status_view()

    @_serialized
    def start_rollout(self, version: str) -> dict:
        """Request a GUARD-style rolling upgrade to ``version``; waves
        start on the next federation tick."""
        return self._operator().request_rollout(version)

    # -- fault resource (repro.core.faults) --------------------------------
    def _fault_plane(self):
        if self.faults is None:
            raise ApiError(ErrorCode.FAILED_PRECONDITION,
                           "no fault plane attached to this deployment")
        return self.faults

    @_serialized
    def install_fault(self, body: dict) -> dict:
        """Install a fault plan on a named interposition point. ``body``
        carries ``point`` plus any of ``key``/``latency_s``/``error``/
        ``hang``/``mode``/``probability`` (see ``repro.core.faults``)."""
        plane = self._fault_plane()
        if not isinstance(body, dict) or "point" not in body:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           "body must carry a fault 'point'")
        unknown = sorted(set(body) - {"point", "key", "latency_s", "error",
                                      "hang", "mode", "probability"})
        if unknown:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           f"unknown fault fields: {unknown}")
        try:
            plan = plane.install(
                body["point"], key=body.get("key"),
                latency_s=body.get("latency_s", 0.0),
                error=body.get("error"),
                hang=bool(body.get("hang", False)),
                mode=body.get("mode", "persistent"),
                probability=body.get("probability", 1.0))
        except (ValueError, TypeError) as e:
            raise ApiError(ErrorCode.INVALID_ARGUMENT, str(e))
        return {"api_version": ADMIN_API_VERSION, **plan}

    @_serialized
    def list_faults(self) -> dict:
        plane = self._fault_plane()
        return {"api_version": ADMIN_API_VERSION, "items": plane.list(),
                "triggered": dict(plane.triggered)}

    @_serialized
    def clear_faults(self, fault_id: Optional[str] = None) -> dict:
        """Clear one plan (waking any hung waiter on it) or, with no id,
        every installed plan."""
        plane = self._fault_plane()
        cleared = plane.clear(fault_id)
        if fault_id is not None and cleared == 0:
            raise ApiError(ErrorCode.NOT_FOUND,
                           f"no such fault: {fault_id}", fault_id=fault_id)
        return {"api_version": ADMIN_API_VERSION, "cleared": cleared}

    # -- migration resource -----------------------------------------------
    def migration_view(self, m: Migration) -> dict:
        return {"api_version": ADMIN_API_VERSION,
                "migration_id": m.migration_id, "tenant": m.tenant,
                "from_shard": m.from_shard, "to_shard": m.to_shard,
                "phase": m.phase.value, "error": m.error,
                "created_at": m.created_at, "updated_at": m.updated_at,
                "stats": dict(m.stats)}

    @_serialized
    def start_migration(self, tenant: str, to_shard: str) -> dict:
        if not tenant or not isinstance(tenant, str):
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           f"invalid tenant {tenant!r}")
        dst = self._backend(to_shard)
        src = self.router.shard_for(tenant)
        if src is dst:
            raise ApiError(ErrorCode.FAILED_PRECONDITION,
                           f"tenant {tenant!r} is already on {to_shard}",
                           tenant=tenant, shard=to_shard)
        if dst.cordoned:
            raise ApiError(ErrorCode.FAILED_PRECONDITION,
                           f"shard {to_shard} is cordoned", shard=to_shard)
        for backend in (src, dst):
            if not backend.alive:
                raise ApiError(ErrorCode.UNAVAILABLE,
                               f"shard {backend.shard_id} is down",
                               shard=backend.shard_id, shard_down=True)
        self.router.lock_tenant(tenant, src.shard_id, dst.shard_id)  # CONFLICT
        m = Migration(migration_id=f"mig-{next(self._mig_ctr):04d}",
                      tenant=tenant, from_shard=src.shard_id,
                      to_shard=dst.shard_id, created_at=self._now(),
                      updated_at=self._now())
        self.migrations[m.migration_id] = m
        self._emit_phase(m)
        return self.migration_view(m)

    def _emit_phase(self, m: Migration):
        """migration_phase platform event into the SOURCE shard's bus,
        stamped with the migrating tenant (so the tenant can watch its own
        migration on /v2/events). Best-effort: observability must never
        fail a phase step."""
        try:
            src = self.router.backend(m.from_shard)
            src.platform.events.emit(
                "admin", "migration_phase", tenant=m.tenant,
                migration=m.migration_id, phase=m.phase.value,
                to_shard=m.to_shard)
        except Exception:
            pass

    @_serialized
    def get_migration(self, migration_id: str) -> dict:
        m = self.migrations.get(migration_id)
        if m is None:
            raise ApiError(ErrorCode.NOT_FOUND,
                           f"no such migration: {migration_id}")
        return self.migration_view(m)

    @_serialized
    def list_migrations(self) -> dict:
        return {"api_version": ADMIN_API_VERSION,
                "items": [self.migration_view(self.migrations[k])
                          for k in sorted(self.migrations)]}

    @_serialized
    def drain(self, shard_id: str) -> dict:
        """Migrate every resident tenant off ``shard_id``, then cordon it.
        Tenants with records get a migration; pinned-but-empty tenants are
        simply re-pinned. Targets are the least-occupied alive, uncordoned
        other shards."""
        backend = self._backend(shard_id)
        if not backend.alive:
            raise ApiError(ErrorCode.UNAVAILABLE,
                           f"shard {shard_id} is down", shard=shard_id,
                           shard_down=True)
        others = [b for b in self.router.backends
                  if b is not backend and b.alive and not b.cordoned]
        if not others:
            raise ApiError(ErrorCode.FAILED_PRECONDITION,
                           "no alive, uncordoned shard to drain into",
                           shard=shard_id)
        backend.cordon()  # no new tenants land here while we move the rest
        # abort in-flight migrations INTO this shard: letting one complete
        # would land its tenant on the just-drained shard after the drain
        # reported success (the drain -> decommission flow would lose it)
        for m in list(self.migrations.values()):
            if m.phase in LIVE_PHASES and m.to_shard == shard_id:
                self._abort(m, f"destination {shard_id} drained")
        with backend.read_locked():
            sizes = {t: len(ids) for t, ids in
                     backend.platform.meta._by_tenant.items() if ids}
        with_jobs = sorted(sizes)
        pinned_empty = sorted(t for t, sid in dict(self.router.pins).items()
                              if sid == shard_id and t not in sizes)

        # Targets by occupancy INCLUDING the jobs this drain is about to
        # send each way — occupancy on disk doesn't change until the
        # migrations complete, so without the pending weight every tenant
        # would pile onto the single currently-least-occupied shard.
        pending: Counter = Counter()

        def least_loaded():
            return min(others,
                       key=lambda b: (len(b.platform.meta._order)
                                      + pending[b.shard_id], b.shard_id))

        migrations, repinned = [], []
        for tenant in with_jobs:
            if self.router.migration_target(tenant) is not None:
                continue  # already moving
            target = least_loaded()
            view = self.start_migration(tenant, target.shard_id)
            pending[target.shard_id] += sizes[tenant]
            migrations.append(view["migration_id"])
        for tenant in pinned_empty:
            target = least_loaded()
            pending[target.shard_id] += 1
            self.router._force_pin(tenant, target.shard_id)
            if tenant in self.tenants:
                self.tenants[tenant].shard = target.shard_id
            repinned.append(tenant)
        return {"api_version": ADMIN_API_VERSION, "shard_id": shard_id,
                "cordoned": True, "migrations": migrations,
                "repinned": repinned}

    # -- the migration state machine --------------------------------------
    @_serialized
    def advance(self):
        """One phase step per live migration; called from Federation.tick.
        Also retries resumes/purges deferred on a dead shard."""
        self._run_deferred()
        for m in list(self.migrations.values()):
            if m.phase not in LIVE_PHASES:
                continue
            src = self.router.backend(m.from_shard)
            dst = self.router.backend(m.to_shard)
            if not src.alive or not dst.alive:
                down = src if not src.alive else dst
                self._abort(m, f"shard {down.shard_id} went down during "
                               f"{m.phase.value}")
                continue
            try:
                if m.phase == MigrationPhase.SNAPSHOT:
                    self._copy_delta(m, src, dst)
                    m.phase = MigrationPhase.CATCHUP
                elif m.phase == MigrationPhase.CATCHUP:
                    with src.write_locked():
                        m.halted_jobs += self._quiesce(src.platform,
                                                       m.tenant)
                    self._copy_delta(m, src, dst)
                    m.stats["catchup_rounds"] += 1
                    m.phase = MigrationPhase.CUTOVER
                elif m.phase == MigrationPhase.CUTOVER:
                    self._cutover(m, src, dst)
                    m.phase = MigrationPhase.DONE
                self._emit_phase(m)
            except (ConnectionError, ObjectStoreError) as e:
                # a metastore or object store failed mid-step: abort back
                # to the intact source of truth
                self._abort(m, f"storage failure during "
                               f"{m.phase.value}: {e}")
                continue
            m.updated_at = self._now()

    def _copy_delta(self, m: Migration, src, dst):
        """Export everything past the watermarks from the source, import
        into the destination. First call = the bulk SNAPSHOT (watermark 0,
        jobs still running); later calls = CATCHUP/CUTOVER deltas."""
        with src.read_locked():
            snap = src.platform.meta.export_tenant(m.tenant,
                                                   since=m.wal_watermark)
            logs = {}
            for jid in snap["records"]:
                since = m.log_watermarks.get(jid, 0)
                recs = src.platform.log_index.export_job(jid, since=since)
                if recs:
                    logs[jid] = (since, recs)
        with dst.write_locked():
            dst.platform.meta.import_tenant(snap)
            for jid, (since, recs) in logs.items():
                dst.platform.log_index.import_records(recs)
        m.wal_watermark = snap["watermark"]
        for jid, (since, recs) in logs.items():
            m.log_watermarks[jid] = since + len(recs)
        m.stats["ops_copied"] += len(snap["ops"])
        m.stats["records_copied"] += len(snap["records"])
        m.stats["log_lines_copied"] += sum(len(r) for _, r in logs.values())

    @staticmethod
    def _quiesce(platform, tenant: str) -> list:
        """Checkpoint-and-halt every non-terminal job of ``tenant`` NOW
        (the platform's own preemption teardown, forced synchronously so
        the cutover never waits on a job stuck in a deploy stage). Returns
        the job ids halted — they are resumed on the destination after
        cutover, or back on the source if the migration aborts. Caller
        holds the source's write lock."""
        halted = []
        for rec in platform.meta.jobs(tenant=tenant):
            if rec.status in TERMINAL or rec.status == JobStatus.HALTED:
                continue
            guardian = platform.guardians.get(rec.job_id)
            if guardian is not None and guardian.stage != "GC_DONE":
                guardian._do_halt()  # teardown + checkpointed state kept
            else:
                platform.meta.update_status(rec.job_id, JobStatus.HALTED,
                                            "halted")
            platform.guardians.pop(rec.job_id, None)
            halted.append(rec.job_id)
        return halted

    def _cutover(self, m: Migration, src, dst):
        """The atomic flip: both write locks (in shard order, the same
        total order AllShardsLock uses), final delta, runtime-state move,
        source purge, pin flip, destination resume. In-flight v1 requests
        either ran before the locks (old shard, fully present) or resolve
        after them (new shard, fully present)."""
        first, second = sorted(
            (src, dst), key=lambda b: self.router.backends.index(b))
        with first.write_locked(), second.write_locked():
            # submits that landed after the CATCHUP quiesce
            m.halted_jobs += self._quiesce(src.platform, m.tenant)
            snap = src.platform.meta.export_tenant(m.tenant,
                                                   since=m.wal_watermark)
            dst.platform.meta.import_tenant(snap)
            m.stats["ops_copied"] += len(snap["ops"])
            m.stats["records_copied"] += len(snap["records"])
            job_ids = sorted(src.platform.meta._by_tenant.get(m.tenant, []))
            # copy phase first — it can FAIL (object-store fault) and must
            # leave the source fully intact so the abort path stays clean;
            # only after every copy lands do the destructive steps run
            for jid in job_ids:
                since = m.log_watermarks.get(jid, 0)
                recs = src.platform.log_index.export_job(jid, since=since)
                if recs:
                    dst.platform.log_index.import_records(recs)
                    m.stats["log_lines_copied"] += len(recs)
                self._copy_runtime_state(m, src.platform, dst.platform, jid)
            for jid in job_ids:
                self._drop_runtime_state(src.platform, dst.platform, jid)
            src.platform.log_index.purge_jobs(job_ids)
            src.platform.meta.purge_tenant(m.tenant)
            self.router._force_pin(m.tenant, m.to_shard)
            self.router.unlock_tenant(m.tenant)
            if m.tenant in self.tenants:
                self.tenants[m.tenant].shard = m.to_shard
            self._resume_jobs(dst, m.halted_jobs, "resumed after migration")

    def _copy_runtime_state(self, m: Migration, src_p, dst_p, job_id: str):
        """Volume (checkpoints, log offsets, creds) and object-store
        artifacts follow the job. NON-destructive: the source keeps
        everything, so an object-store fault here propagates and aborts
        the cutover with the source still whole — never a silent loss of
        a migrated job's results."""
        vol = src_p.volumes.get(job_id)
        if vol is not None:
            dst_p.volumes[job_id] = vol
            m.stats["volumes_moved"] += 1
        rec = dst_p.meta.get(job_id)
        if rec is None:
            return
        bucket = rec.manifest.results_bucket
        for key in src_p.objstore.list(bucket, prefix=f"{job_id}/"):
            # get/put raise ObjectStoreError on a fault -> cutover aborts
            dst_p.objstore.put(bucket, key, src_p.objstore.get(bucket, key))
            m.stats["objects_copied"] += 1

    @staticmethod
    def _drop_runtime_state(src_p, dst_p, job_id: str):
        """Destructive source cleanup, run only after EVERY copy landed.
        Nothing here can fail (dict pops + ObjectStore.delete never
        raises); leftovers would be garbage, not data loss."""
        src_p.volumes.pop(job_id, None)
        if job_id in src_p.admission.over_quota:
            dst_p.admission.over_quota[job_id] = \
                src_p.admission.over_quota.pop(job_id)
        rec = dst_p.meta.get(job_id)
        if rec is not None:
            bucket = rec.manifest.results_bucket
            for key in dst_p.objstore.list(bucket, prefix=f"{job_id}/"):
                src_p.objstore.delete(bucket, key)

    def _abort(self, m: Migration, error: str):
        """Back to a consistent source of truth: unlock routing, resume
        whatever the quiesce halted on the SOURCE (now, or when a dead
        source comes back up — a migration-quiesced job must never be
        left HALTED forever), and purge the partial import from the
        destination (now, or when it comes back up)."""
        m.phase = MigrationPhase.FAILED
        m.error = error
        m.updated_at = self._now()
        self._emit_phase(m)
        self.router.unlock_tenant(m.tenant)
        if m.halted_jobs:
            # resume wherever the tenant is ROUTED now — normally the
            # source, but if the failure struck after the cutover's pin
            # flip the destination is already authoritative and the
            # records are purged from the source
            owner = self.router.shard_for(m.tenant).shard_id
            self._deferred_resumes.append((owner, list(m.halted_jobs)))
        self._deferred_purges.append((m.to_shard, m.tenant))
        self._run_deferred()

    @staticmethod
    def _resume_jobs(backend, job_ids, msg: str):
        """Caller holds the backend's write lock."""
        for jid in job_ids:
            rec = backend.platform.meta.get(jid)
            if rec is not None and rec.status == JobStatus.HALTED:
                backend.platform.guardians.pop(jid, None)
                backend.platform.meta.update_status(jid, JobStatus.RESUMED,
                                                    msg)

    def _run_deferred(self):
        """Abort cleanup that could not run while a shard was down."""
        still = []
        for shard_id, job_ids in self._deferred_resumes:
            backend = self.router.backend(shard_id)
            if not backend.alive:
                still.append((shard_id, job_ids))
                continue
            with backend.write_locked():
                try:
                    self._resume_jobs(backend, job_ids,
                                      "resumed after aborted migration")
                except ConnectionError:
                    still.append((shard_id, job_ids))
        self._deferred_resumes = still
        still = []
        for shard_id, tenant in self._deferred_purges:
            backend = self.router.backend(shard_id)
            # never purge the tenant's CURRENT shard (e.g. a later
            # migration moved it here in the meantime)
            if self.router.shard_for(tenant) is backend:
                continue
            if not backend.alive:
                still.append((shard_id, tenant))
                continue
            with backend.write_locked():
                try:
                    p = backend.platform
                    # grab result buckets BEFORE purging the manifests, so
                    # artifacts copied by an aborted cutover are removed
                    # too (not leaked on the abandoned destination)
                    buckets = {r.job_id: r.manifest.results_bucket
                               for r in p.meta.jobs(tenant=tenant)}
                    jids = p.meta.purge_tenant(tenant)
                    p.log_index.purge_jobs(jids)
                    for jid in jids:
                        p.volumes.pop(jid, None)
                        bucket = buckets.get(jid)
                        if bucket is None:
                            continue
                        for key in p.objstore.list(bucket,
                                                   prefix=f"{jid}/"):
                            p.objstore.delete(bucket, key)
                except ConnectionError:
                    still.append((shard_id, tenant))
        self._deferred_purges = still


# Every AdminGateway verb runs inside a deadline scope (the v2 analogue
# of the v1 gateway's _deadlined; enforced by the DEADLINE-VERB check).
_deadlined = deadline_guarded()


class AdminGateway:
    """The wire-facing v2 verb surface: admin auth in front of the shared
    :class:`AdminPlane`. Every verb takes ``(api_key, ...)`` and returns a
    plain JSON-able dict stamped ``"api_version": "v2"`` — the HTTP layer
    serializes it verbatim, and the in-process surface is identical."""

    # per-verb deadline budget; instances may tighten it (drills do)
    verb_budget_s = 10.0

    def __init__(self, plane: AdminPlane, auth: AuthService):
        self.plane = plane
        self.auth = auth

    def _require(self, api_key: str) -> Principal:
        principal = self.auth.require(api_key, ADMIN)
        if not principal.is_admin:
            raise ApiError(ErrorCode.FORBIDDEN,
                           "admin plane requires an operator (\"*\") key")
        return principal

    # -- tenants ----------------------------------------------------------
    @_deadlined
    def create_tenant(self, api_key: str, body: dict) -> dict:
        self._require(api_key)
        if not isinstance(body, dict) or "name" not in body:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           "body must carry a tenant 'name'")
        unknown = sorted(set(body) - {"name", "quota_chips", "tier", "rate",
                                      "burst", "shard"})
        if unknown:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           f"unknown tenant fields: {unknown}")
        return self.plane.create_tenant(TenantSpec(
            name=body["name"], quota_chips=body.get("quota_chips"),
            tier=body.get("tier", "paid"), rate=body.get("rate"),
            burst=body.get("burst"), shard=body.get("shard")))

    @_deadlined
    def get_tenant(self, api_key: str, name: str) -> dict:
        self._require(api_key)
        return self.plane.get_tenant(name)

    @_deadlined
    def list_tenants(self, api_key: str) -> dict:
        self._require(api_key)
        return self.plane.list_tenants()

    @_deadlined
    def patch_tenant(self, api_key: str, name: str, patch: dict) -> dict:
        self._require(api_key)
        if not isinstance(patch, dict):
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           "patch must be a JSON object")
        return self.plane.patch_tenant(name, patch)

    @_deadlined
    def delete_tenant(self, api_key: str, name: str) -> dict:
        self._require(api_key)
        return self.plane.delete_tenant(name)

    # -- shards -----------------------------------------------------------
    @_deadlined
    def list_shards(self, api_key: str) -> dict:
        self._require(api_key)
        return self.plane.list_shards()

    @_deadlined
    def get_shard(self, api_key: str, shard_id: str) -> dict:
        self._require(api_key)
        return self.plane.get_shard(shard_id)

    @_deadlined
    def cordon_shard(self, api_key: str, shard_id: str) -> dict:
        self._require(api_key)
        return self.plane.cordon(shard_id)

    @_deadlined
    def uncordon_shard(self, api_key: str, shard_id: str) -> dict:
        self._require(api_key)
        return self.plane.uncordon(shard_id)

    @_deadlined
    def drain_shard(self, api_key: str, shard_id: str) -> dict:
        self._require(api_key)
        return self.plane.drain(shard_id)

    # -- operator ---------------------------------------------------------
    @_deadlined
    def operator_status(self, api_key: str) -> dict:
        self._require(api_key)
        return self.plane.operator_status()

    @_deadlined
    def start_rollout(self, api_key: str, body: dict) -> dict:
        self._require(api_key)
        if not isinstance(body, dict) or not isinstance(
                body.get("version"), str) or not body["version"]:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           "body must carry a non-empty 'version' string")
        return self.plane.start_rollout(body["version"])

    # -- faults -----------------------------------------------------------
    @_deadlined
    def install_fault(self, api_key: str, body: dict) -> dict:
        self._require(api_key)
        if not isinstance(body, dict):
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           "body must be a JSON object")
        return self.plane.install_fault(body)

    @_deadlined
    def list_faults(self, api_key: str) -> dict:
        self._require(api_key)
        return self.plane.list_faults()

    @_deadlined
    def clear_faults(self, api_key: str,
                     fault_id: Optional[str] = None) -> dict:
        self._require(api_key)
        return self.plane.clear_faults(fault_id)

    # -- migrations -------------------------------------------------------
    @_deadlined
    def start_migration(self, api_key: str, body: dict) -> dict:
        self._require(api_key)
        if not isinstance(body, dict) or "tenant" not in body \
                or "to_shard" not in body:
            raise ApiError(ErrorCode.INVALID_ARGUMENT,
                           "body must carry 'tenant' and 'to_shard'")
        return self.plane.start_migration(body["tenant"], body["to_shard"])

    @_deadlined
    def get_migration(self, api_key: str, migration_id: str) -> dict:
        self._require(api_key)
        return self.plane.get_migration(migration_id)

    @_deadlined
    def list_migrations(self, api_key: str) -> dict:
        self._require(api_key)
        return self.plane.list_migrations()
