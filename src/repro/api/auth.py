"""Per-tenant API-key authentication + scope checks (FfDL §3.2).

The paper's API tier authenticates every request and namespaces all job
state by tenant; one tenant can never read or halt another tenant's jobs.
We model that with opaque bearer keys issued per tenant:

  * ``issue_key(tenant, scopes)`` mints a key; scopes are ``read`` (status,
    logs, listings) and ``write`` (submit, halt, resume, cancel);
  * ``authenticate(key)`` resolves a :class:`Principal` or raises
    ``UNAUTHENTICATED``;
  * a principal for the wildcard tenant ``"*"`` is an operator/admin
    credential that may act across tenants (the platform's own facade uses
    one so legacy callers keep their pre-auth behaviour);
  * the ``admin`` scope gates the v2 admin control plane
    (``repro.api.admin``: tenants/quotas/shards/migrations as wire
    resources). A plain ``"*"`` read/write key can still use the v1
    cross-tenant *data*-plane reads, but cannot touch platform topology —
    mint an operator key with ``issue_admin_key()`` for that.

Keys are deterministic per AuthService instance (seeded counter + hash) so
simulations stay reproducible.
"""

from __future__ import annotations

import hashlib
import itertools
from dataclasses import dataclass
from typing import Dict, Tuple

from repro.api.types import ApiError, ErrorCode

READ = "read"
WRITE = "write"
ADMIN = "admin"  # v2 control plane: tenants, quotas, shards, migrations
ALL_TENANTS = "*"


@dataclass(frozen=True)
class Principal:
    tenant: str
    scopes: Tuple[str, ...]
    key_id: str

    @property
    def is_admin(self) -> bool:
        return self.tenant == ALL_TENANTS

    def can(self, scope: str) -> bool:
        return scope in self.scopes

    def owns(self, tenant: str) -> bool:
        return self.is_admin or self.tenant == tenant


class AuthService:
    def __init__(self, seed: int = 0):
        self._keys: Dict[str, Principal] = {}
        self._ctr = itertools.count(1)
        self._seed = seed

    def issue_key(self, tenant: str,
                  scopes: Tuple[str, ...] = (READ, WRITE)) -> str:
        n = next(self._ctr)
        digest = hashlib.sha256(
            f"{self._seed}:{tenant}:{n}".encode()).hexdigest()[:24]
        key = f"ffdl-{digest}"
        self._keys[key] = Principal(tenant=tenant, scopes=tuple(scopes),
                                    key_id=f"key-{n:04d}")
        return key

    def issue_admin_key(self) -> str:
        """Operator credential for the v2 admin plane: wildcard tenant plus
        the ``admin`` scope (and the data-plane scopes, so one key can both
        drive a migration and verify the tenant's jobs afterwards)."""
        return self.issue_key(ALL_TENANTS, scopes=(READ, WRITE, ADMIN))

    def revoke(self, key: str):
        self._keys.pop(key, None)

    def peek(self, api_key: str):
        """Resolve a key without raising — ``None`` for unknown keys. Used
        by the rate limiter to pick a bucket before authentication runs
        (unauthenticated floods must be throttleable too)."""
        return self._keys.get(api_key)

    def authenticate(self, api_key: str) -> Principal:
        principal = self._keys.get(api_key)
        if principal is None:
            raise ApiError(ErrorCode.UNAUTHENTICATED,
                           "unknown or revoked API key")
        return principal

    def require(self, api_key: str, scope: str) -> Principal:
        principal = self.authenticate(api_key)
        if not principal.can(scope):
            raise ApiError(ErrorCode.FORBIDDEN,
                           f"key {principal.key_id} lacks scope {scope!r}",
                           scope=scope)
        return principal
