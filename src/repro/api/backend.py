"""Backend: one FfDLPlatform shard behind the gateway tier (FfDL §3.2-3.3).

The paper's API layer is stateless and *independently scalable* from the
backend microservices it fronts: the REST contract survives backend
re-architecture. This module is the seam that makes that true here — a
:class:`Backend` wraps one ``FfDLPlatform`` shard (its own metastore,
scheduler, cluster, log index) with the two pieces of state the gateway
tier needs:

  * **a per-shard readers-writer lock** (:class:`RWLock`). The simulation
    core is single-threaded, so every v1 verb must hold its shard's lock —
    but *only* its shard's lock, and reads share it. A ``status`` on
    shard A never serializes behind a ``submit`` on shard B, and two
    ``list_jobs`` on the same shard run concurrently. This replaces the
    PR-2 global ``server.lock`` that funnelled every HTTP handler thread
    through one mutex;
  * **health state**. A crashed shard (``crash()``) answers
    ``UNAVAILABLE`` for *its* tenants only — the router keeps sending
    every other tenant to their own healthy shards, and the load
    balancer's replica crash-masking composes on top unchanged.

:class:`AllShardsLock` is the compatibility bridge for code that used the
old global lock (``with server.lock: platform.tick()``): it acquires every
shard's write lock in shard order (a total order, so it cannot deadlock
against verb handlers, which hold at most one shard lock at a time).
"""

from __future__ import annotations

import threading
from contextlib import ExitStack, contextmanager

from repro.core.faults import DeadlineExceeded, ShardBreaker, remaining


class RWLock:
    """Writer-preferring readers-writer lock.

    Readers share; a writer excludes everyone. Writer preference (readers
    queue behind a *waiting* writer) keeps submits from starving under the
    read-heavy traffic this lock exists to scale.

    Acquisition waits are **deadline-bounded**: when the calling thread
    carries an ambient deadline (the gateway wraps every v1 verb in a
    :func:`repro.core.faults.deadline_scope`), a wait that outlives the
    budget raises :class:`DeadlineExceeded` instead of blocking forever.
    This is the defense that matters against a *gray* shard: a hung tick
    holds the write lock, and without the bound every verb on the shard
    would stall indefinitely at lock acquisition.

    ``shared_reads=False`` degrades reads to exclusive acquisitions — the
    pre-federation single-lock behaviour, kept so ``benchmarks/api_tier.py``
    can measure the read/write split against an honest baseline.
    """

    def __init__(self, shared_reads: bool = True):
        self.shared_reads = shared_reads
        # Stable label for the lock-order witness (repro.analysis.witness):
        # owners set it ("shard:0", ...) so witnessed acquisition-graph
        # edges read as topology, not object ids.
        self.name = None  # type: str | None
        self._cond = threading.Condition()
        self._readers = 0
        self._writer_active = False
        self._writers_waiting = 0
        # benchmark introspection: proves reads actually overlapped
        self.stats = {"reads": 0, "writes": 0, "max_concurrent_readers": 0}

    def _wait(self):
        """One condition wait, bounded by the thread's ambient deadline."""
        rem = remaining()
        if rem is None:
            self._cond.wait()
        elif rem <= 0:
            raise DeadlineExceeded("lock wait exceeded the deadline budget")
        else:
            self._cond.wait(rem)

    @contextmanager
    def read_locked(self):
        if not self.shared_reads:
            with self.write_locked():
                yield
            return
        with self._cond:
            while self._writer_active or self._writers_waiting:
                self._wait()
            self._readers += 1
            self.stats["reads"] += 1
            if self._readers > self.stats["max_concurrent_readers"]:
                self.stats["max_concurrent_readers"] = self._readers
        try:
            yield
        finally:
            with self._cond:
                self._readers -= 1
                if self._readers == 0:
                    self._cond.notify_all()

    @contextmanager
    def write_locked(self):
        with self._cond:
            self._writers_waiting += 1
            try:
                while self._writer_active or self._readers:
                    self._wait()
            except BaseException:
                # readers queued behind this (now aborted) writer would
                # otherwise sleep until the next unrelated notify
                self._cond.notify_all()
                raise
            finally:
                self._writers_waiting -= 1
            self._writer_active = True
            self.stats["writes"] += 1
        try:
            yield
        finally:
            with self._cond:
                self._writer_active = False
                self._cond.notify_all()


class Backend:
    """One platform shard + its lock + its health state.

    ``platform`` is duck-typed (an ``FfDLPlatform``); the gateway reaches
    its metastore/log-index/admission/cluster through ``backend.platform``
    while holding ``backend.lock``.
    """

    def __init__(self, shard_id: str, platform, shared_reads: bool = True):
        self.shard_id = shard_id
        self.platform = platform
        self.lock = RWLock(shared_reads=shared_reads)
        self.lock.name = f"shard:{shard_id}"
        self.alive = True
        # operator cordon (v2 admin plane): a cordoned shard keeps serving
        # its resident tenants but accepts no NEW tenant placements and no
        # migration destinations. drain = migrate everyone off, then cordon.
        self.cordoned = False
        # software version the shard runs; the autonomous operator's rolling
        # upgrades bump it wave by wave via restart(version=...).
        self.version = "v0"
        # retired: fenced out of the fleet by the operator after a
        # scale-down drain. Stays in router.backends (the hash modulus and
        # composite cursors must not shift) but the federation stops
        # ticking it and the operator excludes its capacity.
        self.retired = False
        # gray-failure quarantine: per-shard circuit breaker. The gateway
        # records one outcome per v1 verb and checks allow() at shard
        # selection; an open breaker answers fast UNAVAILABLE exactly like
        # a dead shard (shard_down details), so a wedged-but-alive shard
        # cannot stall its tenants.
        self.breaker = ShardBreaker()

    # -- shard lifecycle (chaos) ------------------------------------------
    def crash(self):
        """Down the whole shard: every verb routed here answers
        UNAVAILABLE until restart. Other shards' tenants are unaffected."""
        self.alive = False

    def restart(self, version: str = None):
        self.alive = True
        if version is not None:
            self.version = version
        # a restart clears the gray-failure presumption; if the shard is
        # still wedged the breaker re-opens within failure_threshold calls
        self.breaker.reset()

    # -- operator lifecycle (v2 admin plane) ------------------------------
    def cordon(self):
        self.cordoned = True

    def uncordon(self):
        self.cordoned = False

    def retire(self):
        """Fence the shard out of the fleet (cordon + stop ticking). The
        shard object stays addressable so existing composite cursors and
        the tenant-hash modulus remain valid."""
        self.cordoned = True
        self.retired = True

    def read_locked(self):
        return self.lock.read_locked()

    def write_locked(self):
        return self.lock.write_locked()

    def __repr__(self):
        state = "up" if self.alive else "DOWN"
        return f"Backend({self.shard_id}, {state})"


class AllShardsLock:
    """Every shard's write lock, acquired in shard order.

    Drop-in for the old global ``server.lock``: external code that ticks a
    platform from another thread (`with server.lock: platform.tick()`)
    still excludes every in-flight verb. Verb handlers themselves hold at
    most one shard lock and never acquire a second while holding it, so
    this total-order acquisition cannot deadlock against them.
    """

    def __init__(self, router):
        self.router = router
        self._stack = None

    def __enter__(self):
        stack = ExitStack()
        try:
            for backend in self.router.backends:
                stack.enter_context(backend.lock.write_locked())
        except BaseException:
            stack.close()
            raise
        self._stack = stack
        return self

    def __exit__(self, *exc):
        stack, self._stack = self._stack, None
        stack.close()
        return False
