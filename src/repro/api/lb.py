"""Round-robin load balancer over stateless API replicas (FfDL §3.2).

The paper's recovery claim for the API tier: replicas are stateless, so a
crashed one is masked by routing to a healthy sibling — clients observe
zero failed calls as long as one replica is up. The Kubernetes Service in
front of FfDL's REST pods does exactly this; we reproduce it as a
client-side balancer so ``benchmarks/api_tier.py`` can measure it.

Routing: pure round-robin across replicas. A call that fails with a
*retryable* ``ApiError`` (``UNAVAILABLE`` — raised by a dead replica before
any side effect, so re-issuing is safe; ``submit`` dedup additionally rides
on idempotency keys) fails over to the next replica, trying each at most
once. Non-retryable errors (auth, validation, quota, not-found) propagate
immediately — they would fail identically everywhere.

Federation-aware: an ``UNAVAILABLE`` whose details carry ``shard_down``
means the caller's *backend shard* is dead, not the replica — every
replica routes the same tenant to the same shard, so failing over would
burn every replica to learn nothing. The balancer propagates it
immediately (and counts it in ``stats["shard_down"]``); tenants on other
shards are unaffected, and replica crash-masking still composes on top.

Gray failures compose the same way: ``DEADLINE_EXCEEDED`` (the verb
outlived its budget against a wedged-but-alive shard) is deliberately
NOT retryable — every replica fronts the same shard, so a failover
would burn another full deadline budget per replica to learn nothing.
It propagates immediately and is counted in
``stats["deadline_exceeded"]``; the per-shard circuit breaker (see
``repro.core.faults``) then quarantines the shard so subsequent calls
get fast ``UNAVAILABLE`` (``shard_down`` + ``breaker_open`` details)
instead of each eating a budget.
"""

from __future__ import annotations

import threading
from typing import Optional

from repro.api.gateway import ApiGateway
from repro.api.types import ApiError, ErrorCode


class LoadBalancer:
    def __init__(self, replicas: list, events=None):
        if not replicas:
            raise ValueError("need at least one replica")
        self.replicas: list[ApiGateway] = list(replicas)
        self.event_log = events  # the owning shard's bus (verb `events` differs)
        self._rr = 0
        # handler threads hit the balancer concurrently now that verbs
        # lock per shard instead of under one global HTTP lock — guard the
        # counters or the failover/shard_down numbers the benchmarks
        # report would undercount under exactly the loads they measure
        self._stats_lock = threading.Lock()
        self.stats = {"calls": 0, "failovers": 0, "exhausted": 0,
                      "shard_down": 0, "deadline_exceeded": 0}

    def _bump(self, key: str):
        with self._stats_lock:
            self.stats[key] += 1

    @property
    def healthy_replicas(self) -> list:
        return [r for r in self.replicas if r.alive]

    def _call(self, method: str, *args, **kwargs):
        self._bump("calls")
        n = len(self.replicas)
        last: Optional[ApiError] = None
        for _ in range(n):
            with self._stats_lock:
                replica = self.replicas[self._rr % n]
                self._rr += 1
            try:
                return getattr(replica, method)(*args, **kwargs)
            except ApiError as e:
                if e.code is ErrorCode.DEADLINE_EXCEEDED:
                    self._bump("deadline_exceeded")
                if not e.retryable:
                    raise
                if e.details.get("shard_down"):
                    # the tenant's shard is down, not this replica: every
                    # replica would answer identically — don't mask
                    self._bump("shard_down")
                    raise
                last = e
                self._bump("failovers")
                if self.event_log is not None:
                    self.event_log.emit("api", "lb_failover",
                                     replica=replica.replica_id,
                                     method=method)
        self._bump("exhausted")
        raise last if last is not None else ApiError(
            ErrorCode.UNAVAILABLE, "no replicas configured")

    # -- full v1 surface, delegated --------------------------------------
    def submit(self, api_key, req):
        return self._call("submit", api_key, req)

    def status(self, api_key, job_id, **kwargs):
        return self._call("status", api_key, job_id, **kwargs)

    def status_history(self, api_key, job_id):
        return self._call("status_history", api_key, job_id)

    def list_jobs(self, api_key, **kwargs):
        return self._call("list_jobs", api_key, **kwargs)

    def logs(self, api_key, job_id, **kwargs):
        return self._call("logs", api_key, job_id, **kwargs)

    def search_logs(self, api_key, query, **kwargs):
        return self._call("search_logs", api_key, query, **kwargs)

    def halt(self, api_key, job_id, requeue: bool = False):
        return self._call("halt", api_key, job_id, requeue=requeue)

    def resume(self, api_key, job_id):
        return self._call("resume", api_key, job_id)

    def cancel(self, api_key, job_id):
        return self._call("cancel", api_key, job_id)

    # -- observability plane ----------------------------------------------
    def usage(self, api_key, **kwargs):
        return self._call("usage", api_key, **kwargs)

    def events(self, api_key, **kwargs):
        return self._call("events", api_key, **kwargs)
