"""v1 API contract: versioned request/response envelopes + structured errors.

FfDL's API tier (§3.2) is the platform's only public surface: every request
is validated, authenticated per tenant, and answered with a typed response.
This module is the wire contract for our reproduction of that tier:

  * every request/response envelope carries ``api_version`` (currently
    ``"v1"``); a gateway rejects versions it does not speak with a stable
    ``UNSUPPORTED_VERSION`` error instead of silently misparsing;
  * errors are ``ApiError`` with a stable :class:`ErrorCode` — clients (and
    the load balancer) branch on ``err.code``, never on exception class or
    message text;
  * list-shaped responses are ``Page`` envelopes with an opaque
    ``next_cursor`` — cursors stay stable under concurrent submits because
    they key on monotonically increasing ids/offsets, not list positions.

The same contract is served over two transports: in-process (the
``LoadBalancer`` / ``ApiGateway`` objects) and JSON-over-HTTP
(:mod:`repro.api.http`), where every ``ErrorCode`` maps to a stable HTTP
status (see ``repro.api.http.STATUS_OF`` and ``docs/api.md``).

The pre-gateway raw-exception facade (``ApiError.to_legacy()`` plus the
``FfDLPlatform.submit/status/...`` shims) was removed once every caller
migrated to tenant-scoped keys; clients now always see ``ApiError``.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from enum import Enum
from typing import Generic, List, Optional, TypeVar

from repro.core.types import JobManifest, JobRecord

API_VERSION = "v1"
SUPPORTED_VERSIONS = ("v1",)
# The v2 admin control plane (repro.api.admin) is a SEPARATE, versioned
# surface: resource-oriented operator envelopes stamped "v2". The v1 job
# data plane above is untouched by it — v1 requests still carry (and are
# answered with) "v1", and v1 rejects anything else exactly as before.
ADMIN_API_VERSION = "v2"

T = TypeVar("T")


class ErrorCode(str, Enum):
    UNAUTHENTICATED = "UNAUTHENTICATED"        # missing/unknown/revoked key
    FORBIDDEN = "FORBIDDEN"                    # authenticated, wrong tenant/scope
    NOT_FOUND = "NOT_FOUND"                    # unknown job id
    INVALID_ARGUMENT = "INVALID_ARGUMENT"      # malformed manifest/cursor
    QUOTA_EXCEEDED = "QUOTA_EXCEEDED"          # admission control rejection
    FAILED_PRECONDITION = "FAILED_PRECONDITION"  # e.g. resume on non-HALTED job
    CONFLICT = "CONFLICT"                      # idempotency key reused with a
    #                                            different payload
    UNAVAILABLE = "UNAVAILABLE"                # replica/metastore/shard down;
    #                                            retryable — except when
    #                                            details carry ``shard_down``
    #                                            (the tenant's backend shard
    #                                            is dead; every replica
    #                                            answers identically, so the
    #                                            LB propagates immediately)
    UNSUPPORTED_VERSION = "UNSUPPORTED_VERSION"
    RATE_LIMITED = "RATE_LIMITED"              # per-tenant backpressure (429);
    #                                            details carry ``retry_after``
    DEADLINE_EXCEEDED = "DEADLINE_EXCEEDED"    # request outlived its per-verb
    #                                            deadline budget (504). NOT
    #                                            LB-retryable: every replica
    #                                            fronts the same shard, so a
    #                                            wedged shard would just eat
    #                                            another full budget per
    #                                            replica. Idempotent verbs may
    #                                            be retried client-side with
    #                                            backoff (see ApiClient).


# Codes the load balancer may transparently retry on another replica.
# RATE_LIMITED is deliberately NOT here: it is raised by the admission
# front *before* the balancer, and failing over would defeat backpressure.
RETRYABLE = {ErrorCode.UNAVAILABLE}


class ApiError(Exception):
    """Structured API failure with a stable, client-branchable code."""

    def __init__(self, code: ErrorCode, message: str = "", **details):
        super().__init__(f"[{code.value}] {message}")
        self.code = code
        self.message = message
        self.details = details

    @property
    def retryable(self) -> bool:
        return self.code in RETRYABLE

    @property
    def retry_after(self) -> Optional[float]:
        """Seconds the client should wait before retrying (RATE_LIMITED)."""
        return self.details.get("retry_after")


# --------------------------------------------------------------------------
# Deadline guard (shared by every wire-facing gateway)
# --------------------------------------------------------------------------

def deadline_guarded(budget_s: float = 10.0, attr: str = "verb_budget_s"):
    """Decorator factory: run a gateway verb inside a ``deadline_scope``.

    The v1 data plane got per-verb deadlines in the gray-failure PR (see
    ``repro.api.gateway._deadlined``, which layers breaker accounting and
    long-poll budgets on top). This is the plane-agnostic core of that
    rule for the v2 admin/workload gateways: a verb that outlives its
    budget answers a stable ``DEADLINE_EXCEEDED`` (HTTP 504) instead of
    wedging the caller behind a gray-failing shard. The budget is read
    from ``getattr(self, attr)`` when present so drills and benchmarks
    can tighten a live gateway, falling back to ``budget_s``.

    The DEADLINE-VERB analyzer (``python -m repro.analysis``) enforces
    that every ``*Gateway`` method taking ``api_key`` is wrapped in this
    (or opens a ``deadline_scope`` itself).
    """
    def decorate(fn):
        name = fn.__name__

        @functools.wraps(fn)
        def wrapper(self, *args, **kwargs):
            # Core stays importable without the API tier, not the other
            # way round: importing the deadline plane here is cycle-free,
            # but lazy keeps types.py usable in stripped-down contexts.
            from repro.core.faults import DeadlineExceeded, deadline_scope
            budget = getattr(self, attr, None) or budget_s
            try:
                with deadline_scope(budget):
                    return fn(self, *args, **kwargs)
            except DeadlineExceeded:
                raise ApiError(
                    ErrorCode.DEADLINE_EXCEEDED,
                    f"{name} exceeded its {budget:.2f}s deadline budget",
                    verb=name, budget_s=round(budget, 3))
        return wrapper
    return decorate


# --------------------------------------------------------------------------
# Envelopes
# --------------------------------------------------------------------------

@dataclass
class SubmitRequest:
    manifest: JobManifest
    # Client-supplied dedup token: two submits with the same (tenant, key)
    # return the same job id, even across a metastore crash/recover — the
    # mapping is journaled in the WAL before the first ack.
    idempotency_key: Optional[str] = None
    api_version: str = API_VERSION


@dataclass
class SubmitResponse:
    job_id: str
    deduplicated: bool = False   # True when an idempotency key was replayed
    api_version: str = API_VERSION


@dataclass
class JobView:
    """Tenant-visible projection of a JobRecord (no placement internals)."""

    job_id: str
    name: str
    tenant: str
    status: str
    submitted_at: float
    finished_at: Optional[float] = None
    progress_step: int = 0
    message: str = ""
    api_version: str = API_VERSION

    @classmethod
    def of(cls, rec: JobRecord) -> "JobView":
        return cls(job_id=rec.job_id, name=rec.manifest.name,
                   tenant=rec.manifest.tenant, status=rec.status.value,
                   submitted_at=rec.submitted_at, finished_at=rec.finished_at,
                   progress_step=rec.progress_step, message=rec.message)


@dataclass
class Page(Generic[T]):
    """One page of a cursor-paginated listing.

    ``next_cursor`` is opaque to clients: pass it back verbatim to fetch the
    next page; ``None`` means exhausted. Cursors remain valid under
    concurrent submits/appends (new items only ever land after them).
    """

    items: List[T] = field(default_factory=list)
    # Opaque; three shapes exist behind it, all stable under concurrent
    # appends: job ids (listings), append offsets (logs/search), and the
    # composite multi-shard form (admin reads over a federation; one
    # per-shard cursor per shard — see repro.api.router).
    next_cursor: Optional[str] = None
    api_version: str = API_VERSION


def check_version(api_version: str):
    if api_version not in SUPPORTED_VERSIONS:
        raise ApiError(ErrorCode.UNSUPPORTED_VERSION,
                       f"api_version {api_version!r} not in "
                       f"{SUPPORTED_VERSIONS}")
