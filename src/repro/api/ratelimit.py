"""Per-tenant backpressure for the API tier (FfDL §3.2, multi-tenant story).

The paper's API layer absorbs heavy traffic from many tenants at once; the
dependability claim only holds if one flooding tenant cannot starve the
others. Two mechanisms compose in front of the :class:`LoadBalancer`:

  * a **token bucket per tenant** — sustained rate ``rate`` req/s with a
    burst allowance of ``burst``. A drained bucket answers
    ``RATE_LIMITED`` (HTTP 429) with a ``retry_after`` hint instead of
    queueing, so a flood is rejected in O(1) without ever touching a
    gateway replica or the metastore;
  * a **bounded in-flight gate** — at most ``max_inflight`` requests may
    be inside the tier at once (across all tenants). Excess load sheds
    immediately rather than building an unbounded queue (tail-latency
    protection for everyone).

``RateLimitedApi`` wraps anything exposing the v1 verb surface (the
balancer, one gateway replica — of a single platform or a multi-shard
federation), so rate limiting composes with replica crash-masking AND
with per-shard locking: a throttled call is rejected before any shard
lock is even resolved, an admitted call still fails over on UNAVAILABLE.
One caveat worth knowing: a ``logs`` long-poll (``wait_ms``) occupies an
in-flight slot while it parks, so ``max_inflight`` bounds the number of
concurrently parked followers too.

Buckets are keyed by the *tenant* behind the API key (all of a tenant's
keys share one budget); unknown keys share a single "anonymous" bucket so
credential-guessing floods are throttled before auth even runs.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional

from repro.api.auth import ADMIN
from repro.api.types import ApiError, ErrorCode

_ANON = "<anonymous>"


@dataclass(frozen=True)
class RateLimitConfig:
    """Per-tenant budget: ``rate`` tokens/s refill, ``burst`` capacity."""

    rate: float = 200.0
    burst: int = 100
    max_inflight: int = 64  # global gate (only read off the default config)


class TokenBucket:
    """Classic token bucket; thread-safe; injectable clock for tests."""

    def __init__(self, rate: float, burst: float,
                 clock: Callable[[], float] = time.monotonic):
        if rate <= 0 or burst <= 0:
            raise ValueError("rate and burst must be positive")
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._tokens = float(burst)
        self._last = clock()
        self._lock = threading.Lock()

    def try_take(self, n: float = 1.0) -> tuple[bool, float]:
        """Take ``n`` tokens if available. Returns ``(ok, retry_after)``;
        ``retry_after`` is how long until ``n`` tokens accrue (0 when ok).
        """
        with self._lock:
            now = self._clock()
            self._tokens = min(self.burst,
                               self._tokens + (now - self._last) * self.rate)
            self._last = now
            if self._tokens >= n:
                self._tokens -= n
                return True, 0.0
            return False, (n - self._tokens) / self.rate

    @property
    def tokens(self) -> float:
        """Current balance including accrual since the last take."""
        with self._lock:
            return min(self.burst,
                       self._tokens + (self._clock() - self._last) * self.rate)


class RateLimitedApi:
    """The v1 verb surface with per-tenant admission in front.

    ``inner`` is any object with the nine v1 verbs (``LoadBalancer``,
    ``ApiGateway``, ...). ``auth`` resolves API keys to tenants for bucket
    selection (without consuming the authentication itself — the gateway
    still authenticates admitted calls).
    """

    def __init__(self, inner, auth,
                 config: Optional[RateLimitConfig] = None,
                 per_tenant: Optional[Dict[str, RateLimitConfig]] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.inner = inner
        self.auth = auth
        self.config = config or RateLimitConfig()
        self.per_tenant = dict(per_tenant or {})
        self._clock = clock
        self._buckets: Dict[str, TokenBucket] = {}
        self._buckets_lock = threading.Lock()
        self._inflight = 0
        self._inflight_lock = threading.Lock()
        # counters are touched by every handler thread; guard them or the
        # shed/throttle numbers undercount under exactly the floods they
        # exist to measure
        self._stats_lock = threading.Lock()
        self.stats = {"admitted": 0, "throttled": 0, "shed_inflight": 0}
        self.throttled_by_tenant: Dict[str, int] = {}
        # observability hookup (attach_observability): throttles become
        # `rate_limited` platform events + per-tenant meter counts
        self._router = None

    def set_tenant_config(self, tenant: str, config: Optional[RateLimitConfig]):
        """Live-update one tenant's budget (v2 admin PATCH). ``None``
        reverts to the default config. The tenant's existing bucket is
        dropped so the new rate/burst apply to the very next request."""
        with self._buckets_lock:
            if config is None:
                self.per_tenant.pop(tenant, None)
            else:
                self.per_tenant[tenant] = config
            self._buckets.pop(tenant, None)

    # -- admission --------------------------------------------------------
    def _tenant_of(self, api_key: str) -> str:
        principal = self.auth.peek(api_key)
        return principal.tenant if principal is not None else _ANON

    def _bucket_for(self, tenant: str) -> TokenBucket:
        with self._buckets_lock:
            b = self._buckets.get(tenant)
            if b is None:
                cfg = self.per_tenant.get(tenant, self.config)
                b = TokenBucket(cfg.rate, cfg.burst, clock=self._clock)
                self._buckets[tenant] = b
        return b

    def _admit(self, api_key: str) -> str:
        tenant = self._tenant_of(api_key)
        ok, retry_after = self._bucket_for(tenant).try_take(1.0)
        if not ok:
            with self._stats_lock:
                self.stats["throttled"] += 1
                self.throttled_by_tenant[tenant] = \
                    self.throttled_by_tenant.get(tenant, 0) + 1
            self._note_throttle(tenant)
            raise ApiError(ErrorCode.RATE_LIMITED,
                           f"tenant {tenant!r} exceeded its request rate",
                           tenant=tenant, retry_after=round(retry_after, 4))
        return tenant

    def admit_once(self, api_key: str) -> str:
        """Spend ONE token for a long-lived SSE stream at open time. A
        stream then holds no in-flight slot and no further tokens — the
        server's own ``max_streams`` cap bounds concurrency instead."""
        return self._admit(api_key)

    # -- observability ----------------------------------------------------
    def attach_observability(self, router):
        """Give the limiter a TenantRouter so 429s become ``rate_limited``
        platform events on the throttled tenant's home shard (satellite:
        throttling must be operator-visible). No wire behavior change —
        the 429/Retry-After envelope is untouched."""
        self._router = router

    def _note_throttle(self, tenant: str):
        # Emitted WITHOUT any shard lock (handler thread) — the bus takes
        # its own mutex. Best-effort: anonymous floods have no home shard.
        if self._router is None or tenant == _ANON:
            return
        try:
            backend = self._router.shard_for(tenant)
            if backend.alive:
                backend.platform.events.emit("ratelimit", "rate_limited",
                                             tenant=tenant)
        except Exception:
            pass

    def _enter(self):
        with self._inflight_lock:
            if self._inflight >= self.config.max_inflight:
                with self._stats_lock:
                    self.stats["shed_inflight"] += 1
                raise ApiError(
                    ErrorCode.RATE_LIMITED,
                    f"API tier at max in-flight requests "
                    f"({self.config.max_inflight})",
                    retry_after=0.05)
            self._inflight += 1

    def _exit(self):
        with self._inflight_lock:
            self._inflight -= 1

    def throttle_non_admin(self, api_key: str):
        """Admission check for the v2 admin plane: operator keys (the
        ``admin``-scoped ``"*"`` principals) pass untouched — admin verbs
        are the operator's backpressure controls, not tenant traffic — but
        unknown keys, tenant keys, and wildcard keys WITHOUT the admin
        scope spend a token from their usual bucket, so a flood against
        ``/v2/admin`` is throttled before auth exactly like one against
        v1."""
        principal = self.auth.peek(api_key)
        if principal is not None and principal.is_admin \
                and principal.can(ADMIN):
            return
        self._admit(api_key)

    def _call(self, method: str, api_key: str, *args, **kwargs):
        # gate before bucket: a request shed at the in-flight limit (global
        # congestion the tenant didn't cause) must not also cost a token
        self._enter()
        try:
            self._admit(api_key)
            with self._stats_lock:
                self.stats["admitted"] += 1
            return getattr(self.inner, method)(api_key, *args, **kwargs)
        finally:
            self._exit()

    # -- full v1 surface, gated -------------------------------------------
    def submit(self, api_key, req):
        return self._call("submit", api_key, req)

    def status(self, api_key, job_id, **kwargs):
        return self._call("status", api_key, job_id, **kwargs)

    def status_history(self, api_key, job_id):
        return self._call("status_history", api_key, job_id)

    def list_jobs(self, api_key, **kwargs):
        return self._call("list_jobs", api_key, **kwargs)

    def logs(self, api_key, job_id, **kwargs):
        return self._call("logs", api_key, job_id, **kwargs)

    def search_logs(self, api_key, query, **kwargs):
        return self._call("search_logs", api_key, query, **kwargs)

    def halt(self, api_key, job_id, requeue: bool = False):
        return self._call("halt", api_key, job_id, requeue=requeue)

    def resume(self, api_key, job_id):
        return self._call("resume", api_key, job_id)

    def cancel(self, api_key, job_id):
        return self._call("cancel", api_key, job_id)

    # -- observability plane, gated ---------------------------------------
    def usage(self, api_key, **kwargs):
        return self._call("usage", api_key, **kwargs)

    def events(self, api_key, **kwargs):
        return self._call("events", api_key, **kwargs)
