"""Checkpointing to an object store (FfDL §3.8).

Layout per checkpoint ``<prefix>/step_<k>/``:
  * one zstd-compressed blob per pytree leaf (``leaf/<path>``),
  * ``MANIFEST.json`` written **last** — the atomicity commit marker. A
    checkpoint whose manifest is missing (writer crashed mid-save) or whose
    blob checksums mismatch (corruption) is invalid and skipped.

``latest_step`` implements the paper's recovery contract: "a FfDL component
running inside the pod searches the object store bucket for the latest
checkpoint and uses that to resume training". Restoration can re-shard onto
a different mesh (elastic recovery): blobs are full logical arrays, and the
caller device_puts them with whatever sharding the new mesh dictates.

``AsyncCheckpointer`` overlaps serialization/PUT with training (the
distributed-optimization trick of hiding checkpoint latency), while keeping
the commit-marker ordering.
"""

from __future__ import annotations

import json
import hashlib
import threading
from typing import Any, Optional

import jax
import msgpack
import numpy as np

import zlib

try:
    import zstandard
except ImportError:  # stdlib zlib fallback keeps checkpoints working
    zstandard = None

# One-byte codec tag so blobs round-trip across environments with and
# without zstandard installed (a zstd blob read where only zlib exists
# fails with a clear CheckpointError, not a raw codec error).
_TAG_ZLIB = b"\x01"
_TAG_ZSTD = b"\x02"
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"  # legacy untagged frames


def _compress(payload: bytes) -> bytes:
    if zstandard is not None:
        return _TAG_ZSTD + zstandard.ZstdCompressor(level=1).compress(payload)
    return _TAG_ZLIB + zlib.compress(payload, 1)


def _decompress(blob: bytes) -> bytes:
    tag = blob[:1]
    if tag == _TAG_ZSTD or blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise CheckpointError(
                "checkpoint blob is zstd-compressed but the zstandard "
                "module is not installed (see requirements-dev.txt)")
        body = blob[1:] if tag == _TAG_ZSTD else blob
        return zstandard.ZstdDecompressor().decompress(body)
    body = blob[1:] if tag == _TAG_ZLIB else blob
    return zlib.decompress(body)

from repro.utils.trees import tree_flatten_with_paths

try:  # registers bfloat16 et al with numpy
    import ml_dtypes  # noqa: F401
except ImportError:
    pass


class CheckpointError(Exception):
    pass


def _encode_leaf(arr) -> bytes:
    np_arr = np.asarray(arr)
    payload = msgpack.packb({
        "dtype": str(np_arr.dtype),
        "shape": list(np_arr.shape),
        "data": np_arr.tobytes(),
    })
    return _compress(payload)


def _decode_leaf(blob: bytes):
    payload = msgpack.unpackb(_decompress(blob))
    return np.frombuffer(payload["data"],
                         dtype=np.dtype(payload["dtype"])).reshape(payload["shape"])


def _sha(b: bytes) -> str:
    return hashlib.sha256(b).hexdigest()


def save(bucket, prefix: str, step: int, tree, metadata: Optional[dict] = None):
    """Synchronous checkpoint save. ``bucket`` is a MountedBucket-like."""
    base = f"{prefix}/step_{step:08d}"
    leaves = tree_flatten_with_paths(tree)
    manifest = {"step": step, "leaves": {}, "metadata": metadata or {}}
    for path, leaf in leaves:
        blob = _encode_leaf(jax.device_get(leaf))
        key = f"{base}/leaf/{path}"
        bucket.write(key, blob)
        manifest["leaves"][path] = {"key": key, "sha256": _sha(blob),
                                    "bytes": len(blob)}
    # Commit marker LAST: an interrupted save leaves no manifest → invalid.
    bucket.write(f"{base}/MANIFEST.json", json.dumps(manifest).encode())
    return base


def is_valid(bucket, prefix: str, step: int, verify_data: bool = True) -> bool:
    base = f"{prefix}/step_{step:08d}"
    if not bucket.exists(f"{base}/MANIFEST.json"):
        return False
    try:
        manifest = json.loads(bucket.read(f"{base}/MANIFEST.json"))
        for path, info in manifest["leaves"].items():
            if not bucket.exists(info["key"]):
                return False
            if verify_data and _sha(bucket.read(info["key"])) != info["sha256"]:
                return False
    except Exception:
        return False
    return True


def steps_available(bucket, prefix: str) -> list[int]:
    steps = set()
    for key in bucket.listdir(prefix + "/"):
        tail = key[len(prefix) + 1:]
        if tail.startswith("step_") and "/" in tail:
            try:
                steps.add(int(tail.split("/")[0][5:]))
            except ValueError:
                continue
    return sorted(steps)


def latest_step(bucket, prefix: str, verify_data: bool = True) -> Optional[int]:
    """Newest *valid* checkpoint step (corrupt/partial ones are skipped)."""
    for step in reversed(steps_available(bucket, prefix)):
        if is_valid(bucket, prefix, step, verify_data=verify_data):
            return step
    return None


def restore(bucket, prefix: str, step: int, like=None, shardings=None):
    """Load a checkpoint. ``like`` (a pytree) provides the structure; leaves
    are returned as numpy (or device_put with ``shardings`` when given,
    enabling restore onto a different mesh than the one that saved)."""
    base = f"{prefix}/step_{step:08d}"
    try:
        manifest = json.loads(bucket.read(f"{base}/MANIFEST.json"))
    except Exception as e:
        raise CheckpointError(f"no manifest for {base}: {e}")
    by_path = {}
    for path, info in manifest["leaves"].items():
        blob = bucket.read(info["key"])
        if _sha(blob) != info["sha256"]:
            raise CheckpointError(f"checksum mismatch for {path}")
        by_path[path] = _decode_leaf(blob)
    if like is None:
        return by_path, manifest["metadata"]

    flat = tree_flatten_with_paths(like)
    missing = [p for p, _ in flat if p not in by_path]
    if missing:
        raise CheckpointError(f"checkpoint missing leaves: {missing[:5]}")
    arrays = [by_path[p] for p, _ in flat]
    if shardings is not None:
        shard_flat = [s for _, s in tree_flatten_with_paths(shardings)]
        arrays = [jax.device_put(a, s) if s is not None else jax.numpy.asarray(a)
                  for a, s in zip(arrays, shard_flat)]
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, arrays), manifest["metadata"]


class AsyncCheckpointer:
    """Fire-and-forget saves on a background thread, one in flight at a time
    (a new save waits for the previous — preserves step ordering)."""

    def __init__(self, bucket, prefix: str):
        self.bucket = bucket
        self.prefix = prefix
        self._thread: Optional[threading.Thread] = None
        self.error: Optional[Exception] = None
        self.saved_steps: list[int] = []

    def save(self, step: int, tree, metadata: Optional[dict] = None):
        self.wait()
        # Snapshot to host memory synchronously (cheap) so training can
        # mutate device buffers while the PUTs run in the background.
        host_tree = jax.tree.map(jax.device_get, tree)

        def run():
            try:
                save(self.bucket, self.prefix, step, host_tree, metadata)
                self.saved_steps.append(step)
            except Exception as e:  # surfaced on next wait()
                self.error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            err, self.error = self.error, None
            raise err


def prune_old(bucket, prefix: str, keep: int = 3):
    """Delete all but the newest ``keep`` checkpoints."""
    steps = steps_available(bucket, prefix)
    for step in steps[:-keep] if keep else steps:
        base = f"{prefix}/step_{step:08d}"
        for key in bucket.listdir(base):
            bucket.store.delete(bucket.bucket, key)
