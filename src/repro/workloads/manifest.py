"""Declarative workload manifests: the spec-in half of the resource model.

FfDL's job spec (§3) is declarative at the single-job level; this module
extends it to whole *workloads* — the fiaas Application-CRD pattern: a
manifest describes desired state, the reconciler (:mod:`.reconciler`)
converges the platform to it, and the status block on the stored resource
reports how far along it is. Three kinds:

  * ``Pipeline`` — a DAG of named stages (train → eval → serve). Each
    stage either submits a v1 job (``job:`` — a :class:`JobManifest`
    field dict) or materializes a child ``Service`` (``service:``).
    ``after: [names]`` gates a stage on its predecessors' completion;
    ``retries:`` bounds per-stage resubmits before the pipeline is
    marked DEGRADED.
  * ``RecurringJob`` — one job spec re-submitted every ``every_ticks``
    platform ticks, with an ``overlap:`` policy (``skip`` | ``allow`` |
    ``replace``) deciding what happens when the previous run is still
    live, and an optional ``max_runs``.
  * ``Service`` — a multi-tenant inference serving tier: ``replicas:``
    long-running replica jobs per tenant (each a platform job holding
    ``chips_per_replica`` chips), scaled by editing ``replicas:`` and
    re-applying.

Manifests arrive as JSON or as a **minimal, no-dependency YAML subset**
(:func:`parse_manifest_text`): nested mappings by 2-space-ish
indentation, ``- `` list items (inline-map form supported), inline flow
lists ``[a, b]``, ``#`` comments, and plain/quoted scalars with
JSON-style type inference. It is deliberately tiny — anything it cannot
parse is an ``INVALID_ARGUMENT``, never a guess.

Validation (:func:`validate_workload`) is strict the same way the v1
submit path is: unknown fields at any level are rejected with
``INVALID_ARGUMENT`` (satellite: typos in a manifest-derived spec must
not be maskable), stage DAGs must be acyclic with resolvable ``after``
references, and embedded job specs are checked against the
:class:`JobManifest` field vocabulary plus ``TRAIN_SPEC_FIELDS``.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.api.types import ApiError, ErrorCode
from repro.core.types import JobManifest, TRAIN_SPEC_FIELDS

WORKLOAD_KINDS = ("Pipeline", "RecurringJob", "Service")

OVERLAP_POLICIES = ("skip", "allow", "replace")

_JOB_FIELDS = {f.name for f in dataclasses.fields(JobManifest)}

# Per-kind field vocabularies (strict: unknown keys are rejected).
_COMMON_FIELDS = {"kind", "name", "tenant"}
_PIPELINE_FIELDS = _COMMON_FIELDS | {"stages"}
_STAGE_FIELDS = {"name", "job", "service", "after", "retries"}
_RECURRING_FIELDS = _COMMON_FIELDS | {"job", "every_ticks", "overlap",
                                      "max_runs"}
_SERVICE_FIELDS = _COMMON_FIELDS | {"replicas", "chips_per_replica",
                                    "arch", "engine", "tier"}
_ENGINES = ("sim", "real")


def _bad(msg: str, **details) -> ApiError:
    return ApiError(ErrorCode.INVALID_ARGUMENT, msg, **details)


# --------------------------------------------------------------------------
# The YAML subset
# --------------------------------------------------------------------------

def _scalar(tok: str):
    """JSON-ish scalar inference for the YAML subset."""
    tok = tok.strip()
    if tok.startswith('"') and tok.endswith('"') and len(tok) >= 2:
        return json.loads(tok)
    if tok.startswith("'") and tok.endswith("'") and len(tok) >= 2:
        return tok[1:-1]
    low = tok.lower()
    if low in ("null", "~", ""):
        return None
    if low == "true":
        return True
    if low == "false":
        return False
    try:
        return int(tok)
    except ValueError:
        pass
    try:
        return float(tok)
    except ValueError:
        pass
    return tok


def _flow_list(tok: str) -> list:
    """``[a, b, c]`` → list of scalars (no nesting — manifests don't
    need it, and refusing beats guessing)."""
    inner = tok.strip()[1:-1].strip()
    if not inner:
        return []
    if "[" in inner or "{" in inner:
        raise _bad("nested flow collections are not in the YAML subset")
    return [_scalar(p) for p in inner.split(",")]


def _split_key(line: str, lineno: int):
    """``key: value`` → (key, value-token); value may be empty."""
    if ":" not in line:
        raise _bad(f"line {lineno}: expected 'key: value', got {line!r}")
    key, _, rest = line.partition(":")
    key = key.strip()
    if not key:
        raise _bad(f"line {lineno}: empty key")
    return key, rest.strip()


def parse_yaml(text: str):
    """Parse the minimal YAML subset. Returns dict/list/scalar."""
    lines = []
    for i, raw in enumerate(text.splitlines(), start=1):
        if "\t" in raw:
            raise _bad(f"line {i}: tabs are not allowed in manifests")
        stripped = raw.split("#", 1)[0].rstrip()
        if not stripped.strip():
            continue
        indent = len(stripped) - len(stripped.lstrip(" "))
        lines.append((i, indent, stripped.strip()))
    if not lines:
        raise _bad("empty manifest")
    value, nxt = _parse_block(lines, 0, lines[0][1])
    if nxt != len(lines):
        lineno = lines[nxt][0]
        raise _bad(f"line {lineno}: unexpected de-indent/content")
    return value


def _parse_block(lines, pos, indent):
    """Parse one block (mapping or list) at exactly ``indent``."""
    if lines[pos][2].startswith("- ") or lines[pos][2] == "-":
        return _parse_list(lines, pos, indent)
    return _parse_map(lines, pos, indent)


def _parse_map(lines, pos, indent):
    out = {}
    while pos < len(lines):
        lineno, ind, content = lines[pos]
        if ind < indent:
            break
        if ind > indent:
            raise _bad(f"line {lineno}: unexpected indent")
        if content.startswith("- "):
            raise _bad(f"line {lineno}: list item in a mapping block")
        key, tok = _split_key(content, lineno)
        if key in out:
            raise _bad(f"line {lineno}: duplicate key {key!r}")
        pos += 1
        if tok:
            out[key] = _flow_list(tok) if tok.startswith("[") else \
                _scalar(tok)
        else:
            # nested block (or an explicitly empty value at EOF/dedent)
            if pos < len(lines) and lines[pos][1] > indent:
                out[key], pos = _parse_block(lines, pos, lines[pos][1])
            else:
                out[key] = None
    return out, pos


def _parse_list(lines, pos, indent):
    out = []
    while pos < len(lines):
        lineno, ind, content = lines[pos]
        if ind < indent:
            break
        if ind > indent:
            raise _bad(f"line {lineno}: unexpected indent")
        if not (content.startswith("- ") or content == "-"):
            break
        body = content[2:].strip() if content.startswith("- ") else ""
        pos += 1
        if not body:
            # "-" alone: nested block item
            if pos < len(lines) and lines[pos][1] > indent:
                item, pos = _parse_block(lines, pos, lines[pos][1])
                out.append(item)
            else:
                out.append(None)
            continue
        if ":" in body and not body.startswith(("[", '"', "'")):
            # inline-map item: "- name: train" opens a mapping whose
            # continuation lines are indented past the dash
            key, tok = _split_key(body, lineno)
            item = {key: (_flow_list(tok) if tok.startswith("[")
                          else _scalar(tok)) if tok else None}
            if tok == "" and pos < len(lines) and \
                    lines[pos][1] > indent + 2:
                item[key], pos = _parse_block(lines, pos, lines[pos][1])
            if pos < len(lines) and lines[pos][1] == indent + 2 and \
                    not lines[pos][2].startswith("- "):
                rest, pos = _parse_map(lines, pos, indent + 2)
                for k, v in rest.items():
                    if k in item:
                        raise _bad(f"duplicate key {k!r} in list item")
                    item[k] = v
            out.append(item)
        else:
            out.append(_flow_list(body) if body.startswith("[")
                       else _scalar(body))
    return out, pos


def parse_manifest_text(text: str) -> dict:
    """JSON (leading ``{``) or the YAML subset → a raw manifest dict."""
    if not isinstance(text, str) or not text.strip():
        raise _bad("empty manifest text")
    if text.lstrip().startswith("{"):
        try:
            d = json.loads(text)
        except ValueError as e:
            raise _bad(f"manifest is not valid JSON: {e}")
    else:
        d = parse_yaml(text)
    if not isinstance(d, dict):
        raise _bad("manifest must be a mapping at the top level")
    return d


# --------------------------------------------------------------------------
# Validation → normalized spec
# --------------------------------------------------------------------------

def _require_str(d: dict, key: str, where: str) -> str:
    v = d.get(key)
    if not isinstance(v, str) or not v:
        raise _bad(f"{where}.{key} must be a non-empty string")
    return v


def _int_field(d: dict, key: str, where: str, default=None,
               minimum: int = 0) -> Optional[int]:
    v = d.get(key, default)
    if v is None:
        return None
    if isinstance(v, bool) or not isinstance(v, int):
        raise _bad(f"{where}.{key} must be an integer")
    if v < minimum:
        raise _bad(f"{where}.{key} must be >= {minimum}")
    return v


def validate_job_spec(d, where: str, tenant: str) -> dict:
    """An embedded v1 job spec: JobManifest fields minus ``tenant``
    (inherited from the workload), strict on unknown keys at both the
    manifest and ``train:`` levels — the same hygiene the v1 submit
    path enforces, applied at apply() time so a bad stage spec fails
    the whole manifest before anything runs."""
    if not isinstance(d, dict):
        raise _bad(f"{where} must be a mapping of JobManifest fields")
    unknown = sorted(set(d) - _JOB_FIELDS)
    if unknown:
        raise _bad(f"{where}: unknown job spec fields: {unknown}")
    if d.get("tenant") not in (None, tenant):
        raise _bad(f"{where}.tenant must be omitted or {tenant!r}")
    train = d.get("train", {})
    if not isinstance(train, dict):
        raise _bad(f"{where}.train must be a mapping")
    bad = sorted(set(train) - set(TRAIN_SPEC_FIELDS))
    if bad:
        raise _bad(f"{where}.train: unknown train spec fields: {bad} "
                   f"(known: {list(TRAIN_SPEC_FIELDS)})")
    out = dict(d)
    out.pop("tenant", None)
    return out


def _validate_service_fields(d: dict, where: str) -> dict:
    unknown = sorted(set(d) - _SERVICE_FIELDS)
    if unknown:
        raise _bad(f"{where}: unknown Service fields: {unknown}")
    out = {
        "replicas": _int_field(d, "replicas", where, default=1),
        "chips_per_replica": _int_field(d, "chips_per_replica", where,
                                        default=1, minimum=1),
        "engine": d.get("engine", "sim"),
        "tier": d.get("tier", "paid"),
    }
    if d.get("arch") is not None:
        out["arch"] = _require_str(d, "arch", where)
    if out["engine"] not in _ENGINES:
        raise _bad(f"{where}.engine must be one of {list(_ENGINES)}")
    return out


def _validate_stages(stages, tenant: str) -> list:
    if not isinstance(stages, list) or not stages:
        raise _bad("Pipeline.stages must be a non-empty list")
    names = []
    out = []
    for i, s in enumerate(stages):
        where = f"stages[{i}]"
        if not isinstance(s, dict):
            raise _bad(f"{where} must be a mapping")
        unknown = sorted(set(s) - _STAGE_FIELDS)
        if unknown:
            raise _bad(f"{where}: unknown stage fields: {unknown}")
        name = _require_str(s, "name", where)
        if name in names:
            raise _bad(f"{where}: duplicate stage name {name!r}")
        names.append(name)
        after = s.get("after", [])
        if not isinstance(after, list) or \
                not all(isinstance(a, str) for a in after):
            raise _bad(f"{where}.after must be a list of stage names")
        has_job = s.get("job") is not None
        has_svc = s.get("service") is not None
        if has_job == has_svc:
            raise _bad(f"{where}: exactly one of job:/service: is required")
        stage = {"name": name, "after": sorted(set(after)),
                 "retries": _int_field(s, "retries", where, default=0)}
        if has_job:
            stage["job"] = validate_job_spec(s["job"], f"{where}.job",
                                             tenant)
        else:
            svc = s["service"]
            if not isinstance(svc, dict):
                raise _bad(f"{where}.service must be a mapping")
            svc = dict(svc)
            svc_name = svc.pop("name", None)
            stage["service"] = _validate_service_fields(
                {k: v for k, v in svc.items()}, f"{where}.service")
            if svc_name is not None:
                if not isinstance(svc_name, str) or not svc_name:
                    raise _bad(f"{where}.service.name must be a string")
                stage["service_name"] = svc_name
        out.append(stage)
    # DAG checks: references resolve, no cycles (Kahn over sorted names
    # so the canonical stage order is deterministic)
    known = set(names)
    deps = {s["name"]: set(s["after"]) for s in out}
    for s in out:
        missing = sorted(set(s["after"]) - known)
        if missing:
            raise _bad(f"stage {s['name']!r}: after references unknown "
                       f"stages {missing}")
        if s["name"] in s["after"]:
            raise _bad(f"stage {s['name']!r} depends on itself")
    order, ready = [], sorted(n for n, d in deps.items() if not d)
    remaining = {n: set(d) for n, d in deps.items() if d}
    while ready:
        n = ready.pop(0)
        order.append(n)
        newly = []
        for m, d in list(remaining.items()):
            d.discard(n)
            if not d:
                del remaining[m]
                newly.append(m)
        ready = sorted(ready + newly)
    if remaining:
        raise _bad(f"Pipeline.stages has a dependency cycle through "
                   f"{sorted(remaining)}")
    return out


def validate_workload(d) -> dict:
    """Raw manifest dict → normalized, strictly-validated spec dict.

    The returned dict is canonical: re-validating an equal input yields
    an equal output, which is what makes ``apply`` idempotence a simple
    spec comparison on the plane."""
    if not isinstance(d, dict):
        raise _bad("manifest must be a mapping")
    kind = d.get("kind")
    if kind not in WORKLOAD_KINDS:
        raise _bad(f"manifest.kind must be one of {list(WORKLOAD_KINDS)}, "
                   f"got {kind!r}")
    name = _require_str(d, "name", "manifest")
    tenant = _require_str(d, "tenant", "manifest")
    spec = {"kind": kind, "name": name, "tenant": tenant}

    if kind == "Pipeline":
        unknown = sorted(set(d) - _PIPELINE_FIELDS)
        if unknown:
            raise _bad(f"unknown Pipeline fields: {unknown}")
        spec["stages"] = _validate_stages(d.get("stages"), tenant)
    elif kind == "RecurringJob":
        unknown = sorted(set(d) - _RECURRING_FIELDS)
        if unknown:
            raise _bad(f"unknown RecurringJob fields: {unknown}")
        if d.get("job") is None:
            raise _bad("RecurringJob.job is required")
        spec["job"] = validate_job_spec(d["job"], "job", tenant)
        spec["every_ticks"] = _int_field(d, "every_ticks", "manifest",
                                         default=None, minimum=1)
        if spec["every_ticks"] is None:
            raise _bad("RecurringJob.every_ticks is required (>= 1)")
        spec["overlap"] = d.get("overlap", "skip")
        if spec["overlap"] not in OVERLAP_POLICIES:
            raise _bad(f"RecurringJob.overlap must be one of "
                       f"{list(OVERLAP_POLICIES)}")
        spec["max_runs"] = _int_field(d, "max_runs", "manifest",
                                     default=None, minimum=1)
    else:  # Service
        spec.update(_validate_service_fields(
            {k: v for k, v in d.items() if k not in _COMMON_FIELDS},
            "manifest"))
    return spec


def job_manifest_for(spec: dict, tenant: str, default_name: str) \
        -> JobManifest:
    """Normalized job spec dict → a typed JobManifest owned by ``tenant``."""
    d = dict(spec)
    d.setdefault("name", default_name)
    d["tenant"] = tenant
    return JobManifest(**d)
