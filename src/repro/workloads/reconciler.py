"""The workloads reconciler: converge applied manifests, one Federation tick
at a time.

Same architecture as the autonomous operator (:mod:`repro.obs.operator`),
deliberately: :class:`ReconcilerPolicy` is a *pure* state machine —
``decide(obs)`` maps an observation dict to a list of decision dicts with
no I/O, no clock, no RNG, and every enumeration canonically sorted, so the
decision journal is a deterministic function of the observed state no
matter how the observation was assembled (the property test replays a
trace under shuffled orderings and asserts identical journals, and a
steady-state observation decides *nothing*, which is the apply-twice
idempotence the tests pin). :class:`WorkloadReconciler` wraps it with
sensing and acting:

  * **sense** — drain each live shard's event bus through a private
    cursor for ``job_completed`` / ``job_failed`` terminal notices (the
    EventBus is the primary gate, per the paper's event-driven Guardian),
    backstopped by reading tracked jobs' statuses from the metastore
    under the shard read lock so a ring-compacted bus can never stall a
    pipeline; snapshot every manifest's spec + status;
  * **decide** — pipelines as DAGs (a stage submits when all of its
    ``after`` deps are DONE; terminal job events gate successors; a
    failed stage retries ``retries:`` times and then fails, skipping its
    descendants and degrading the pipeline), recurring jobs on a
    tick-based schedule with ``overlap: skip | allow | replace``, and
    services as slot→replica maps healed toward ``replicas:``;
  * **act** — every mutation goes through the same doors a client would
    use: stage jobs and serving replicas are v1 gateway submits (with
    ``wl/…`` idempotency keys, so a crashed-and-reconverging reconciler
    re-submits into the dedup window instead of duplicating work), child
    Services a pipeline materializes are plane ``apply`` calls, teardown
    is v1 ``cancel``. Each act is journaled as a ``workload_*`` platform
    event; ready serving replicas accrue ``serving_replica_seconds``
    into their tenant's shard meter every tick.

Lock order is plane mutex → shard lock, identical to the plane verbs, so
a wire ``apply`` and a reconcile step serialize instead of deadlocking.
"""

from __future__ import annotations

import collections
import copy
import threading
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Set

# Decision/event vocabulary. Every act the reconciler (or the plane, for
# apply/delete) takes is journaled on the event bus under one of these
# kinds — part of PLATFORM_EVENT_KINDS (docs/api.md pins them).
WORKLOAD_EVENT_KINDS = (
    "workload_applied",
    "workload_deleted",
    "workload_stage_submitted",
    "workload_stage_failed",
    "workload_pipeline_done",
    "workload_pipeline_degraded",
    "workload_recurring_run",
    "workload_recurring_skipped",
    "workload_service_scaled",
    "workload_service_ready",
    "workload_service_degraded",
)

# Stage states a pipeline DAG node moves through (terminal: DONE /
# FAILED / SKIPPED). Documented in docs/api.md's workloads section.
STAGE_TERMINAL = ("DONE", "FAILED", "SKIPPED")


@dataclass(frozen=True)
class ReconcilerConfig:
    """Knobs for the workloads reconciler (docs/architecture.md)."""

    replica_sim_duration: float = 1e9   # serving replicas run "forever"
    max_decisions: int = 400            # decision-journal ring size
    event_page: int = 5000              # bus events drained per page


def _outcome(job_id: Optional[str], jobs: Dict[str, str],
             completed: Set[str], failed: Set[str]) -> Optional[str]:
    """Terminal outcome of a tracked job: "completed" / "failed", or
    ``None`` while it runs (or while its shard is unreachable — an
    outage must not look like a failure and trigger spurious retries).
    Bus events are consulted first, metastore status second."""
    if job_id is None:
        return None
    if job_id in failed:
        return "failed"
    if job_id in completed:
        return "completed"
    st = jobs.get(job_id)
    if st == "COMPLETED":
        return "completed"
    if st == "FAILED":
        return "failed"
    return None


class ReconcilerPolicy:
    """Pure decision core: ``decide(obs)`` -> list of decision dicts."""

    def __init__(self, config: ReconcilerConfig):
        self.config = config
        self.tick = 0
        self.decisions: Deque[dict] = collections.deque(
            maxlen=config.max_decisions)

    def _log(self, decision: dict) -> dict:
        decision = {"tick": self.tick, **decision}
        self.decisions.append(decision)
        return decision

    def decide(self, obs: dict) -> List[dict]:
        self.tick = obs["tick"]
        jobs = obs["jobs"]
        completed = frozenset(obs["completed"])
        failed = frozenset(obs["failed"])
        manifests = sorted(obs["manifests"],
                           key=lambda m: (m["tenant"], m["name"]))
        by_key = {(m["tenant"], m["name"]): m for m in manifests}
        out: List[dict] = []
        for m in manifests:
            if m["kind"] == "Pipeline":
                self._decide_pipeline(m, by_key, jobs, completed, failed,
                                      out)
            elif m["kind"] == "RecurringJob":
                self._decide_recurring(m, jobs, completed, failed, out)
            else:
                self._decide_service(m, jobs, completed, failed, out)
        return out

    # -- pipelines ---------------------------------------------------------
    def _decide_pipeline(self, m, by_key, jobs, completed, failed, out):
        st = m["status"]
        if st["phase"] in ("SUCCEEDED", "DEGRADED"):
            return
        base = {"tenant": m["tenant"], "name": m["name"]}
        stages = m["spec"]["stages"]   # validation order = submit order
        sst = st["stages"]
        for s in stages:
            cur = sst[s["name"]]
            if cur["state"] == "PENDING":
                dep_states = [sst[d]["state"] for d in s["after"]]
                if any(ds in ("FAILED", "SKIPPED") for ds in dep_states):
                    out.append(self._log({
                        **base, "action": "stage_skip", "stage": s["name"],
                        "reason": "an upstream stage failed"}))
                elif all(ds == "DONE" for ds in dep_states):
                    if s.get("service") is not None:
                        out.append(self._log({
                            **base, "action": "stage_service",
                            "stage": s["name"]}))
                    else:
                        out.append(self._log({
                            **base, "action": "stage_submit",
                            "stage": s["name"],
                            "attempt": cur["attempts"]}))
            elif cur["state"] == "RUNNING":
                if s.get("service") is not None:
                    child = by_key.get((m["tenant"], cur.get("service")))
                    if child is None:
                        out.append(self._log({
                            **base, "action": "stage_failed",
                            "stage": s["name"], "job": None,
                            "reason": "materialized Service was deleted"}))
                    elif child["status"].get("phase") == "RUNNING":
                        out.append(self._log({
                            **base, "action": "stage_done",
                            "stage": s["name"]}))
                    continue
                oc = _outcome(cur["job"], jobs, completed, failed)
                if oc == "completed":
                    out.append(self._log({
                        **base, "action": "stage_done",
                        "stage": s["name"]}))
                elif oc == "failed":
                    if cur["attempts"] <= s["retries"]:
                        out.append(self._log({
                            **base, "action": "stage_retry",
                            "stage": s["name"], "attempt": cur["attempts"],
                            "reason": (f"attempt {cur['attempts']} of "
                                       f"{1 + s['retries']} failed")}))
                    else:
                        out.append(self._log({
                            **base, "action": "stage_failed",
                            "stage": s["name"], "job": cur["job"],
                            "reason": (f"failed after {cur['attempts']} "
                                       f"attempts (retries: "
                                       f"{s['retries']})")}))
        states = [sst[s["name"]]["state"] for s in stages]
        if st["phase"] == "RUNNING" and all(
                x in STAGE_TERMINAL for x in states):
            if all(x == "DONE" for x in states):
                out.append(self._log({
                    **base, "action": "pipeline_done"}))
            else:
                out.append(self._log({
                    **base, "action": "pipeline_degraded",
                    "failed_stages": sorted(
                        s["name"] for s in stages
                        if sst[s["name"]]["state"] != "DONE")}))

    # -- recurring jobs ----------------------------------------------------
    def _decide_recurring(self, m, jobs, completed, failed, out):
        st = m["status"]
        if st["phase"] != "ACTIVE":
            return
        base = {"tenant": m["tenant"], "name": m["name"]}
        spec = m["spec"]
        live = sorted(j for j in st["jobs"]
                      if _outcome(j, jobs, completed, failed) is None)
        done = sorted(j for j in st["jobs"] if j not in set(live))
        max_runs = spec.get("max_runs")
        if max_runs is not None and st["runs"] >= max_runs:
            if not live:
                out.append(self._log({
                    **base, "action": "recurring_done",
                    "reason": f"max_runs {max_runs} reached"}))
            return
        due = (st["last_run_tick"] is None
               or self.tick - st["last_run_tick"] >= spec["every_ticks"])
        if not due:
            return
        if live and spec["overlap"] == "skip":
            out.append(self._log({
                **base, "action": "recurring_skip",
                "live": live, "reason": "previous run still live"}))
        elif live and spec["overlap"] == "replace":
            out.append(self._log({
                **base, "action": "recurring_replace", "cancel": live,
                "prune": done, "run": st["runs"]}))
        else:
            out.append(self._log({
                **base, "action": "recurring_run", "prune": done,
                "run": st["runs"]}))

    # -- services ----------------------------------------------------------
    def _decide_service(self, m, jobs, completed, failed, out):
        st = m["status"]
        base = {"tenant": m["tenant"], "name": m["name"]}
        desired = m["spec"]["replicas"]
        replicas = st["replicas"]
        for slot in range(desired):
            k = str(slot)
            job = replicas.get(k)
            if job is None:
                out.append(self._log({
                    **base, "action": "replica_start", "slot": k,
                    "reason": "slot empty"}))
                continue
            # a serving replica must never exit: any terminal outcome in
            # a slot is a dead replica — restart it. (An unreachable
            # shard reports nothing, and nothing is not an outcome.)
            oc = _outcome(job, jobs, completed, failed)
            if oc is not None:
                out.append(self._log({
                    **base, "action": "replica_start", "slot": k,
                    "replaces": job,
                    "reason": f"replica job ended ({oc})"}))
        extra = sorted((k for k in replicas if int(k) >= desired), key=int)
        for k in extra:
            out.append(self._log({
                **base, "action": "replica_stop", "slot": k,
                "job": replicas[k],
                "reason": f"scaled down to {desired}"}))
        ready = sorted((k for k in replicas
                        if int(k) < desired
                        and jobs.get(replicas[k]) == "PROCESSING"),
                       key=int)
        if desired == 0:
            phase = "STOPPED"
        elif len(ready) == desired:
            phase = "RUNNING"
        elif st["phase"] in ("RUNNING", "DEGRADED"):
            phase = "DEGRADED"
        else:
            phase = "PENDING"
        if ready != st["ready_slots"] or phase != st["phase"]:
            out.append(self._log({
                **base, "action": "service_status", "ready": ready,
                "phase": phase, "prev_phase": st["phase"]}))


class WorkloadReconciler:
    """Sense → decide → act wrapper stepped from ``Federation.tick`` after
    ``admin.advance()`` and the operator — never from inside a shard tick
    (it submits through the gateway, which takes shard locks)."""

    def __init__(self, fed, plane, config: Optional[ReconcilerConfig] = None):
        self.fed = fed
        self.plane = plane
        self.config = config or ReconcilerConfig()
        self.policy = ReconcilerPolicy(self.config)
        self._mutex = threading.RLock()
        self._ticks = 0
        self._cursors: Dict[str, int] = {}   # shard_id -> bus cursor
        self._completed: Set[str] = set()    # event-derived terminal sets
        self._failed: Set[str] = set()

    # -- the loop -----------------------------------------------------------
    def step(self) -> List[dict]:
        """One reconcile pass over every applied manifest."""
        with self.plane._mutex:
            with self._mutex:
                obs = self._sense()
                decisions = self.policy.decide(obs)
                for d in decisions:
                    self._act(d)
                self._meter_serving()
                return decisions

    def journal(self) -> List[dict]:
        with self._mutex:
            return [dict(d) for d in self.policy.decisions]

    def status_view(self) -> dict:
        from repro.api.types import ADMIN_API_VERSION
        with self.plane._mutex:
            with self._mutex:
                return {"api_version": ADMIN_API_VERSION,
                        "tick": self._ticks,
                        "resources": len(self.plane.records),
                        "decisions": [dict(d)
                                      for d in self.policy.decisions]}

    # -- sensing ------------------------------------------------------------
    def _sense(self) -> dict:
        self._ticks += 1
        # 1. event gate: drain terminal job notices from every live bus.
        for b in sorted(self.fed.router.backends, key=lambda b: b.shard_id):
            if not b.alive:
                continue  # cursor kept; catch up if the shard revives
            bus = b.platform.events
            cur = self._cursors.get(b.shard_id, -1)
            while True:
                evs, cur, _missed = bus.read_since(
                    cur, self.config.event_page, None, None)
                for e in evs:
                    if e.kind == "job_completed":
                        self._completed.add(e.fields.get("job"))
                    elif e.kind == "job_failed":
                        self._failed.add(e.fields.get("job"))
                if len(evs) < self.config.event_page:
                    break
            self._cursors[b.shard_id] = cur
        # 2. status backstop: read every tracked job's metastore record
        # under its home shard's read lock (ring compaction can drop
        # events; a pipeline must still converge).
        tracked_by_tenant: Dict[str, Set[str]] = {}
        all_tracked: Set[str] = set()
        for rec in self.plane.records.values():
            ids = rec.tracked_jobs()
            all_tracked.update(ids)
            if ids:
                tracked_by_tenant.setdefault(
                    rec.tenant, set()).update(ids)
        self._completed &= all_tracked
        self._failed &= all_tracked
        jobs: Dict[str, str] = {}
        for tenant in sorted(tracked_by_tenant):
            try:
                b = self.fed.router.shard_for(tenant)
            except Exception:
                continue
            if not b.alive:
                continue
            with b.read_locked():
                meta = b.platform.meta
                for j in sorted(tracked_by_tenant[tenant]):
                    r = meta.get(j)
                    if r is not None:
                        jobs[j] = r.status.value
        manifests = [{"tenant": rec.tenant, "name": rec.name,
                      "kind": rec.kind, "generation": rec.generation,
                      "spec": copy.deepcopy(rec.spec),
                      "status": copy.deepcopy(rec.status)}
                     for rec in self.plane.records.values()]
        return {"tick": self._ticks, "manifests": manifests, "jobs": jobs,
                "completed": sorted(self._completed),
                "failed": sorted(self._failed)}

    # -- acting -------------------------------------------------------------
    def _act(self, d: dict):
        from repro.api.types import ApiError
        try:
            self._dispatch(d)
        except ApiError as exc:
            # The gateway refused (quota exhausted, admission preempted
            # the window, shard down mid-act…). Journal and move on: the
            # next tick re-observes and re-decides.
            self.policy._log({"action": "act_failed",
                              "attempted": d["action"],
                              "tenant": d.get("tenant"),
                              "name": d.get("name"), "error": str(exc),
                              "reason": "v1/plane verb rejected the act"})

    def _dispatch(self, d: dict):
        rec = self.plane.records.get((d["tenant"], d["name"]))
        if rec is None:
            return  # deleted between decide and act
        fn = getattr(self, "_act_" + d["action"])
        fn(rec, d)

    def _submit(self, manifest, idempotency_key: str) -> str:
        from repro.api.types import SubmitRequest
        resp = self.plane._api.submit(
            self.plane._key,
            SubmitRequest(manifest=manifest,
                          idempotency_key=idempotency_key))
        return resp.job_id

    def _cancel(self, job_id: str):
        from repro.api.types import ApiError
        try:
            self.plane._api.cancel(self.plane._key, job_id)
        except ApiError:
            pass  # already terminal / unknown / shard down

    # stage verbs
    def _stage(self, rec, name):
        return next(s for s in rec.spec["stages"] if s["name"] == name)

    def _act_stage_submit(self, rec, d):
        from repro.workloads.manifest import job_manifest_for
        s = self._stage(rec, d["stage"])
        jm = job_manifest_for(s["job"], rec.tenant,
                              f"{rec.name}-{d['stage']}")
        idem = (f"wl/{rec.tenant}/{rec.name}/g{rec.generation}"
                f"/{d['stage']}/a{d['attempt']}")
        job_id = self._submit(jm, idem)
        cur = rec.status["stages"][d["stage"]]
        cur.update(state="RUNNING", job=job_id,
                   attempts=d["attempt"] + 1)
        if rec.status["phase"] == "PENDING":
            rec.status["phase"] = "RUNNING"
        self.plane._emit("workload_stage_submitted", rec.tenant,
                         name=rec.name, stage=d["stage"], job=job_id,
                         attempt=d["attempt"] + 1)

    _act_stage_retry = _act_stage_submit

    def _act_stage_service(self, rec, d):
        s = self._stage(rec, d["stage"])
        child = f"{rec.name}-{d['stage']}"
        self.plane.apply({"kind": "Service", "name": child,
                          "tenant": rec.tenant, **s["service"]},
                         owner=(rec.tenant, rec.name))
        cur = rec.status["stages"][d["stage"]]
        cur.update(state="RUNNING", service=child)
        if rec.status["phase"] == "PENDING":
            rec.status["phase"] = "RUNNING"

    def _act_stage_done(self, rec, d):
        rec.status["stages"][d["stage"]]["state"] = "DONE"

    def _act_stage_skip(self, rec, d):
        rec.status["stages"][d["stage"]]["state"] = "SKIPPED"

    def _act_stage_failed(self, rec, d):
        rec.status["stages"][d["stage"]]["state"] = "FAILED"
        self.plane._emit("workload_stage_failed", rec.tenant,
                         name=rec.name, stage=d["stage"],
                         job=d.get("job"), reason=d.get("reason", ""))

    def _act_pipeline_done(self, rec, d):
        rec.status["phase"] = "SUCCEEDED"
        self.plane._emit("workload_pipeline_done", rec.tenant,
                         name=rec.name, generation=rec.generation)

    def _act_pipeline_degraded(self, rec, d):
        rec.status["phase"] = "DEGRADED"
        self.plane._emit("workload_pipeline_degraded", rec.tenant,
                         name=rec.name, generation=rec.generation,
                         failed_stages=d.get("failed_stages", []))

    # recurring verbs
    def _act_recurring_run(self, rec, d):
        from repro.workloads.manifest import job_manifest_for
        for j in d.get("cancel", ()):
            self._cancel(j)
        run = d["run"]
        jm = job_manifest_for(rec.spec["job"], rec.tenant,
                              f"{rec.name}-run{run}")
        job_id = self._submit(jm, f"wl/{rec.tenant}/{rec.name}/run{run}")
        drop = set(d.get("prune", ())) | set(d.get("cancel", ()))
        st = rec.status
        st["jobs"] = [j for j in st["jobs"] if j not in drop] + [job_id]
        st["runs"] = run + 1
        st["last_run_tick"] = self.policy.tick
        self.plane._emit("workload_recurring_run", rec.tenant,
                         name=rec.name, run=run, job=job_id,
                         replaced=sorted(d.get("cancel", ())))

    _act_recurring_replace = _act_recurring_run

    def _act_recurring_skip(self, rec, d):
        st = rec.status
        st["skipped"] += 1
        st["last_run_tick"] = self.policy.tick
        self.plane._emit("workload_recurring_skipped", rec.tenant,
                         name=rec.name, live=d.get("live", []))

    def _act_recurring_done(self, rec, d):
        rec.status["phase"] = "DONE"

    # service verbs
    def _act_replica_start(self, rec, d):
        from repro.core.types import JobManifest
        slot = d["slot"]
        inc = rec.status.setdefault("incarnations", {})
        n = inc.get(slot, 0)
        jm = JobManifest(
            name=f"{rec.name}-r{slot}", tenant=rec.tenant, n_learners=1,
            chips_per_learner=rec.spec["chips_per_replica"],
            tier=rec.spec["tier"],
            sim_duration=self.config.replica_sim_duration)
        job_id = self._submit(
            jm, f"wl/{rec.tenant}/{rec.name}/r{slot}/i{n}")
        rec.status["replicas"][slot] = job_id
        inc[slot] = n + 1
        self.plane._emit("workload_service_scaled", rec.tenant,
                         name=rec.name, slot=slot, job=job_id,
                         replicas=rec.spec["replicas"])

    def _act_replica_stop(self, rec, d):
        slot = d["slot"]
        job = rec.status["replicas"].pop(slot, None)
        if slot in rec.status["ready_slots"]:
            rec.status["ready_slots"].remove(slot)
        if job:
            self._cancel(job)
        self.plane._emit("workload_service_scaled", rec.tenant,
                         name=rec.name, slot=slot, job=None,
                         replicas=rec.spec["replicas"])

    def _act_service_status(self, rec, d):
        prev = rec.status["phase"]
        rec.status["ready_slots"] = list(d["ready"])
        rec.status["phase"] = d["phase"]
        if d["phase"] == "RUNNING" and prev != "RUNNING":
            self.plane._emit("workload_service_ready", rec.tenant,
                             name=rec.name, ready=list(d["ready"]))
        elif d["phase"] == "DEGRADED" and prev != "DEGRADED":
            self.plane._emit("workload_service_degraded", rec.tenant,
                             name=rec.name, ready=list(d["ready"]))

    # -- serving metering ---------------------------------------------------
    def _meter_serving(self):
        """Ready replicas bill ``serving_replica_seconds`` per tick into
        their tenant's shard meter (same cadence chip_seconds accrue)."""
        for (tenant, _name), rec in sorted(self.plane.records.items()):
            if rec.kind != "Service":
                continue
            n = len(rec.status.get("ready_slots", []))
            if not n:
                continue
            try:
                b = self.fed.router.shard_for(tenant)
            except Exception:
                continue
            if b.alive and not getattr(b, "retired", False):
                b.platform.meter.bump(
                    tenant, "serving_replica_seconds",
                    n * b.platform.tick_period)
