# Declarative workloads: manifest resources (Pipeline / RecurringJob /
# Service) stored in a WorkloadPlane, served over /v2/workloads by a
# WorkloadGateway, and converged by a WorkloadReconciler stepped from
# Federation.tick — the control loop above the v1 job plane.
from repro.workloads.manifest import (
    OVERLAP_POLICIES,
    WORKLOAD_KINDS,
    job_manifest_for,
    parse_manifest_text,
    parse_yaml,
    validate_workload,
)
from repro.workloads.plane import (
    WorkloadGateway,
    WorkloadPlane,
    WorkloadRecord,
    initial_status,
)
from repro.workloads.reconciler import (
    STAGE_TERMINAL,
    WORKLOAD_EVENT_KINDS,
    ReconcilerConfig,
    ReconcilerPolicy,
    WorkloadReconciler,
)

__all__ = [
    "OVERLAP_POLICIES",
    "ReconcilerConfig",
    "ReconcilerPolicy",
    "STAGE_TERMINAL",
    "WORKLOAD_EVENT_KINDS",
    "WORKLOAD_KINDS",
    "WorkloadGateway",
    "WorkloadPlane",
    "WorkloadReconciler",
    "WorkloadRecord",
    "initial_status",
    "job_manifest_for",
    "parse_manifest_text",
    "parse_yaml",
    "validate_workload",
]
