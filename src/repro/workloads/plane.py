"""The workloads resource plane: stored manifests + the /v2/workloads verbs.

Mirrors the v2 admin plane split (:mod:`repro.api.admin`): one
:class:`WorkloadPlane` per federation holds the applied manifests (spec +
reconciler-owned status), :class:`WorkloadGateway` is the auth-checking
verb surface served over HTTP and in-process. Unlike the admin plane the
workloads plane is **tenant-scoped**: a plain tenant key may apply, list,
get, delete, and invoke its *own* workloads; an admin key addresses any
tenant's (``tenant=`` selects which).

Resources are keyed ``(tenant, name)``. ``apply`` is idempotent by
construction — the normalized spec (:func:`..manifest.validate_workload`)
is compared structurally, and an equal re-apply changes nothing, bumps
nothing, and emits nothing. A changed spec bumps ``generation``;
pipelines restart from a clean DAG on a spec change, services and
recurring jobs carry their runtime state forward (scale by editing
``replicas:`` and re-applying).

``invoke`` is the serving tier's data path: it routes one inference
request to a ready replica of a RUNNING ``Service``, round-robin. Over
HTTP it rides the same per-tenant token buckets as every other tenant
call (``throttle_non_admin`` in the handler), which is what gives the
serving tier per-tenant QoS for free: a flooding tenant sees 429s, other
tenants' requests are untouched. When a real
:class:`repro.launch.serve.ServeEngine` is attached
(:meth:`WorkloadPlane.attach_engine`), the invoke path drives it
in-process; otherwise the reply is a simulated echo carrying the routing
decision (which replica job served it).
"""

from __future__ import annotations

import copy
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.api.auth import READ, WRITE, AuthService
from repro.api.types import (ADMIN_API_VERSION, ApiError, ErrorCode,
                             deadline_guarded)
from repro.workloads.manifest import (
    parse_manifest_text,
    validate_workload,
)


def _serialized(fn):
    """Every public plane verb under the plane mutex (reentrant: delete
    cascades re-enter). Ordering is always plane mutex -> shard lock —
    the same order the reconciler uses — never the reverse."""
    def wrapper(self, *args, **kwargs):
        with self._mutex:
            return fn(self, *args, **kwargs)
    wrapper.__name__ = fn.__name__
    wrapper.__doc__ = fn.__doc__
    return wrapper


def initial_status(spec: dict) -> dict:
    """The reconciler-owned status block a fresh resource starts from."""
    kind = spec["kind"]
    if kind == "Pipeline":
        return {"phase": "PENDING",
                "stages": {s["name"]: {"state": "PENDING", "job": None,
                                       "attempts": 0, "service": None}
                           for s in spec["stages"]}}
    if kind == "RecurringJob":
        return {"phase": "ACTIVE", "runs": 0, "skipped": 0,
                "jobs": [], "last_run_tick": None}
    return {"phase": "PENDING", "replicas": {}, "ready_slots": [],
            "round_robin": 0, "invocations": 0}


@dataclass
class WorkloadRecord:
    """One applied manifest: the spec is the user's, the status block is
    the reconciler's, and nobody else writes either."""

    spec: dict
    generation: int = 1
    status: dict = field(default_factory=dict)
    # Set when a pipeline's serve stage applied this resource: deleting
    # the owner cascades here.
    owner: Optional[Tuple[str, str]] = None

    @property
    def kind(self) -> str:
        return self.spec["kind"]

    @property
    def tenant(self) -> str:
        return self.spec["tenant"]

    @property
    def name(self) -> str:
        return self.spec["name"]

    def to_wire(self) -> dict:
        return {"api_version": ADMIN_API_VERSION,
                "kind": self.kind, "name": self.name,
                "tenant": self.tenant, "generation": self.generation,
                "spec": copy.deepcopy(self.spec),
                "status": copy.deepcopy(self.status),
                "owner": (f"{self.owner[0]}/{self.owner[1]}"
                          if self.owner else None)}

    def tracked_jobs(self) -> list:
        """Every job id this resource currently references (sorted)."""
        st, out = self.status, []
        if self.kind == "Pipeline":
            out = [s["job"] for s in st.get("stages", {}).values()
                   if s.get("job")]
        elif self.kind == "RecurringJob":
            out = list(st.get("jobs", []))
        else:
            out = [j for j in st.get("replicas", {}).values() if j]
        return sorted(out)


class WorkloadPlane:
    """Shared manifest store + teardown plumbing. The reconciler
    (:class:`repro.workloads.reconciler.WorkloadReconciler`) is the only
    writer of record status; the plane's own verbs only create, replace,
    and delete records."""

    def __init__(self, router, auth: AuthService):
        from repro.api.gateway import ApiGateway
        self.router = router
        self.auth = auth
        self.records: Dict[Tuple[str, str], WorkloadRecord] = {}
        self._mutex = threading.RLock()
        # The plane acts on the v1 data plane exactly like a client would:
        # its own gateway replica + an operator key (same pattern as the
        # autonomous operator acting through /v2/admin verbs).
        self._api = ApiGateway(router, auth, replica_id="api-workloads")
        self._key = auth.issue_admin_key()
        # (tenant, name) -> in-process ServeEngine for `engine: real`
        self._engines: Dict[Tuple[str, str], object] = {}

    # -- plumbing ---------------------------------------------------------
    def _emit(self, kind: str, tenant: str, **fields):
        """Journal a workload event on the first live shard's bus (the
        same convention the autonomous operator uses)."""
        for b in self.router.backends:
            if b.alive and not getattr(b, "retired", False):
                b.platform.events.emit("workloads", kind, tenant=tenant,
                                       **fields)
                return

    def _get(self, tenant: str, name: str) -> WorkloadRecord:
        rec = self.records.get((tenant, name))
        if rec is None:
            raise ApiError(ErrorCode.NOT_FOUND,
                           f"no workload {name!r} for tenant {tenant!r}",
                           tenant=tenant, name=name)
        return rec

    # -- verbs ------------------------------------------------------------
    @_serialized
    def apply(self, manifest, owner: Optional[Tuple[str, str]] = None) \
            -> Tuple[dict, bool, bool]:
        """Upsert one manifest (raw dict or manifest text). Returns
        ``(view, created, changed)``; an equal re-apply is a full no-op
        (created=False, changed=False) — the idempotence the property
        tests pin."""
        if isinstance(manifest, str):
            manifest = parse_manifest_text(manifest)
        spec = validate_workload(manifest)
        key = (spec["tenant"], spec["name"])
        rec = self.records.get(key)
        if rec is None:
            rec = WorkloadRecord(spec=spec, status=initial_status(spec),
                                 owner=owner)
            self.records[key] = rec
            self._emit("workload_applied", spec["tenant"],
                       name=spec["name"], workload_kind=spec["kind"],
                       generation=1)
            return rec.to_wire(), True, True
        if rec.spec == spec:
            return rec.to_wire(), False, False
        if rec.kind != spec["kind"]:
            raise ApiError(ErrorCode.CONFLICT,
                           f"workload {spec['name']!r} exists with kind "
                           f"{rec.kind!r}; delete it before re-applying "
                           f"as {spec['kind']!r}")
        rec.spec = spec
        rec.generation += 1
        if owner is not None:
            rec.owner = owner
        if spec["kind"] == "Pipeline":
            # a changed pipeline is a new run: fresh DAG, old stage jobs
            # are left to finish (they were already paid for)
            rec.status = initial_status(spec)
        self._emit("workload_applied", spec["tenant"], name=spec["name"],
                   workload_kind=spec["kind"], generation=rec.generation)
        return rec.to_wire(), False, True

    @_serialized
    def get(self, tenant: str, name: str) -> dict:
        return self._get(tenant, name).to_wire()

    @_serialized
    def list(self, tenant: Optional[str] = None) -> list:
        return [rec.to_wire()
                for (t, _n), rec in sorted(self.records.items())
                if tenant is None or t == tenant]

    @_serialized
    def delete(self, tenant: str, name: str) -> dict:
        """Remove the resource and tear down everything it materialized:
        non-terminal tracked jobs are cancelled through the v1 gateway,
        and child resources a pipeline applied are deleted recursively."""
        rec = self._get(tenant, name)
        view = rec.to_wire()
        del self.records[(tenant, name)]
        for (t, n), child in sorted(self.records.items()):
            if child.owner == (tenant, name):
                self.delete(t, n)
        for job_id in rec.tracked_jobs():
            try:
                self._api.cancel(self._key, job_id)
            except ApiError:
                pass  # already terminal / unknown / shard down
        self._engines.pop((tenant, name), None)
        self._emit("workload_deleted", tenant, name=name,
                   workload_kind=rec.kind)
        return view

    @_serialized
    def invoke(self, tenant: str, name: str, payload=None) -> dict:
        """Route one inference request to a ready replica (round-robin)."""
        rec = self._get(tenant, name)
        if rec.kind != "Service":
            raise ApiError(ErrorCode.FAILED_PRECONDITION,
                           f"workload {name!r} is a {rec.kind}, not a "
                           f"Service")
        ready = list(rec.status.get("ready_slots", []))
        if not ready:
            raise ApiError(
                ErrorCode.FAILED_PRECONDITION,
                f"service {name!r} has no ready replicas "
                f"(phase {rec.status.get('phase')})",
                phase=rec.status.get("phase"))
        slot = ready[rec.status["round_robin"] % len(ready)]
        rec.status["round_robin"] += 1
        rec.status["invocations"] += 1
        job_id = rec.status["replicas"].get(slot)
        engine = self._engines.get((tenant, name))
        if engine is not None:
            output = engine.infer(payload)
        else:
            output = {"echo": payload, "engine": rec.spec.get("engine"),
                      "model": rec.spec.get("arch")}
        return {"api_version": ADMIN_API_VERSION, "service": name,
                "tenant": tenant, "replica": slot, "job": job_id,
                "output": output}

    @_serialized
    def attach_engine(self, tenant: str, name: str, engine):
        """Bind an in-process serving engine (anything with ``infer``,
        e.g. ``ServeEngine.session(...)`` wrapped) to a Service."""
        self._get(tenant, name)  # must exist
        self._engines[(tenant, name)] = engine


# Every WorkloadGateway verb runs inside a deadline scope (enforced by
# the DEADLINE-VERB check in repro.analysis).
_deadlined = deadline_guarded()


class WorkloadGateway:
    """Auth-checking verb surface over one shared plane — the in-process
    twin of the ``/v2/workloads`` HTTP routes. Tenant keys operate on
    their own tenant's resources; admin keys on anyone's."""

    # per-verb deadline budget; instances may tighten it (drills do)
    verb_budget_s = 10.0

    def __init__(self, plane: WorkloadPlane, auth: AuthService):
        self.plane = plane
        self.auth = auth

    def _resolve_tenant(self, principal, tenant: Optional[str]) -> str:
        """Which tenant is this call about? Tenant keys default (and are
        restricted) to their own; admin keys must say."""
        if tenant is None:
            if principal.is_admin:
                raise ApiError(ErrorCode.INVALID_ARGUMENT,
                               "admin keys must pass tenant=")
            return principal.tenant
        if not principal.owns(tenant):
            raise ApiError(ErrorCode.FORBIDDEN,
                           f"key for tenant {principal.tenant!r} cannot "
                           f"address workloads of {tenant!r}")
        return tenant

    @_deadlined
    def apply(self, api_key: str, manifest) -> dict:
        """``manifest``: raw dict, or JSON/YAML-subset text."""
        principal = self.auth.require(api_key, WRITE)
        if isinstance(manifest, str):
            manifest = parse_manifest_text(manifest)
        spec = validate_workload(manifest)
        if not principal.owns(spec["tenant"]):
            raise ApiError(ErrorCode.FORBIDDEN,
                           f"key for tenant {principal.tenant!r} cannot "
                           f"apply workloads for {spec['tenant']!r}")
        view, created, _changed = self.plane.apply(manifest)
        view["created"] = created
        return view

    @_deadlined
    def get_workload(self, api_key: str, name: str,
                     tenant: Optional[str] = None) -> dict:
        principal = self.auth.require(api_key, READ)
        return self.plane.get(self._resolve_tenant(principal, tenant), name)

    @_deadlined
    def list_workloads(self, api_key: str,
                       tenant: Optional[str] = None) -> dict:
        principal = self.auth.require(api_key, READ)
        if principal.is_admin:
            items = self.plane.list(tenant)  # None = every tenant
        else:
            items = self.plane.list(self._resolve_tenant(principal, tenant))
        return {"api_version": ADMIN_API_VERSION, "items": items}

    @_deadlined
    def delete_workload(self, api_key: str, name: str,
                        tenant: Optional[str] = None) -> dict:
        principal = self.auth.require(api_key, WRITE)
        return self.plane.delete(self._resolve_tenant(principal, tenant),
                                 name)

    @_deadlined
    def invoke_workload(self, api_key: str, name: str, payload=None,
                        tenant: Optional[str] = None) -> dict:
        principal = self.auth.require(api_key, READ)
        return self.plane.invoke(self._resolve_tenant(principal, tenant),
                                 name, payload)
