"""Quickstart: submit jobs to FfDL and watch them run.

    PYTHONPATH=src python examples/quickstart.py

Shows the user-facing surface of the platform (FfDL §3.1): a manifest is
"code + data location + resources"; the platform does the rest — placement,
status pipeline, logs, results.
"""

from repro.api import ApiClient
from repro.core import FfDLPlatform, JobManifest, JobStatus


def main():
    # a small cluster: 4 hosts x 4 chips
    platform = FfDLPlatform(n_hosts=4, chips_per_host=4, placement="pack")
    platform.admission.register_tenant("demo-team", quota_chips=12)
    # every user-facing call goes through the v1 API tier with a
    # tenant-scoped key (the raw platform facade is gone)
    client = ApiClient.for_platform(platform, tenant="demo-team")

    # 1) a simulated job (what the scheduling benchmarks use)
    sim = client.submit(JobManifest(
        name="preprocessing-sim", tenant="demo-team",
        n_learners=2, chips_per_learner=2, sim_duration=120))

    # 2) a real JAX training job: tiny llama-family model, 40 steps
    train = client.submit(JobManifest(
        name="smollm-tiny-train", tenant="demo-team",
        n_learners=1, chips_per_learner=2,
        arch="smollm-360m", checkpoint_interval=20,
        train={"steps": 40, "batch": 4, "seq": 64, "lr": 1e-3}))

    print(f"submitted: {sim} (simulated), {train} (real training)")
    last = {}
    while True:
        platform.tick()
        for j in (sim, train):
            st = client.status(j)
            if last.get(j) != st:
                rec = platform.meta.get(j)
                print(f"[t={platform.clock.now():7.1f}s] {j} "
                      f"{st.value:12s} step={rec.progress_step}")
                last[j] = st
        if all(client.status(j) in (JobStatus.COMPLETED, JobStatus.FAILED)
               for j in (sim, train)):
            break

    print("\nstatus history of the training job:")
    for ts, status, msg in client.status_history(train):
        print(f"  {ts:8.1f}s  {status:12s} {msg}")

    print(f"\ncluster utilization now: {platform.cluster.utilization():.0%}")
    print(f"results in object store: "
          f"{platform.objstore.list('results', train)[:3]} ...")


if __name__ == "__main__":
    main()
