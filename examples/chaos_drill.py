"""Chaos drill: a fleet of jobs (including real training) rides out learner
crashes, node failures, and guardian/controller crashes (FfDL §3.8, §5.6).

    PYTHONPATH=src python examples/chaos_drill.py
"""

from collections import Counter

from repro.api import ApiClient
from repro.core import ChaosConfig, FfDLPlatform, JobManifest, JobStatus


def main():
    chaos = ChaosConfig(
        seed=7,
        p_learner_crash=0.004,
        p_host_fail=0.001,
        p_guardian_crash=0.002,
        p_controller_crash=0.003,
        host_recovery_s=90.0,
    )
    p = FfDLPlatform(n_hosts=8, chips_per_host=4, chaos=chaos, seed=3)
    c = ApiClient.for_platform(p)

    jobs = [c.submit(JobManifest(name=f"sim-{i}", n_learners=2,
                                 chips_per_learner=2, sim_duration=300,
                                 max_restarts=20))
            for i in range(5)]
    jobs.append(c.submit(JobManifest(
        name="real-train", arch="smollm-360m", n_learners=1,
        chips_per_learner=2, checkpoint_interval=15, max_restarts=20,
        train={"steps": 80, "batch": 4, "seq": 64})))

    print(f"running {len(jobs)} jobs under chaos "
          f"(learner/host/guardian/controller faults enabled)...")
    ok = p.run_until_terminal(jobs, max_sim_s=50000)

    print("\n--- outcome ---")
    statuses = Counter(c.status(j).value for j in jobs)
    print(f"job outcomes: {dict(statuses)}")
    assert ok and statuses.get("COMPLETED", 0) == len(jobs), statuses

    print("\n--- what chaos did (event log) ---")
    for kind in ("learner_killed", "host_killed", "guardian_crashed",
                 "controller_killed", "pod_evicted", "node_notready"):
        print(f"  {kind:20s} {p.events.count(kind)}")

    print("\n--- how the platform recovered ---")
    for kind in ("pod_restarted", "learners_replaced", "rollback",
                 "guardian_restarted", "resume_from_checkpoint"):
        print(f"  {kind:22s} {p.events.count(kind)}")

    print("\n--- recovery timeline of the real training job ---")
    j = jobs[-1]
    for ts, status, msg in c.status_history(j):
        print(f"  {ts:8.1f}s  {status:12s} {msg}")
    print(f"\nno leaked chips: {p.cluster.used_chips} in use  OK")


if __name__ == "__main__":
    main()
