"""Multi-tenant scheduling demo: quotas, opportunistic over-quota admission,
reclamation preemption, PACK packing (FfDL §3.4-3.6) — driven through the
v1 API tier (§3.2): per-tenant keys, typed envelopes, and cross-tenant
isolation enforced by the gateway.

    PYTHONPATH=src python examples/multi_tenant.py           # in-process
    PYTHONPATH=src python examples/multi_tenant.py --http    # over the wire

With ``--http`` the demo boots a real local HTTP server (JSON over the
wire, ``Authorization``/``Idempotency-Key`` headers, 429s from the
per-tenant rate limiter) and drives the exact same flow through
``HttpTransport`` — the path a real user's `ffdl` CLI takes.
"""

import argparse

from repro.api import (
    ApiClient,
    ApiError,
    ApiHttpServer,
    ErrorCode,
    HttpTransport,
    RateLimitConfig,
)
from repro.core import FfDLPlatform, JobManifest


def banner(s):
    print(f"\n=== {s} " + "=" * max(0, 60 - len(s)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--http", action="store_true",
                    help="drive the demo over a live local HTTP server")
    args = ap.parse_args()

    p = FfDLPlatform(n_hosts=8, chips_per_host=4, placement="pack")  # 32 chips
    p.admission.register_tenant("vision-team", quota_chips=16)
    p.admission.register_tenant("nlp-team", quota_chips=12)
    p.admission.register_tenant("interns", quota_chips=4, tier="free")
    # each tenant talks to the API tier with its own key
    vision_key = p.auth.issue_key("vision-team")
    nlp_key = p.auth.issue_key("nlp-team")

    server = None
    if args.http:
        server = ApiHttpServer(p, rate_limit=RateLimitConfig(
            rate=500.0, burst=200)).start()
        transport = HttpTransport(server.base_url)
        print(f"(speaking JSON over HTTP to {server.base_url})")
    else:
        transport = p.api
    vision = ApiClient(transport, vision_key)
    nlp = ApiClient(transport, nlp_key)

    def advance(sim_seconds):
        # the sim is single-threaded: tick under the server's lock so HTTP
        # handler threads never interleave with the engine. Never hold the
        # lock while issuing client calls (the handler needs it).
        if server is not None:
            with server.lock:
                p.run_for(sim_seconds)
        else:
            p.run_for(sim_seconds)

    try:
        banner("vision-team fills its quota AND borrows idle capacity")
        v = [vision.submit(
                JobManifest(name=f"vision-{i}", tenant="vision-team",
                            n_learners=2, chips_per_learner=4,
                            sim_duration=600),
                idempotency_key=f"vision-{i}")
             for i in range(3)]  # 24 chips > 16 quota: third is opportunistic
        advance(90)
        for j in v:
            print(f"  {j}: {vision.status(j).value}")

        banner("tenant isolation: nlp-team cannot touch vision-team's jobs")
        try:
            nlp.halt(v[0])
        except ApiError as e:
            assert e.code == ErrorCode.FORBIDDEN
            extra = f" (HTTP {e.details['http_status']})" if args.http else ""
            print(f"  halt({v[0]}) with nlp key -> {e.code.value}{extra}")
        dup = vision.submit_envelope(
            JobManifest(name="vision-0", tenant="vision-team",
                        n_learners=2, chips_per_learner=4,
                        sim_duration=600),
            idempotency_key="vision-0")
        print(f"  duplicate submit (same idempotency key) -> {dup.job_id} "
              f"deduplicated={dup.deduplicated}")
        print(f"  utilization: {p.cluster.utilization():.0%}  "
              f"(over-quota jobs: "
              f"{[k for k, o in p.admission.over_quota.items() if o]})")

        banner("nlp-team claims its quota -> vision's over-quota job is "
               "preempted")
        n = nlp.submit(JobManifest(name="nlp-big", tenant="nlp-team",
                                   n_learners=3, chips_per_learner=4,
                                   sim_duration=300))
        advance(240)
        for j in v:
            print(f"  {j}: {vision.status(j).value}")
        print(f"  {n}: {nlp.status(n).value}")
        preempts = p.events.of_kind("preempt")
        print(f"  preemptions: "
              f"{[(e.fields['job'], e.fields['reason']) for e in preempts]}")

        banner("PACK keeps whole hosts free for big gangs")
        frees = sorted(h.free_chips for h in p.cluster.hosts.values())
        print(f"  free chips per host: {frees}")

        banner("drain")
        # HALTED is NOT terminal here: the preempted over-quota job is
        # auto-requeued and must come back and finish
        deadline = 20000
        while deadline > 0:
            advance(200)
            deadline -= 200
            views = [vision.view(j) for j in v] + [nlp.view(n)]
            if all(s.status in ("COMPLETED", "FAILED") for s in views):
                break
        for j in v:
            print(f"  {j}: {vision.status(j).value}")
        print(f"  {n}: {nlp.status(n).value}")
        print("\nper-tenant history:")
        for t, cli in (("vision-team", vision), ("nlp-team", nlp)):
            page = cli.list_jobs(tenant=t, limit=20)
            for view in page.items:
                print(f"  {t:12s} {view.job_id} {view.status}")
    finally:
        if server is not None:
            server.stop()


if __name__ == "__main__":
    main()
