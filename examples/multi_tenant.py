"""Multi-tenant scheduling demo: quotas, opportunistic over-quota admission,
reclamation preemption, PACK packing (FfDL §3.4-3.6) — driven through the
v1 API tier (§3.2): per-tenant keys, typed envelopes, and cross-tenant
isolation enforced by the gateway.

    PYTHONPATH=src python examples/multi_tenant.py
"""

from repro.api import ApiError, ErrorCode, SubmitRequest
from repro.core import FfDLPlatform, JobManifest, JobStatus


def banner(s):
    print(f"\n=== {s} " + "=" * max(0, 60 - len(s)))


def main():
    p = FfDLPlatform(n_hosts=8, chips_per_host=4, placement="pack")  # 32 chips
    p.admission.register_tenant("vision-team", quota_chips=16)
    p.admission.register_tenant("nlp-team", quota_chips=12)
    p.admission.register_tenant("interns", quota_chips=4, tier="free")
    # each tenant talks to the replicated API tier with its own key
    vision_key = p.auth.issue_key("vision-team")
    nlp_key = p.auth.issue_key("nlp-team")

    banner("vision-team fills its quota AND borrows idle capacity")
    v = [p.api.submit(vision_key, SubmitRequest(
            manifest=JobManifest(name=f"vision-{i}", tenant="vision-team",
                                 n_learners=2, chips_per_learner=4,
                                 sim_duration=600),
            idempotency_key=f"vision-{i}")).job_id
         for i in range(3)]  # 24 chips > 16 quota: third is opportunistic
    p.run_for(90)
    for j in v:
        print(f"  {j}: {p.status(j).value}")

    banner("tenant isolation: nlp-team cannot touch vision-team's jobs")
    try:
        p.api.halt(nlp_key, v[0])
    except ApiError as e:
        assert e.code == ErrorCode.FORBIDDEN
        print(f"  halt({v[0]}) with nlp key -> {e.code.value}")
    dup = p.api.submit(vision_key, SubmitRequest(
        manifest=JobManifest(name="vision-0", tenant="vision-team",
                             n_learners=2, chips_per_learner=4,
                             sim_duration=600),
        idempotency_key="vision-0"))
    print(f"  duplicate submit (same idempotency key) -> {dup.job_id} "
          f"deduplicated={dup.deduplicated}")
    print(f"  utilization: {p.cluster.utilization():.0%}  "
          f"(over-quota jobs: {[k for k, o in p.admission.over_quota.items() if o]})")

    banner("nlp-team claims its quota -> vision's over-quota job is preempted")
    n = p.submit(JobManifest(name="nlp-big", tenant="nlp-team",
                             n_learners=3, chips_per_learner=4,
                             sim_duration=300))
    p.run_for(240)
    for j in v + [n]:
        print(f"  {j}: {p.status(j).value}")
    preempts = p.events.of_kind("preempt")
    print(f"  preemptions: {[(e.fields['job'], e.fields['reason']) for e in preempts]}")

    banner("PACK keeps whole hosts free for big gangs")
    frees = sorted(h.free_chips for h in p.cluster.hosts.values())
    print(f"  free chips per host: {frees}")

    banner("drain")
    all_jobs = v + [n]
    p.run_until_terminal(all_jobs, max_sim_s=20000)
    for j in all_jobs:
        print(f"  {j}: {p.status(j).value}")
    print("\nper-tenant history:")
    for t in ("vision-team", "nlp-team"):
        for h in p.meta.history(t):
            print(f"  {t:12s} {h['job_id']} {h['status']}")


if __name__ == "__main__":
    main()
