"""Declarative workloads end-to-end: apply a train→eval→serve Pipeline
manifest, let the reconciler converge it unattended, then query the
multi-tenant serving tier it materialized.

    PYTHONPATH=src python examples/pipeline_e2e.py

What you should see: the pipeline's stages submit one after another as
their `after:` deps complete, the final stage materializes a child
Service whose replicas are ordinary platform jobs, inference requests
round-robin across the ready replicas, and scaling is just "edit
`replicas:` and re-apply". The same flow works over HTTP against
`ffdl serve` with `ffdl apply -f examples/manifests/pipeline.yaml`.
"""

import pathlib

from repro.api import Federation, WorkloadClient

MANIFEST = pathlib.Path(__file__).resolve().parent / "manifests" / \
    "pipeline.yaml"


def main():
    # tick_period=5 sim-seconds per tick so stage jobs clear the fixed
    # 30 s deploy/download phases in a handful of ticks
    fed = Federation(n_shards=2, n_hosts=2, chips_per_host=4,
                     tick_period=5.0)
    client = WorkloadClient.for_platform(fed, tenant="demo-team")

    view = client.apply(MANIFEST.read_text())
    print(f"applied {view['kind']}/{view['name']} "
          f"(generation {view['generation']})")

    seen = {}
    for tick in range(1, 201):
        fed.tick()
        status = client.get("lm-pipe")["status"]
        for stage, s in status["stages"].items():
            if seen.get(stage) != s["state"]:
                seen[stage] = s["state"]
                print(f"tick {tick:3d}: stage {stage:<6} -> {s['state']}"
                      + (f" ({s['job']})" if s["job"] else ""))
        if status["phase"] in ("SUCCEEDED", "DEGRADED"):
            print(f"tick {tick:3d}: pipeline {status['phase']}")
            break

    svc = client.get("lm-pipe-serve")
    print(f"\nchild service: lm-pipe-serve phase={svc['status']['phase']} "
          f"ready={svc['status']['ready_slots']} "
          f"(owner {svc['owner']})")
    for i in range(4):
        out = client.invoke("lm-pipe-serve", payload={"prompt": f"q{i}"})
        print(f"invoke {i}: replica {out['replica']} job {out['job']}")

    # scale the serving tier by editing replicas: and re-applying
    spec = svc["spec"]
    client.apply({"kind": "Service", "name": "lm-pipe-serve",
                  "tenant": "demo-team", **{
                      k: v for k, v in spec.items()
                      if k not in ("kind", "name", "tenant")},
                  "replicas": 3})
    for _ in range(60):
        fed.tick()
        if len(client.get("lm-pipe-serve")["status"]["ready_slots"]) == 3:
            break
    ready = client.get("lm-pipe-serve")["status"]["ready_slots"]
    print(f"\nscaled to replicas=3 by re-applying; ready slots: {ready}")

    # per-tenant usage now carries serving_replica_seconds for the tier
    meter = fed.router.shard_for("demo-team").platform.meter
    row = meter.snapshot().get("demo-team", {})
    print(f"serving_replica_seconds billed: "
          f"{row.get('serving_replica_seconds', 0.0):.0f}")


if __name__ == "__main__":
    main()
