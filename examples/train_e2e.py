"""End-to-end driver: train a ~100M-parameter model for a few hundred steps
THROUGH the platform, with a mid-run HALT/RESUME (hyperparameter-workflow
path, FfDL §3.8) and checkpoint-based recovery.

    PYTHONPATH=src python examples/train_e2e.py              # ~100M, 240 steps
    PYTHONPATH=src python examples/train_e2e.py --quick      # tiny, 60 steps

The model is a smollm-family decoder sized to ~100M params; data is the
deterministic synthetic LM stream. Loss is reported from the learner's
checkpoint metadata trail.
"""

import argparse

from repro.ckpt import checkpoint as ckpt
from repro.api import ApiClient
from repro.core import FfDLPlatform, JobManifest, JobStatus
from repro.data.objectstore import MountedBucket


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    steps = args.steps or (150 if args.quick else 300)
    overrides = (
        {} if args.quick else {
            # ~100M params: 12L x 768d x 12H(kv4), 16k vocab
            "n_layers": 12, "d_model": 768, "n_heads": 12, "n_kv_heads": 4,
            "d_ff": 2048, "vocab_size": 16384, "scan_layers": False,
            "attn_chunk": 64,
        })

    p = FfDLPlatform(n_hosts=2, chips_per_host=4)
    c = ApiClient.for_platform(p)
    j = c.submit(JobManifest(
        name="e2e-train", arch="smollm-360m", n_learners=1,
        chips_per_learner=4, checkpoint_interval=25,
        train={"steps": steps, "batch": 8, "seq": 128, "lr": 1.5e-3,
               "warmup": 10, "tiny": True, "overrides": overrides,
               "seed": 0}))
    n_params = None
    halted = False
    print(f"submitted {j}: ~100M-param decoder, {steps} steps")
    while c.status(j) not in (JobStatus.COMPLETED, JobStatus.FAILED):
        p.tick()
        rec = p.meta.get(j)
        g = p.guardians.get(j)
        if g and g.runtimes.get(0) is not None and n_params is None:
            rt = g.runtimes[0]
            if getattr(rt, "_state", None) is not None:
                from repro.utils import tree_count
                n_params = tree_count(rt._state.params)
                print(f"model materialized: {n_params/1e6:.1f}M params")
        if rec.status == JobStatus.PROCESSING and rec.progress_step and \
                rec.progress_step % 50 < 5 and g and g.runtimes.get(0):
            hist = getattr(g.runtimes[0], "loss_history", [])
            if hist:
                print(f"  step {hist[-1][0]:4d}  loss {hist[-1][1]:.4f}")
        # demonstrate HALT/RESUME mid-run (the hyperparameter workflow)
        if not halted and rec.status == JobStatus.PROCESSING \
                and rec.progress_step >= steps // 3:
            print(f"-> HALT at step {rec.progress_step} "
                  "(checkpoint + free chips)")
            c.halt(j)
            halted = True
        if halted and rec.status == JobStatus.HALTED:
            print(f"-> chips free: {p.cluster.used_chips} in use; RESUME")
            c.resume(j)
            halted = "resumed"

    print(f"\nfinal status: {c.status(j).value}")
    bucket = MountedBucket(p.objstore, "results")
    trail = []
    for s in ckpt.steps_available(bucket, f"{j}/ckpt"):
        _, meta = ckpt.restore(bucket, f"{j}/ckpt", s, like=None)
        if "loss" in meta:
            trail.append((s, meta["loss"]))
    print("loss trail from checkpoints:")
    for s, l in trail:
        print(f"  step {s:4d}  loss {l:.4f}")
    if len(trail) >= 2:
        assert trail[-1][1] < trail[0][1], "loss did not decrease!"
        print(f"loss decreased {trail[0][1]:.3f} -> {trail[-1][1]:.3f}  OK")
    hist = [s for _, s, _ in c.status_history(j)]
    assert "HALTED" in hist and "RESUMED" in hist
    print("HALT/RESUME exercised through the status pipeline  OK")


if __name__ == "__main__":
    main()
