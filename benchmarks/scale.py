"""§5.5 — scale test: 680-chip cluster, light load (70) vs heavy load (700
concurrent jobs), staggered starts.

Paper: chips-class batches start staggered (K80 first 15 min, P100 at 30,
V100 at 32); under heavy load shared network/object-storage bandwidth
degrades late-starting (V100) jobs the most: K80 6-8%, P100 ~24%, V100
~51%; 12/700 jobs hit faulty nodes, were cordoned + restarted by the
platform; zero platform-software failures.

Method: the same staggered mix on a 170-host x 4-chip cluster, with a
shared-bandwidth contention model (each active learner gets bandwidth
share; SimLearner slowdown = demand/capacity when oversubscribed), plus a
handful of chaos host faults to reproduce the cordon-and-restart tail.
"""

from __future__ import annotations

import numpy as np

from repro.api import ApiClient
from repro.core import ChaosConfig, FfDLPlatform, JobManifest, JobStatus

# job classes: (label, n_jobs_LL, n_jobs_HL, start_s, base_duration_s,
#               input_sensitivity)
# input_sensitivity models the paper's key observation: the faster the
# accelerator, the higher its input-bandwidth demand, so shared-pipe
# contention hurts V100 jobs most and K80 jobs least (§5.5: K80 6-8%,
# P100 ~24%, V100 ~51%).
BATCHES = [
    ("K80-b1", 30, 300, 30.0, 5400.0, 0.15),
    ("K80-b2", 24, 240, 900.0, 5400.0, 0.15),
    ("P100-b3", 11, 110, 1800.0, 3200.0, 0.55),
    ("V100-b4", 5, 50, 1920.0, 1900.0, 2.0),
]
# shared pipe: how many concurrently-PROCESSING learners it can feed at
# full speed (beyond this, contention grows with the overload factor)
BANDWIDTH_LEARNERS = 480


def run_scenario(heavy: bool, seed=0):
    p = FfDLPlatform(n_hosts=170, chips_per_host=4, seed=seed,
                     chaos=ChaosConfig(seed=seed),
                     tick_period=5.0)
    c = ApiClient.for_platform(p)
    # a few faulty hosts (the paper found 12/700 jobs on bad nodes)
    faulty = [f"host-{i:04d}" for i in (7, 33, 101)] if heavy else []

    jobs_by_class: dict[str, list[str]] = {}
    sensitivity: dict[str, float] = {}
    submitted = []
    for label, n_ll, n_hl, start, dur, sens in BATCHES:
        n = n_hl if heavy else n_ll
        sensitivity[label] = sens
        ids = []
        for i in range(n):
            m = JobManifest(name=f"{label}-{i}", n_learners=1,
                            chips_per_learner=1, sim_duration=dur,
                            max_restarts=5)
            ids.append((start, m))
        jobs_by_class[label] = []
        submitted.append((label, ids))

    # submit on schedule
    pending = [(start, label, m) for label, ids in submitted
               for start, m in ids]
    pending.sort(key=lambda x: x[0])
    runtimes: dict[str, tuple[str, float]] = {}  # job_id → (label, t_submit)

    idx = 0
    killed_faulty = False
    t_end = 3600.0 * 16
    while p.clock.now() < t_end:
        while idx < len(pending) and pending[idx][0] <= p.clock.now():
            start, label, m = pending[idx]
            jid = c.submit(m)
            jobs_by_class[label].append(jid)
            runtimes[jid] = (label, p.clock.now())
            idx += 1
        # contention model: overload factor of the shared pipe, scaled by
        # each class's input-bandwidth sensitivity
        active = 0
        for g in p.guardians.values():
            for rt in g.runtimes.values():
                if getattr(rt, "phase", "") == "PROCESSING":
                    active += 1
        overload = max(0.0, active / BANDWIDTH_LEARNERS - 1.0)
        for jid, g in p.guardians.items():
            label = runtimes.get(jid, ("K80-b1", 0))[0]
            s = sensitivity.get(label, 0.5)
            for rt in g.runtimes.values():
                if hasattr(rt, "slowdown"):
                    rt.slowdown = 1.0 + overload * s
        # inject the faulty-node event once jobs are running
        if heavy and not killed_faulty and p.clock.now() > 2400:
            for h in faulty:
                p.cluster.fail_host(h)
            killed_faulty = True
        p.tick()
        if idx >= len(pending):
            done = all(p.meta.get(j).status in
                       (JobStatus.COMPLETED, JobStatus.FAILED)
                       for js in jobs_by_class.values() for j in js)
            if done:
                break

    # per-class end-to-end runtimes
    out = {}
    all_done = 0
    failed = 0
    for label, js in jobs_by_class.items():
        times = []
        for j in js:
            rec = p.meta.get(j)
            if rec.status == JobStatus.COMPLETED:
                # runtime from placement (queue wait excluded, as in Fig 5's
                # per-class runtime comparison)
                t0 = rec.scheduled_at or rec.submitted_at
                times.append(rec.finished_at - t0)
                all_done += 1
            else:
                failed += 1
        out[label] = float(np.mean(times)) if times else float("nan")
    evicted = p.events.count("pod_evicted")
    return {"e2e_s": out, "completed": all_done, "failed": failed,
            "evictions": evicted,
            "restarts": p.events.count("learners_replaced")}


def run() -> dict:
    ll = run_scenario(heavy=False)
    hl = run_scenario(heavy=True)
    degr = {}
    for label in ll["e2e_s"]:
        a, b = ll["e2e_s"][label], hl["e2e_s"][label]
        degr[label] = 100.0 * (b - a) / a if a == a and b == b else float("nan")
    return {"light": ll, "heavy": hl, "degradation_pct": degr}


def main():
    out = run()
    print("# §5.5 analogue: scale test, 680 chips, LL=70 vs HL=700 jobs")
    print("class,e2e_light_s,e2e_heavy_s,degradation_pct,paper_pct")
    paper = {"K80-b1": "6-8", "K80-b2": "6-8", "P100-b3": "~24",
             "V100-b4": "~51"}
    for label in out["light"]["e2e_s"]:
        print(f"{label},{out['light']['e2e_s'][label]:.0f},"
              f"{out['heavy']['e2e_s'][label]:.0f},"
              f"{out['degradation_pct'][label]:.1f},{paper[label]}")
    print(f"heavy_completed,{out['heavy']['completed']}")
    print(f"heavy_failed,{out['heavy']['failed']}")
    print(f"heavy_evictions,{out['heavy']['evictions']}")
    return out


if __name__ == "__main__":
    main()
