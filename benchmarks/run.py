"""Benchmark driver: one module per paper table/figure.

  Table 1/2  → benchmarks.overhead     (platform overhead vs bare metal)
  Table 3    → benchmarks.recovery     (component crash-recovery times)
  Fig 3      → benchmarks.spread_pack  (60-day trace, SPREAD vs PACK)
  Fig 4      → benchmarks.gang         (gang vs pod-at-a-time deadlocks)
  Tables 4-6 → benchmarks.sizing       (feeder scaling + t-shirt sizes)
  §5.5       → benchmarks.scale        (680 chips, 70 vs 700 jobs)
  §5.6       → benchmarks.failures     (chaos campaign failure analysis)
  §Roofline  → benchmarks.roofline     (dry-run-derived roofline table)
  §3.2       → benchmarks.api_tier     (replicated API availability/latency)
  §7         → benchmarks.hotpath      (indexed control-plane hot paths)
  §3.2/§4    → benchmarks.observability (SSE streaming, event replay)
  §6         → benchmarks.operator     (autonomous operator: autoscale,
                                        isolation, rolling upgrade)
  §3/§4      → benchmarks.serving      (declarative pipelines + serving
                                        tier QoS under flood)
  §5.6       → benchmarks.faults       (gray-failure resilience: fault
                                        plane vs deadlines/breakers/retry)

Per-benchmark summary lines are CSV-ish: name,us_per_call,derived.
``hotpath``'s full run additionally writes ``BENCH_hotpath.json`` at the
repo root (``hotpath.main`` owns that artifact) — the tracked perf
trajectory.
"""

from __future__ import annotations

import argparse
import json
import os
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args()

    from benchmarks import (
        api_tier,
        failures,
        faults,
        gang,
        hotpath,
        observability,
        operator,
        overhead,
        recovery,
        roofline,
        scale,
        serving,
        sizing,
        spread_pack,
    )

    all_benches = [
        ("api_tier_s3_2", api_tier.main),
        ("hotpath", hotpath.main),
        ("observability", observability.main),
        ("operator", operator.main),
        ("overhead_table1_2", overhead.main),
        ("recovery_table3", recovery.main),
        ("spread_pack_fig3", spread_pack.main),
        ("gang_fig4", gang.main),
        ("sizing_tables4_6", sizing.main),
        ("scale_s5_5", scale.main),
        ("serving", serving.main),
        ("failures_s5_6", failures.main),
        ("faults", faults.main),
        ("roofline", roofline.main),
    ]
    only = set(args.only.split(",")) if args.only else None
    os.makedirs(args.out, exist_ok=True)

    summary = []
    failed = []
    for name, fn in all_benches:
        if only and name not in only:
            continue
        print(f"\n{'='*72}\n== {name}\n{'='*72}", flush=True)
        t0 = time.perf_counter()
        try:
            result = fn()
            dt = time.perf_counter() - t0
            summary.append((name, dt))
            with open(os.path.join(args.out, f"{name}.json"), "w") as f:
                json.dump(result, f, indent=1, default=str)
        except Exception as e:
            failed.append(name)
            print(f"BENCH FAILED {name}: {type(e).__name__}: {e}")
            traceback.print_exc(limit=5)

    print(f"\n{'='*72}\n== summary (name,us_per_call,derived)\n{'='*72}")
    for name, dt in summary:
        print(f"{name},{dt*1e6:.0f},wall_s={dt:.1f}")
    if failed:
        print(f"FAILED: {failed}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
