"""Table 1 & 2 — platform overhead vs bare metal.

Paper claim (Table 1): FfDL's dependability layers (containerization,
status pipeline, log collection, mounted object store) cost <= ~5% of
training throughput vs running the same job directly on bare metal.

Method here: train the same model/config/steps
  (a) bare metal — a raw jit'd loop, data in-process, no platform;
  (b) FfDL       — through the full platform path (Guardian-deployed
      learner, volume status writes, controller + log collector ticking,
      etcd relay; checkpointing disabled to isolate *platform* overhead,
      as the paper's measurement does);
and report images(tokens)/sec delta. Table 2's "specialized hardware" tier
is approximated by (c): the raw loop with donated buffers + no status I/O —
the upper bound a hand-tuned single-tenant setup would reach.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.api import ApiClient
from repro.core import FfDLPlatform, JobManifest, JobStatus


def _bare_metal(arch: str, steps: int, batch: int, seq: int, donate=False):
    from repro.configs import get_tiny_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import steps as msteps
    from repro.optim import adamw

    cfg = get_tiny_config(arch)
    opt_cfg = adamw.AdamWConfig(total_steps=steps)
    train = jax.jit(msteps.make_train_step(cfg, opt_cfg),
                    donate_argnums=(0,) if donate else ())
    data = SyntheticLM(DataConfig(cfg.vocab_size, seq, batch, seed=0))
    state = msteps.init_train_state(cfg, jax.random.key(0))
    # warmup/compile
    state, _ = train(state, data.batch_at(0))
    jax.block_until_ready(state.params)
    t0 = time.perf_counter()
    for s in range(1, steps):
        state, m = train(state, data.batch_at(s))
    jax.block_until_ready(state.params)
    dt = time.perf_counter() - t0
    return (steps - 1) * batch * seq / dt  # tokens/sec


def _through_platform(arch: str, steps: int, batch: int, seq: int):
    p = FfDLPlatform(n_hosts=2, chips_per_host=4)
    c = ApiClient.for_platform(p)
    j = c.submit(JobManifest(
        name="bench", arch=arch, n_learners=1, chips_per_learner=2,
        checkpoint_interval=10 ** 9,  # no checkpoints: platform cost only
        train={"steps": steps, "batch": batch, "seq": seq}))
    # advance to PROCESSING (deployment cost excluded, as in the paper —
    # Table 1 measures steady-state images/sec)
    for _ in range(500):
        p.tick()
        rec = p.meta.get(j)
        if rec.status == JobStatus.PROCESSING and rec.progress_step >= 1:
            break
    start_step = rec.progress_step
    t0 = time.perf_counter()
    while p.meta.get(j).status == JobStatus.PROCESSING:
        p.tick()
    dt = time.perf_counter() - t0
    done = p.run_until_terminal([j], max_sim_s=1000)
    assert done and c.status(j) == JobStatus.COMPLETED
    n_steps = steps - start_step
    return n_steps * batch * seq / dt


def run(steps: int = 80, batch: int = 8, seq: int = 128) -> dict:
    rows = []
    for arch in ["smollm-360m", "qwen2.5-3b", "recurrentgemma-2b"]:
        bare = _bare_metal(arch, steps, batch, seq)
        plat = _through_platform(arch, steps, batch, seq)
        tuned = _bare_metal(arch, steps, batch, seq, donate=True)
        rows.append({
            "arch": arch,
            "bare_tokens_s": bare,
            "platform_tokens_s": plat,
            "tuned_tokens_s": tuned,
            "overhead_vs_bare_pct": 100 * (1 - plat / bare),
            "gap_vs_tuned_pct": 100 * (1 - plat / tuned),
        })
    return {"table": rows}


def main():
    out = run()
    print("# Table 1/2 analogue: platform overhead")
    print("arch,bare_tok_s,platform_tok_s,overhead_pct,gap_vs_tuned_pct")
    for r in out["table"]:
        print(f"{r['arch']},{r['bare_tokens_s']:.0f},"
              f"{r['platform_tokens_s']:.0f},"
              f"{r['overhead_vs_bare_pct']:.2f},{r['gap_vs_tuned_pct']:.2f}")
    return out


if __name__ == "__main__":
    main()
