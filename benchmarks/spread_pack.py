"""Figure 3 — SPREAD vs PACK on a 60-day production-like trace.

Paper: job arrival traces from a 400-GPU production cluster over 60 days,
replayed against both placement policies; PACK yields >3x fewer jobs queued
longer than 15 minutes (the user-satisfaction threshold).

Method: a synthetic-but-realistic 60-day trace (diurnal Poisson arrivals,
log-normal durations, the paper's mix of 1/2/4-learner x 1/2/4-chip jobs)
replayed through a pure scheduler+cluster discrete-event simulation (no
guardians — this isolates placement policy, like the paper's simulation).
"""

from __future__ import annotations

import heapq

import numpy as np

from repro.core.cluster import ClusterModel
from repro.core.kvstore import EtcdLike
from repro.core.scheduler import GangRequest, GangScheduler
from repro.core.types import EventLog, Pod, SimClock

QUEUE_SLA_S = 15 * 60  # the paper's 15-minute threshold
DAY = 86400.0


def make_trace(days=60, mean_jobs_per_day=280, seed=0):
    """[(arrival_s, n_learners, chips_per_learner, duration_s)] sorted.

    Calibrated to the paper's setting: a *heavily loaded* 400-GPU cluster
    (~75% mean demand, >100% at diurnal peaks — §5.2 "with heavily loaded
    clusters"), with a long-tailed duration distribution and a mix of
    single- and multi-chip learners (the 4-chip learners are the ones
    fragmentation starves)."""
    rng = np.random.default_rng(seed)
    jobs = []
    for d in range(days):
        weekday = d % 7 < 5
        lam = mean_jobs_per_day * (1.0 if weekday else 0.45)
        n = rng.poisson(lam)
        for _ in range(n):
            hour = rng.beta(3, 3) * 12 + 7  # 7:00–19:00 centre-heavy
            t = d * DAY + hour * 3600 + rng.uniform(0, 600)
            n_l = rng.choice([1, 1, 1, 2, 2, 4], p=[.45, .2, .1, .15, .05, .05])
            cpl = rng.choice([1, 2, 4], p=[.35, .3, .35])
            dur = float(np.clip(rng.lognormal(8.9, 0.9), 900, 8 * 3600))
            jobs.append((t, int(n_l), int(cpl), dur))
    jobs.sort()
    return jobs


def simulate(trace, placement: str, n_hosts=100, chips=4, seed=0):
    """Event-driven replay. Returns per-day count of jobs queued > 15 min."""
    clock = SimClock()
    events = EventLog(clock)
    etcd = EtcdLike(clock, events)
    cluster = ClusterModel(n_hosts, chips, clock, etcd, events)
    sched = GangScheduler(cluster, events, placement=placement, seed=seed)

    submitted_at: dict[str, float] = {}
    placed_at: dict[str, float] = {}
    finish_heap: list = []

    def on_placed(req: GangRequest):
        placed_at[req.job_id] = clock.now()
        # bind pods so capacity is held for the duration
        for i, host in enumerate(req.placement):
            pod = Pod(name=f"{req.job_id}-l{i}", job_id=req.job_id,
                      kind="learner", chips=req.chips_per_pod)
            cluster.bind_pod(pod, host)
        sched.confirm(req.job_id)
        dur = durations[req.job_id]
        heapq.heappush(finish_heap, (clock.now() + dur, req.job_id))

    sched.on_placed = on_placed
    durations: dict[str, float] = {}

    i = 0
    while i < len(trace) or finish_heap:
        # next event: arrival or finish
        t_arr = trace[i][0] if i < len(trace) else float("inf")
        t_fin = finish_heap[0][0] if finish_heap else float("inf")
        if t_arr <= t_fin:
            t, n_l, cpl, dur = trace[i]
            i += 1
            clock.run_until(t)
            clock.advance(t - clock.now())
            job_id = f"t{i}"
            durations[job_id] = dur
            submitted_at[job_id] = t
            sched.submit(GangRequest(job_id, n_l, cpl, submitted_at=t))
        else:
            t, job_id = heapq.heappop(finish_heap)
            clock.advance(t - clock.now())
            for k in range(64):
                if f"{job_id}-l{k}" in cluster.pods:
                    cluster.delete_pod(f"{job_id}-l{k}", reason="done")
                else:
                    break
            sched.release(job_id)
        sched.tick()

    # any never-placed jobs count as SLA misses too
    delayed_by_day = np.zeros(61, dtype=int)
    total_by_day = np.zeros(61, dtype=int)
    for job_id, t_sub in submitted_at.items():
        day = min(int(t_sub // DAY), 60)
        total_by_day[day] += 1
        wait = placed_at.get(job_id, t_sub + 10 * QUEUE_SLA_S) - t_sub
        if wait > QUEUE_SLA_S:
            delayed_by_day[day] += 1
    return delayed_by_day, total_by_day


def run(days=60, seed=0) -> dict:
    trace = make_trace(days=days, seed=seed)
    d_spread, totals = simulate(trace, "spread", seed=seed)
    d_pack, _ = simulate(trace, "pack", seed=seed)
    spread_total = int(d_spread.sum())
    pack_total = int(d_pack.sum())
    return {
        "jobs": len(trace),
        "delayed_spread": spread_total,
        "delayed_pack": pack_total,
        "improvement_x": spread_total / max(pack_total, 1),
        "by_day": {"spread": d_spread.tolist(), "pack": d_pack.tolist(),
                   "arrivals": totals.tolist()},
    }


def main():
    out = run()
    print("# Fig 3 analogue: SPREAD vs PACK, 60-day trace, 400-chip cluster")
    print(f"jobs,{out['jobs']}")
    print(f"queued_gt_15min_spread,{out['delayed_spread']}")
    print(f"queued_gt_15min_pack,{out['delayed_pack']}")
    print(f"improvement_x,{out['improvement_x']:.2f}  (paper: >3x)")
    return out


if __name__ == "__main__":
    main()
