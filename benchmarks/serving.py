"""Declarative-workloads drill: pipeline convergence with a chaos-killed
stage, and per-tenant serving QoS over the real HTTP tier.

The reconciler (repro.workloads) must converge a declared train→eval→serve
pipeline to a RUNNING inference Service unattended, re-converge when a
mid-pipeline stage is killed out from under it, and the reaction must be
free for tenants: **zero failed v1 requests** while stages submit, retry,
and the serving tier scales. Two drills:

  * ``pipeline`` — apply a three-stage Pipeline manifest; every tick each
    tenant lists its jobs and reads its workload status (any ApiError is
    a failure — asserted 0). Mid-run the eval stage's job is killed; the
    per-spec retry must resubmit it and the pipeline must still land
    SUCCEEDED with the child Service RUNNING and answering invokes.
  * ``qos`` — a real ApiHttpServer with per-tenant token buckets; a prod
    tenant and a flooding tenant each run a one-replica Service. The
    flood's invokes saturate its own bucket (429s, counted); the prod
    tenant's invokes must never fail — the serving tier's multi-tenant
    QoS rides the existing rate limiter, not new machinery.

Emits machine-readable ``BENCH_serving.json`` at the repo root (full
mode). ``--quick`` shrinks tick counts and invoke rounds; every
zero-failure and convergence assertion still holds.
"""

from __future__ import annotations

import json
import os
import sys
import threading
import time

from repro.api import (
    ApiClient,
    ApiError,
    ApiHttpServer,
    ErrorCode,
    Federation,
    HttpTransport,
    WorkloadClient,
)
from repro.api.http import RateLimitConfig

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_serving.json")

PIPELINE = """\
kind: Pipeline
name: lm-pipe
tenant: team-a
stages:
  - name: train
    job:
      n_learners: 1
      chips_per_learner: 1
      sim_duration: 5
      train:
        tiny: true
        steps: 2
  - name: eval
    after: [train]
    retries: 1
    job:
      n_learners: 1
      chips_per_learner: 1
      sim_duration: 5
  - name: serve
    after: [eval]
    service:
      replicas: 2
      chips_per_replica: 1
"""


def _pipeline_drill(quick: bool) -> dict:
    max_ticks = 120 if quick else 300
    # tick_period=5 sim-s/tick: stage jobs clear the fixed 30 s data
    # stage in a handful of ticks, so the whole DAG fits the window
    fed = Federation(n_shards=2, n_hosts=2, chips_per_host=4,
                     tick_period=5.0)
    tenants = ("team-a", "team-b")
    clients = {t: ApiClient(fed.api, fed.auth.issue_key(t))
               for t in tenants}
    admin = ApiClient(fed.api, fed.auth.issue_admin_key())
    wl = WorkloadClient(fed.workloads_api, fed.auth.issue_key("team-a"))
    wl.apply(PIPELINE)
    counters = {"requests": 0, "failures": 0}
    killed_at = None
    done_at = None
    t0 = time.perf_counter()
    for i in range(max_ticks):
        fed.tick()
        # availability probe: the v1 plane answers while the reconciler
        # submits/retries stages and materializes the serving tier
        for t, c in clients.items():
            counters["requests"] += 1
            try:
                c.list_jobs(limit=5)
            except ApiError as e:
                counters["failures"] += 1
                counters.setdefault("failure_kinds", []).append(
                    f"{t}: {e.code.value}")
        view = wl.get("lm-pipe")
        eval_st = view["status"]["stages"]["eval"]
        if killed_at is None and eval_st["state"] == "RUNNING" and \
                eval_st["job"] is not None:
            # chaos: kill the mid-pipeline stage once it is admitted
            meta = fed.router.shard_for("team-a").platform.meta
            if meta.get(eval_st["job"]).status.value != "PENDING":
                admin.cancel(eval_st["job"])
                killed_at = i + 1
        if view["status"]["phase"] == "SUCCEEDED":
            done_at = i + 1
            break
    wall = time.perf_counter() - t0
    assert counters["failures"] == 0, counters
    assert killed_at is not None, "the chaos kill never fired"
    assert done_at is not None, "pipeline never converged"
    view = wl.get("lm-pipe")
    assert view["status"]["stages"]["eval"]["attempts"] == 2, \
        "the killed stage was not retried per spec"
    child = wl.get("lm-pipe-serve")
    assert child["status"]["phase"] == "RUNNING", child["status"]
    replicas = [wl.invoke("lm-pipe-serve")["replica"] for _ in range(4)]
    assert sorted(set(replicas)) == ["0", "1"], \
        f"invokes not spread round-robin: {replicas}"
    events = {k: sum(p.events.count(k) for p in fed.shards
                     if p.backend.alive)
              for k in ("workload_stage_submitted", "workload_pipeline_done",
                        "workload_service_ready")}
    return {"ticks": done_at, "killed_at_tick": killed_at,
            "eval_attempts": 2, "v1_requests": counters["requests"],
            "v1_failures": 0, "stage_submits":
                events["workload_stage_submitted"],
            "pipeline_done_events": events["workload_pipeline_done"],
            "service_ready_events": events["workload_service_ready"],
            "wall_s": round(wall, 3)}


def _qos_drill(quick: bool) -> dict:
    rounds = 30 if quick else 120
    fed = Federation(n_shards=2, n_hosts=2, chips_per_host=4,
                     tick_period=5.0,
                     pins={"prod": "shard-0", "flood": "shard-1"})
    server = ApiHttpServer(
        fed, rate_limit=RateLimitConfig(rate=2000.0, burst=4000),
        per_tenant={"flood": RateLimitConfig(rate=5.0, burst=5)})
    out = {"rounds": rounds}
    with server:
        transport = HttpTransport(server.base_url)
        prod = WorkloadClient(transport, fed.auth.issue_key("prod"))
        flood = WorkloadClient(transport, fed.auth.issue_key("flood"))
        for c, tenant in ((prod, "prod"), (flood, "flood")):
            c.apply(f"kind: Service\nname: infer\ntenant: {tenant}\n"
                    f"replicas: 1\n")
        stop = threading.Event()

        def ticker():
            while not stop.is_set():
                fed.tick()

        t = threading.Thread(target=ticker, daemon=True)
        t.start()
        try:
            deadline = time.monotonic() + 60
            while time.monotonic() < deadline:
                if prod.get("infer")["status"]["phase"] == "RUNNING" and \
                        flood.get("infer")["status"]["phase"] == "RUNNING":
                    break
                time.sleep(0.02)
            else:
                raise AssertionError("services never converged over HTTP")
            counters = {"prod_ok": 0, "prod_failures": 0,
                        "flood_ok": 0, "flood_429": 0}
            lat = []
            t0 = time.perf_counter()
            for _ in range(rounds):
                r0 = time.perf_counter()
                prod.invoke("infer")          # any raise = drill failure
                lat.append(time.perf_counter() - r0)
                counters["prod_ok"] += 1
                for _ in range(4):            # the flood outruns its bucket
                    try:
                        flood.invoke("infer")
                        counters["flood_ok"] += 1
                    except ApiError as e:
                        assert e.code == ErrorCode.RATE_LIMITED, e
                        counters["flood_429"] += 1
            wall = time.perf_counter() - t0
        finally:
            stop.set()
            t.join(timeout=10)
    assert counters["prod_failures"] == 0
    assert counters["prod_ok"] == rounds
    assert counters["flood_429"] > 0, "the flood was never throttled"
    lat.sort()
    out.update(counters)
    out.update({
        "prod_invoke_p50_ms": round(lat[len(lat) // 2] * 1e3, 3),
        "prod_invoke_p99_ms": round(lat[int(len(lat) * 0.99)] * 1e3, 3),
        "wall_s": round(wall, 3)})
    return out


def run(quick: bool = False) -> dict:
    out = {"quick": quick}

    print("pipeline: train→eval→serve with a chaos-killed stage ...",
          flush=True)
    out["pipeline"] = _pipeline_drill(quick)
    d = out["pipeline"]
    print(f"  converged at tick {d['ticks']} (stage killed at "
          f"{d['killed_at_tick']}, retried); {d['v1_requests']} v1 "
          f"requests, 0 failed")

    print("qos: flooding tenant throttled, prod invokes clean ...",
          flush=True)
    out["qos"] = _qos_drill(quick)
    d = out["qos"]
    print(f"  {d['prod_ok']} prod invokes ok (p50 "
          f"{d['prod_invoke_p50_ms']} ms), flood saw {d['flood_429']} "
          f"429s")
    return out


def main(argv=None):
    quick = "--quick" in (argv if argv is not None else sys.argv[1:])
    out = run(quick=quick)
    if not quick:
        with open(OUT_PATH, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {OUT_PATH}")
    print("SERVING BENCH OK")
    return out


if __name__ == "__main__":
    main()
