"""Table 3 — time to recover from crash failures, by component.

Paper: API 3-5s, LCM 4-6s, Guardian 1-2s, Helper 3-4s, Learner 10-20s
(learners take longest: rebinding object storage + volumes).

Method: crash each component of a live platform and measure simulated time
until the component is functional again (API answering, LCM reconciling,
Guardian monitoring, controller relaying, learner PROCESSING again).
"""

from __future__ import annotations

from repro.api import ApiClient
from repro.core import FfDLPlatform, JobManifest, JobStatus


def _until(p, cond, limit=600.0):
    t0 = p.clock.now()
    while p.clock.now() - t0 < limit:
        p.tick()
        if cond():
            return p.clock.now() - t0
    return float("inf")


def run() -> dict:
    results = {}

    # -- API: stateless replica restart ---------------------------------
    p = FfDLPlatform(n_hosts=2, chips_per_host=4, tick_period=0.5)
    p.api_crash()
    p.clock.call_later(3.0, p.api_restart)  # k8s service failover window

    def api_ok():
        try:
            p.meta.jobs()
            return p._api_up
        except ConnectionError:
            return False

    results["API"] = _until(p, api_ok)

    # -- LCM: crash before it created the job's guardian ------------------
    p = FfDLPlatform(n_hosts=2, chips_per_host=4, tick_period=0.5)
    c = ApiClient.for_platform(p)
    j = c.submit(JobManifest(name="r", n_learners=1, chips_per_learner=1,
                             sim_duration=200))
    p.lcm.crash()
    p.clock.call_later(4.0, p.lcm.restart)
    results["LCM"] = _until(p, lambda: j in p.guardians)

    # -- Guardian: crash while monitoring; K8s Job restarts it -----------
    p = FfDLPlatform(n_hosts=2, chips_per_host=4, tick_period=0.5)
    c = ApiClient.for_platform(p)
    j = c.submit(JobManifest(name="g", n_learners=1, chips_per_learner=1,
                             sim_duration=500))
    _until(p, lambda: j in p.guardians and p.guardians[j].stage == "MONITOR")
    g = p.guardians[j]
    g.crash()
    p.clock.call_later(1.0, g.restart)  # k8s Job restart backoff
    results["Guardian"] = _until(p, lambda: g.alive and g.stage == "MONITOR")

    # -- Helper (controller): restart + status relay resumes --------------
    p = FfDLPlatform(n_hosts=2, chips_per_host=4, tick_period=0.5)
    c = ApiClient.for_platform(p)
    j = c.submit(JobManifest(name="h", n_learners=1, chips_per_learner=1,
                             sim_duration=500))
    _until(p, lambda: p.meta.get(j).status == JobStatus.PROCESSING)
    ctrl = p.guardians[j].controller
    ctrl.crash()
    p.etcd.delete(f"/jobs/{j}/learners/0/status")  # stale state gone
    p.clock.call_later(3.0, ctrl.restart)
    results["Helper"] = _until(
        p, lambda: p.etcd.get(f"/jobs/{j}/learners/0/status") is not None)

    # -- Learner: pod crash → stateful-set restart → container Running ----
    # (the paper's Table 3 measures restart-to-Running: rebinding the object
    # store and volumes — not the subsequent data re-download)
    from repro.core.types import PodPhase
    p = FfDLPlatform(n_hosts=2, chips_per_host=4, tick_period=0.5)
    c = ApiClient.for_platform(p)
    j = c.submit(JobManifest(name="l", n_learners=1, chips_per_learner=1,
                             sim_duration=500, max_restarts=5))
    _until(p, lambda: p.meta.get(j).status == JobStatus.PROCESSING)
    g = p.guardians[j]
    g.runtimes[0].kill()
    p.cluster.fail_pod(g.pods[0].name)
    results["Learner"] = _until(
        p, lambda: g.pods[0].phase == PodPhase.RUNNING)

    return {"recovery_s": results,
            "paper_ranges": {"API": (3, 5), "LCM": (4, 6),
                             "Guardian": (1, 2), "Helper": (3, 4),
                             "Learner": (10, 20)}}


def main():
    out = run()
    print("# Table 3 analogue: component recovery times")
    print("component,measured_s,paper_range_s")
    for comp, t in out["recovery_s"].items():
        lo, hi = out["paper_ranges"][comp]
        print(f"{comp},{t:.1f},{lo}-{hi}")
    return out


if __name__ == "__main__":
    main()
