"""Autonomous-operator drill: autoscaling, hot-tenant isolation, and a
rolling upgrade, under a bursty multi-tenant trace with the v1 data plane
answering every tick.

The operator (repro.obs.operator) must react to load the way FfDL §6's
retrospective demands — automatically — and the reaction must be free for
tenants: **zero failed v1 requests** while shards are spawned, drained,
retired, and upgraded underneath them. Three drills:

  * ``autoscale`` — a burst saturates a 2-shard fleet; the operator must
    scale up (spawn + drain-into), then, when the burst completes, scale
    back down (drain + retire) to the floor. Every tick, every tenant
    lists and stats its jobs; any ApiError is a failure (asserted 0).
  * ``isolation`` — two tenants share a shard, one runs hot; the operator
    must migrate the hot one to the quietest shard (asserted), again with
    zero failed tenant reads.
  * ``rollout`` — a 3-shard fleet with resident tenants upgrades to a new
    version in GUARD-style waves; the drill asserts every shard lands on
    the target version, one wave per shard, and tenants never failed.

Emits machine-readable ``BENCH_operator.json`` at the repo root (full
mode). ``--quick`` shrinks tick counts and tenant fan-out; every
zero-failure and action assertion still holds.
"""

from __future__ import annotations

import json
import os
import sys
import time

from repro.api import AdminClient, ApiClient, ApiError, Federation
from repro.api.ops import install_operator
from repro.core import JobManifest
from repro.obs.operator import OperatorConfig

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_operator.json")


def _probe(clients, jobs, counters):
    """One availability sweep: every tenant lists its jobs and stats one.
    This IS the measurement — any ApiError during an operator action is a
    tenant-visible failure."""
    for tenant, c in clients.items():
        counters["requests"] += 2
        try:
            c.list_jobs(limit=5)
            if tenant in jobs:
                c.view(jobs[tenant])
        except ApiError as e:
            counters["failures"] += 1
            counters.setdefault("failure_kinds", []).append(
                f"{tenant}: {e.code.value}")


def _autoscale_drill(quick: bool) -> dict:
    n_tenants = 4 if quick else 8
    ticks = 160 if quick else 400
    # tick_period=10 sim-s/tick so the burst finishes inside the drill
    # window and the scale-down half of the loop gets exercised too
    fed = Federation(n_shards=2, n_hosts=2, chips_per_host=2,
                     tick_period=10.0)  # 8 chips
    tenants = [f"team-{i:02d}" for i in range(n_tenants)]
    for i, t in enumerate(tenants):
        fed.pin(t, f"shard-{i % 2}")
    install_operator(fed, OperatorConfig(
        high_water=0.7, low_water=0.15, streak_ticks=3, cooldown_ticks=8,
        max_shards=6, validate_ticks=2))
    clients = {t: ApiClient(fed.api, fed.auth.issue_key(t))
               for t in tenants}
    # the burst: every tenant wants 2 chips for a while — 2x the fleet
    jobs = {t: clients[t].submit(JobManifest(
        name=f"{t}-burst", tenant=t, n_learners=1, chips_per_learner=2,
        sim_duration=150 if quick else 300)) for t in tenants}
    counters = {"requests": 0, "failures": 0}
    t0 = time.perf_counter()
    for _ in range(ticks):
        fed.tick()
        _probe(clients, jobs, counters)
    wall = time.perf_counter() - t0
    admin = AdminClient.for_platform(fed)
    shards = admin.list_shards()
    events = {k: sum(p.events.count(k) for p in fed.shards
                     if p.backend.alive)
              for k in ("operator_scale_up", "operator_scale_down")}
    retired = [s["shard_id"] for s in shards if s["retired"]]
    active = [s for s in shards if not s["retired"] and not s["cordoned"]]
    assert counters["failures"] == 0, counters
    assert events["operator_scale_up"] >= 1, \
        "the burst never triggered a scale-up"
    assert events["operator_scale_down"] >= 1 and retired, \
        "the idle fleet never scaled back down"
    assert len(active) >= 2, "scaled below the min_shards floor"
    return {"tenants": n_tenants, "ticks": ticks,
            "v1_requests": counters["requests"], "v1_failures": 0,
            "scale_ups": events["operator_scale_up"],
            "scale_downs": events["operator_scale_down"],
            "shards_final": len(shards), "shards_retired": len(retired),
            "decisions": len(admin.operator_status()["decisions"]),
            "wall_s": round(wall, 3)}


def _isolation_drill(quick: bool) -> dict:
    ticks = 60 if quick else 150
    fed = Federation(n_shards=2, n_hosts=4, chips_per_host=4)
    fed.pin("team-hot", "shard-0")
    fed.pin("team-cold", "shard-0")
    install_operator(fed, OperatorConfig(
        high_water=9.9, low_water=-1.0, hot_share=0.6, min_heat=0.5,
        heat_window=4, isolate_cooldown_ticks=30))
    clients = {t: ApiClient(fed.api, fed.auth.issue_key(t))
               for t in ("team-hot", "team-cold")}
    jobs = {"team-hot": clients["team-hot"].submit(JobManifest(
                name="burn", tenant="team-hot", n_learners=2,
                chips_per_learner=2, sim_duration=1e6)),
            "team-cold": clients["team-cold"].submit(JobManifest(
                name="idle", tenant="team-cold", sim_duration=5))}
    counters = {"requests": 0, "failures": 0}
    isolated_at = None
    t0 = time.perf_counter()
    for i in range(ticks):
        fed.tick()
        _probe(clients, jobs, counters)
        if isolated_at is None and fed.shard_of("team-hot") == "shard-1":
            isolated_at = i + 1
    wall = time.perf_counter() - t0
    assert counters["failures"] == 0, counters
    assert isolated_at is not None, "hot tenant was never isolated"
    assert fed.shard_of("team-cold") == "shard-0"
    n_events = sum(p.events.count("operator_isolate_tenant")
                   for p in fed.shards)
    assert n_events == 1, f"expected exactly one isolation, saw {n_events}"
    return {"ticks": ticks, "isolated_at_tick": isolated_at,
            "v1_requests": counters["requests"], "v1_failures": 0,
            "wall_s": round(wall, 3)}


def _rollout_drill(quick: bool) -> dict:
    max_ticks = 80 if quick else 200
    fed = Federation(n_shards=3, n_hosts=2, chips_per_host=2)
    tenants = ("team-a", "team-b", "team-c")
    for t, sid in zip(tenants, ("shard-0", "shard-1", "shard-2")):
        fed.pin(t, sid)
    install_operator(fed, OperatorConfig(
        high_water=9.9, low_water=-1.0, validate_ticks=2))
    clients = {t: ApiClient(fed.api, fed.auth.issue_key(t))
               for t in tenants}
    jobs = {t: clients[t].submit(JobManifest(
        name=f"{t}-ride", tenant=t, sim_duration=1e6)) for t in tenants}
    admin = AdminClient.for_platform(fed)
    admin.rollout("v1")
    counters = {"requests": 0, "failures": 0}
    done_at = None
    t0 = time.perf_counter()
    for i in range(max_ticks):
        fed.tick()
        _probe(clients, jobs, counters)
        if admin.operator_status()["rollout"]["state"] == "done":
            done_at = i + 1
            break
    wall = time.perf_counter() - t0
    assert counters["failures"] == 0, counters
    assert done_at is not None, "rollout never completed"
    versions = {s["shard_id"]: s["version"] for s in admin.list_shards()}
    assert set(versions.values()) == {"v1"}, versions
    waves = sum(p.events.count("operator_rollout_wave") for p in fed.shards)
    assert waves == 3, f"expected 3 waves, saw {waves}"
    return {"shards": 3, "waves": waves, "done_at_tick": done_at,
            "v1_requests": counters["requests"], "v1_failures": 0,
            "wall_s": round(wall, 3)}


def run(quick: bool = False) -> dict:
    out = {"quick": quick}

    print("autoscale: burst -> scale-up -> drain -> retire ...", flush=True)
    out["autoscale"] = _autoscale_drill(quick)
    d = out["autoscale"]
    print(f"  {d['scale_ups']} scale-up(s), {d['scale_downs']} "
          f"scale-down(s), {d['shards_retired']} retired; "
          f"{d['v1_requests']} v1 requests, 0 failed")

    print("isolation: hot tenant auto-migrated off a shared shard ...",
          flush=True)
    out["isolation"] = _isolation_drill(quick)
    d = out["isolation"]
    print(f"  isolated at tick {d['isolated_at_tick']}; "
          f"{d['v1_requests']} v1 requests, 0 failed")

    print("rollout: 3 shards upgraded in health-gated waves ...",
          flush=True)
    out["rollout"] = _rollout_drill(quick)
    d = out["rollout"]
    print(f"  {d['waves']} waves, done at tick {d['done_at_tick']}; "
          f"{d['v1_requests']} v1 requests, 0 failed")
    return out


def main(argv=None):
    quick = "--quick" in (argv if argv is not None else sys.argv[1:])
    out = run(quick=quick)
    if not quick:
        with open(OUT_PATH, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {OUT_PATH}")
    print("OPERATOR BENCH OK")
    return out


if __name__ == "__main__":
    main()
