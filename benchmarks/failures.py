"""§5.6 / Table 8 / Figs 6-8 — failure analysis from the platform event log.

Paper findings over 4 months on a 680-GPU cluster:
  * scheduling failures concentrate on learner pods (>60%), helpers ~15%;
  * dominant reason: "No nodes available that match all of the predicates"
    (~64%), then transient binding/PVC errors;
  * pod deletions due to node failures stay under ~5%;
  * learner deletions from node failures → job cancellations < 1%/month.

Method: a long chaos campaign (mixed workload, every fault class enabled)
on a mid-size cluster; then aggregate the structured event log exactly the
way the paper mines its K8s scheduler/controller-manager logs.
"""

from __future__ import annotations

from collections import Counter

import numpy as np

from repro.api import ApiClient
from repro.core import ChaosConfig, FfDLPlatform, JobManifest, JobStatus


def run(months: int = 2, jobs_per_month: int = 550, seed: int = 0) -> dict:
    # Fault rates calibrated to production reality (the paper's §5.6 cluster
    # saw a handful of node failures per month, not per hour): probabilities
    # are per 2s tick; e.g. p_host_fail=2e-5 → ~2.5 host faults per
    # 10-hour "month" across 24 hosts.
    chaos = ChaosConfig(
        seed=seed,
        p_learner_crash=5e-5,
        p_host_fail=2e-5,
        p_guardian_crash=3e-5,
        p_controller_crash=5e-5,
        p_volume_fail=0.008,  # Table 8: PVC errors ~1.9% of failing pods
        host_recovery_s=180.0,
    )
    p = FfDLPlatform(n_hosts=24, chips_per_host=4, chaos=chaos, seed=seed,
                     tick_period=2.0)
    c = ApiClient.for_platform(p)
    rng = np.random.default_rng(seed)

    month_s = 3600.0 * 10  # compressed "month" of cluster time
    jobs = []
    monthly_learner_deletions = []
    monthly_job_cancels = []
    for month in range(months):
        t_month_end = (month + 1) * month_s
        arrivals = sorted(rng.uniform(month * month_s, t_month_end,
                                      jobs_per_month))
        ai = 0
        ev_mark = p.events.seq
        while p.clock.now() < t_month_end:
            while ai < len(arrivals) and arrivals[ai] <= p.clock.now():
                n_l = int(rng.choice([1, 1, 2, 4], p=[.5, .2, .2, .1]))
                cpl = int(rng.choice([1, 2], p=[.7, .3]))
                jobs.append(c.submit(JobManifest(
                    name=f"m{month}-{ai}", n_learners=n_l,
                    chips_per_learner=cpl,
                    sim_duration=float(rng.uniform(900, 3600)),
                    max_restarts=6)))
                ai += 1
            p.tick()
        month_events = p.events.since(ev_mark)
        deletions = [e for e in month_events if e.kind == "pod_deleted"]
        node_fail_del = [e for e in deletions
                         if e.fields.get("reason") == "node_failure"]
        learner_del = [e for e in node_fail_del
                       if "-l" in e.fields.get("pod", "")]
        monthly_learner_deletions.append(
            (len(learner_del), max(len(deletions), 1)))
        cancels = sum(1 for e in month_events if e.kind == "job_failed")
        monthly_job_cancels.append(cancels)

    # drain
    p.chaos.enabled = False
    p.run_until_terminal(jobs, max_sim_s=40000)

    ev = p.events
    # the paper mines UNIQUE pod names per failure reason (Table 8); we
    # aggregate unique jobs per reason the same way (a queued gang re-logs
    # no-nodes whenever the cluster/reservation state changed — the BSA
    # verdict cache suppresses byte-identical repeats).
    reason_jobs: dict[str, set] = {
        "no_nodes_match_predicates": set(),
        "binding_rejected": set(),
        "persistentvolumeclaim_not_found": set(),
        "assume_pod_failed": set(),
    }
    for e in ev.events:
        if e.kind == "no_nodes_available":
            reason_jobs["no_nodes_match_predicates"].add(e.fields.get("job"))
        elif e.kind == "binding_rejected":
            reason_jobs["binding_rejected"].add(e.fields.get("pod"))
        elif e.kind == "volume_provision_failed":
            reason_jobs["persistentvolumeclaim_not_found"].add(
                e.fields.get("job"))
        elif e.kind == "bind_failed":
            reason_jobs["assume_pod_failed"].add(e.fields.get("job"))
    sched_failures = Counter({k: len(v) for k, v in reason_jobs.items() if v})
    total_sched = max(sum(sched_failures.values()), 1)

    deletions = ev.of_kind("pod_deleted")
    node_fail = [e for e in deletions
                 if e.fields.get("reason") == "node_failure"]
    # pod-type distribution of scheduling-affected pods (Fig 6 analogue):
    # in our platform the no-nodes events are all gang (learner) level
    statuses = Counter(p.meta.get(j).status.value for j in jobs)
    return {
        "jobs": len(jobs),
        "final_statuses": dict(statuses),
        "sched_failure_reasons_pct": {
            k: 100.0 * v / total_sched for k, v in sched_failures.items()},
        "pod_deletions_total": len(deletions),
        "pod_deletions_node_failure_pct":
            100.0 * len(node_fail) / max(len(deletions), 1),
        "monthly_learner_del_pct": [
            100.0 * a / b for a, b in monthly_learner_deletions],
        "monthly_job_cancellations": monthly_job_cancels,
        "component_crashes": {
            "learner": ev.count("learner_killed"),
            "host": ev.count("host_killed"),
            "guardian": ev.count("guardian_crashed"),
            "controller": ev.count("controller_killed"),
        },
    }


def main():
    out = run()
    print("# §5.6 analogue: failure analysis (chaos campaign)")
    print(f"jobs,{out['jobs']}")
    for k, v in out["final_statuses"].items():
        print(f"status_{k},{v}")
    print("reason,pct  (paper: no_nodes ~64%)")
    for k, v in sorted(out["sched_failure_reasons_pct"].items(),
                       key=lambda kv: -kv[1]):
        print(f"{k},{v:.1f}")
    print(f"pod_deletions_node_failure_pct,"
          f"{out['pod_deletions_node_failure_pct']:.2f}  (paper: <5%)")
    print(f"monthly_learner_deletion_pct,"
          f"{[round(x, 2) for x in out['monthly_learner_del_pct']]}")
    print(f"component_crashes,{out['component_crashes']}")
    return out


if __name__ == "__main__":
    main()
