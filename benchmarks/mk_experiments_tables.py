"""Render the EXPERIMENTS.md roofline tables from experiments/dryrun2/*.json."""

import glob
import json


def fmt_row(r):
    c = r["collectives"]
    return (f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} | "
            f"{r['collective_s']*1e3:.1f} | {r['bottleneck']} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"{r['flops_per_device']:.3g} | "
            f"{r['collective_bytes_per_device']/1e9:.2f} |")


HDR = ("| arch | shape | kind | compute ms | memory ms | collective ms | "
       "bound | MODEL/HLO | HLO flops/dev | coll GB/dev |\n"
       "|---|---|---|---|---|---|---|---|---|---|")


def table(mesh):
    rows = []
    for f in sorted(glob.glob("experiments/dryrun2/*.json")):
        d = json.load(open(f))
        if d["mesh"] == mesh and "remat" not in f and "opt" not in f:
            rows.append(d)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return HDR + "\n" + "\n".join(fmt_row(r) for r in rows)


if __name__ == "__main__":
    print("### single-pod 16x16 (256 chips)\n")
    print(table("16x16"))
    print("\n### multi-pod 2x16x16 (512 chips)\n")
    print(table("2x16x16"))
