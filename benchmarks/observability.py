"""Observability-plane benchmark: SSE streaming vs long-poll, and
exactly-once event replay across a mid-stream shard kill.

FfDL §3.2's API tier must carry many concurrent followers (``ffdl logs
--follow`` et al.) without turning each into a request train. This
benchmark measures the two transports the tier now offers:

  * ``sse_vs_longpoll`` — one follower tails a job's logs to completion
    twice: over long-poll (bounded ``wait_ms`` per request) and over ONE
    server-sent-events connection. The transport's own counters
    (``requests_sent`` / ``streams_opened``) are the measurement: both
    followers deliver identical lines, and the SSE follower must issue
    **≥10× fewer HTTP requests** (asserted in full mode).
  * ``event_replay`` — a 2-shard federation emits a known event load;
    an admin pages ``/v2/events`` through composite cursors while one
    shard is killed mid-chain and restarted. The dead shard answers
    UNAVAILABLE (no silently partial pages); the same cursor then
    resumes, and the chain must serve every retained event exactly once
    — zero duplicates, zero gaps (asserted in both modes).

Emits machine-readable ``BENCH_observability.json`` at the repo root.
``--quick`` shrinks the job and the event load; the replay invariants
still hold, only the timing-sensitive 10× request-ratio assertion is
full-mode-only.
"""

from __future__ import annotations

import json
import os
import sys
import threading
import time

from repro.api import ApiClient, ApiError, ApiHttpServer, Federation, \
    HttpTransport
from repro.core import FfDLPlatform, JobManifest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_observability.json")


class _Driver:
    def __init__(self, server, platform):
        self.server, self.platform = server, platform
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)

    def _run(self):
        while not self._stop.is_set():
            with self.server.lock:
                self.platform.tick()
            time.sleep(0.002)

    def __enter__(self):
        self._thread.start()
        return self

    def __exit__(self, *exc):
        self._stop.set()
        self._thread.join()


def _follow_longpoll(server, platform, key, sim_s: int, wait_ms: int):
    t = HttpTransport(server.base_url)
    client = ApiClient(t, key, prefer_sse=False)
    job = client.submit(JobManifest(name="lp", tenant="bench",
                                    sim_duration=sim_s))
    with _Driver(server, platform):
        t0 = time.perf_counter()
        lines = list(client.follow_logs(job, wait_ms=wait_ms))
        wall = time.perf_counter() - t0
    requests = t.requests_sent  # snapshot before the verification read
    assert lines == client.logs(job), "long-poll follower dropped lines"
    return {"lines": len(lines), "requests": requests,
            "streams": t.streams_opened, "wall_s": round(wall, 3)}


def _follow_sse(server, platform, key, sim_s: int):
    t = HttpTransport(server.base_url)
    client = ApiClient(t, key)  # prefer_sse=True
    job = client.submit(JobManifest(name="sse", tenant="bench",
                                    sim_duration=sim_s))
    with _Driver(server, platform):
        t0 = time.perf_counter()
        lines = list(client.follow_logs(job))
        wall = time.perf_counter() - t0
    requests = t.requests_sent
    assert lines == client.logs(job), "SSE follower dropped lines"
    return {"lines": len(lines), "requests": requests,
            "streams": t.streams_opened, "wall_s": round(wall, 3)}


def _sse_vs_longpoll_drill(quick: bool) -> dict:
    sim_s = 60 if quick else 240
    p = FfDLPlatform(n_hosts=4, chips_per_host=4)
    key = p.auth.issue_key("bench")
    with ApiHttpServer(p, heartbeat_s=1.0) as server:
        lp = _follow_longpoll(server, p, key, sim_s, wait_ms=10)
        sse = _follow_sse(server, p, key, sim_s)
        streams_opened_srv = server.streams_opened
    assert sse["streams"] == 1, sse          # the whole follow: ONE stream
    assert streams_opened_srv == 1
    # submit is 1 request on each side; the follow itself is the rest
    lp_follow = lp["requests"] - 1
    sse_follow = sse["requests"] - 1 + sse["streams"]
    ratio = lp_follow / max(1, sse_follow)
    return {"long_poll": lp, "sse": sse,
            "follow_requests_long_poll": lp_follow,
            "follow_requests_sse": sse_follow,
            "request_ratio": round(ratio, 1)}


def _event_replay_drill(quick: bool) -> dict:
    n_events = 200 if quick else 2_000
    fed = Federation(n_shards=2, n_hosts=4, chips_per_host=4)
    admin = fed.auth.issue_admin_key()
    for i in range(n_events):
        fed.shards[i % 2].events.emit("bench", "job_submitted", n=i)
    kill_at = n_events // 2
    served: set = set()
    cursor = None
    pages = unavailable = duplicates = 0
    killed = False
    t0 = time.perf_counter()
    while True:
        try:
            out = fed.api.events(admin, cursor=cursor, limit=50)
        except ApiError:
            unavailable += 1
            fed.shard_restart(1)  # operator brings the shard back
            continue
        if not out["items"]:
            break
        pages += 1
        for e in out["items"]:
            k = (e["shard"], e["seq"])
            if k in served:
                duplicates += 1
            served.add(k)
        cursor = out["next_cursor"]
        if not killed and len(served) >= kill_at:
            fed.shard_crash(1)  # mid-chain kill
            killed = True
    wall = time.perf_counter() - t0
    total = sum(s.events.seq - s.events.dropped_total for s in fed.shards)
    assert killed and unavailable >= 1, \
        "the kill never hit the page chain — shrink kill_at"
    assert duplicates == 0, f"{duplicates} events replayed"
    assert len(served) == total, \
        f"served {len(served)} of {total} retained events"
    return {"events_emitted": n_events, "events_total_retained": total,
            "events_served": len(served), "pages": pages,
            "duplicates": duplicates, "unavailable_pages": unavailable,
            "events_per_s": round(len(served) / max(wall, 1e-9)),
            "wall_s": round(wall, 3)}


def run(quick: bool = False) -> dict:
    out = {"quick": quick}

    print("sse_vs_longpoll: one follower, two transports ...", flush=True)
    out["sse_vs_longpoll"] = _sse_vs_longpoll_drill(quick)
    d = out["sse_vs_longpoll"]
    print(f"  long-poll {d['follow_requests_long_poll']} requests vs "
          f"SSE {d['follow_requests_sse']} "
          f"({d['request_ratio']}x fewer)")

    print("event_replay: 2 shards, mid-chain kill ...", flush=True)
    out["event_replay"] = _event_replay_drill(quick)
    d = out["event_replay"]
    print(f"  {d['events_served']} events over {d['pages']} pages, "
          f"{d['unavailable_pages']} UNAVAILABLE during the kill, "
          f"0 duplicates ({d['events_per_s']:,} events/s)")

    if not quick:
        # the PR's acceptance bar (timing-sensitive: full size only)
        assert out["sse_vs_longpoll"]["request_ratio"] >= 10, \
            out["sse_vs_longpoll"]
    return out


def main(argv=None):
    quick = "--quick" in (argv if argv is not None else sys.argv[1:])
    out = run(quick=quick)
    if not quick:
        with open(OUT_PATH, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {OUT_PATH}")
    print("OBSERVABILITY BENCH OK")
    return out


if __name__ == "__main__":
    main()
