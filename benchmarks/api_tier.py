"""API-tier benchmark: submit latency + availability under rolling crashes.

FfDL §3.2: the API tier is stateless and replicated — "submitted jobs are
never lost", and a crashed replica is masked by routing to a healthy one.
This benchmark turns that recovery claim into numbers:

  * **submit latency** — wall-clock µs per durable-before-ack submit
    through the load balancer (validation + auth + admission + WAL);
  * **rolling-crash availability** — 3 replicas, exactly one crashed at a
    time in rotation, a mixed idempotent workload (submit with idempotency
    keys, status, paginated list) issued throughout. The balancer must
    deliver 100% availability; the same drill against a single
    un-replicated gateway shows the outage a tenant would see;
  * **idempotency drill** — every submit retried with its idempotency key,
    then the metastore is crashed and rebuilt from the WAL and every key
    replayed once more: duplicates_created must be 0.
"""

from __future__ import annotations

import time

from repro.api import ApiError, SubmitRequest
from repro.core import FfDLPlatform, JobManifest
from repro.core.metastore import MetaStore


def _manifest(i: int, tenant: str = "bench") -> JobManifest:
    return JobManifest(name=f"api-bench-{i}", tenant=tenant, n_learners=1,
                       chips_per_learner=1, sim_duration=30)


def _rolling_drill(n_replicas: int, rounds: int = 30,
                   calls_per_round: int = 6) -> dict:
    """One crash rotation; returns ok/fail counts + per-call latencies."""
    p = FfDLPlatform(n_hosts=8, chips_per_host=4,
                     n_api_replicas=n_replicas)
    key = p.auth.issue_key("bench")
    ok = fail = 0
    latencies: list[float] = []
    submitted: list[str] = []
    for r in range(rounds):
        down = r % max(1, len(p.api_replicas))
        p.api_crash(replica=down)
        for c in range(calls_per_round):
            i = r * calls_per_round + c
            t0 = time.perf_counter()
            try:
                if c % 3 == 0:
                    resp = p.api.submit(key, SubmitRequest(
                        manifest=_manifest(i),
                        idempotency_key=f"idem-{i}"))
                    submitted.append(resp.job_id)
                elif c % 3 == 1 and submitted:
                    p.api.status(key, submitted[-1])
                else:
                    p.api.list_jobs(key, limit=10)
                ok += 1
            except ApiError:
                fail += 1
            latencies.append(time.perf_counter() - t0)
        p.api_restart(replica=down)
        p.tick()
    return {"ok": ok, "fail": fail, "latencies": latencies,
            "failovers": p.api.stats["failovers"],
            "jobs": len(set(submitted)), "platform": p, "key": key}


def _idempotency_drill(p: FfDLPlatform, key: str, n: int = 20) -> dict:
    """Duplicate every submit; crash+rebuild the metastore; replay again."""
    first = {}
    for i in range(n):
        req = SubmitRequest(manifest=_manifest(i, "idem-team"),
                            idempotency_key=f"job-{i}")
        first[i] = p.api.submit(key, req).job_id
    dup_before = sum(
        p.api.submit(key, SubmitRequest(manifest=_manifest(i, "idem-team"),
                                        idempotency_key=f"job-{i}")).job_id
        != first[i] for i in range(n))
    # catastrophic metastore loss → rebuild from the WAL
    journal = list(p.meta._journal)
    p.meta.crash()
    rebuilt = MetaStore(p.clock)
    rebuilt.replay_journal(journal)
    p.meta = rebuilt
    dup_after = sum(
        p.api.submit(key, SubmitRequest(manifest=_manifest(i, "idem-team"),
                                        idempotency_key=f"job-{i}")).job_id
        != first[i] for i in range(n))
    total = len(p.meta.jobs(tenant="idem-team"))
    return {"duplicates_created": dup_before + dup_after,
            "unique_jobs": total, "expected_jobs": n}


def run() -> dict:
    replicated = _rolling_drill(n_replicas=3)
    single = _rolling_drill(n_replicas=1)

    p = replicated["platform"]
    idem_key = p.auth.issue_key("idem-team")
    idem = _idempotency_drill(p, idem_key)

    lat = sorted(replicated["latencies"])
    n = len(lat)
    total_r = replicated["ok"] + replicated["fail"]
    total_s = single["ok"] + single["fail"]
    return {
        "availability_replicated": replicated["ok"] / total_r,
        "availability_single": single["ok"] / total_s,
        "failovers": replicated["failovers"],
        "submit_latency_us": {
            "p50": lat[n // 2] * 1e6,
            "p99": lat[min(n - 1, int(n * 0.99))] * 1e6,
            "mean": sum(lat) / n * 1e6,
        },
        "idempotency": idem,
    }


def main():
    out = run()
    print("# API tier: availability under rolling replica crashes")
    print("metric,value")
    print(f"availability_3_replicas,{out['availability_replicated']:.4f}")
    print(f"availability_1_replica,{out['availability_single']:.4f}")
    print(f"lb_failovers,{out['failovers']}")
    sl = out["submit_latency_us"]
    print(f"call_latency_us_p50,{sl['p50']:.1f}")
    print(f"call_latency_us_p99,{sl['p99']:.1f}")
    print(f"call_latency_us_mean,{sl['mean']:.1f}")
    idem = out["idempotency"]
    print(f"idempotent_duplicates_created,{idem['duplicates_created']}")
    print(f"idempotent_unique_jobs,{idem['unique_jobs']}"
          f" (expected {idem['expected_jobs']})")
    assert out["availability_replicated"] == 1.0, \
        "replicated API tier must mask single-replica crashes"
    assert idem["duplicates_created"] == 0
    return out


if __name__ == "__main__":
    main()
